"""Runtime optimizations (paper §3.3): caching and batching gains.

  1. result cache on duplicate-heavy columns (the typo workload has ~20%
     duplicated rows by construction) — rows/s with vs without cache;
  2. batching: slot count sweep (1 = unbatched per-row invocation, the
     paper's worst case) — throughput vs decode-slot parallelism.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Csv, load_model, make_engine, timed_rows,
                               v5e_decode_rows_per_s)
from repro.training import data as D


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    rows = D.workload_rows("correct", 64, seed=0)     # ~20% dups
    prompts = [D.PROMPTS["correct"] + r.text for r in rows]

    print("\n=== Runtime opts: result cache ===")
    for cached in (False, True):
        eng = make_engine(params, cfg, tok, use_result_cache=cached)
        outs, rps = timed_rows(eng, prompts, 12)
        hit = eng.result_cache.hit_rate if cached else 0.0
        print(f"cache={str(cached):5s} rows/s={rps:7.2f} hit_rate={hit:.2f}")
        csv.add(f"runtime/cache_{cached}", 1e6 / max(rps, 1e-9),
                f"hit={hit:.2f}")

    print("\n=== Runtime opts: batching (slot sweep) ===")
    # CPU caveat: a serial core gains nothing from wider steps (vmap cost
    # is linear), so the measured column inverts; the v5e column models
    # what batching actually amortizes on an accelerator — the per-step
    # weight read is shared by all slots (decode is weight-read-bound).
    uniq = list(dict.fromkeys(prompts))[:24]
    base = v5e_base = None
    for slots in (1, 2, 4, 8):
        eng = make_engine(params, cfg, tok, slots=slots,
                          use_result_cache=False)
        outs, rps = timed_rows(eng, uniq, 12)
        v5e = v5e_decode_rows_per_s(params, cfg, slots, 12)
        base = base or rps
        v5e_base = v5e_base or v5e
        print(f"slots={slots:2d} cpu rows/s={rps:7.2f} ({rps / base:.2f}x)"
              f"   v5e rows/s={v5e:9.0f} ({v5e / v5e_base:.2f}x)")
        csv.add(f"runtime/slots_{slots}", 1e6 / max(rps, 1e-9),
                f"cpu_x={rps / base:.2f};v5e_x={v5e / v5e_base:.2f}")


if __name__ == "__main__":
    main()
