"""Always-on service smoke benchmark: boot the HTTP service, drive a
tenant mix through the real socket path, and hold the subsystem to its
contracts end-to-end.

  PYTHONPATH=src python benchmarks/service.py [--smoke] [--json PATH]

One process plays both sides: ``serve(block=False)`` boots the
ThreadingHTTPServer + pump thread, then a ``ServiceClient`` runs every
tenant's plan over HTTP.  Measured/asserted per run:

  rows/s        end-to-end HTTP-path throughput (admission + NDJSON
                streaming included)
  byte-identity every tenant's HTTP rows == a direct
                ``Scheduler.run_queries`` pass over the same specs on
                a fresh session
  shedding      a tenant capped at 1 in-flight row is 429-shed, and
                the verdict reaches the client
  stats         per-tenant p50/p95/p99 latency present in ``/stats``
  warm restart  checkpoint over HTTP, clean shutdown, restore into a
                FRESH session: a previously seen query re-runs with
                zero recalibrations and identical rows

The JSON artifact embeds the final ``/stats`` payload — the CI
``service-smoke`` job uploads it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from types import SimpleNamespace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import Csv, load_model
from repro.core.compressed import param_bytes
from repro.core.pipeline import Recipe
from repro.olap.query import IOLMSession, Query, query_from_spec
from repro.olap.table import Table
from repro.serving.scheduler import Scheduler, slot_state_bytes
from repro.service import (SemanticQueryService, ServiceClient, TenantSLO,
                           restore_warm_state, serve)
from repro.service.client import ShedError
from repro.service.core import table_rows

MAX_NEW = 6
ENGINE_KW = dict(slots=4, max_len=128, buckets=(24, 96))
RECIPES = [Recipe(name="w8", wbits=8, quant_method="absmax")]

WORDS = ["pyton", "javascrpt", "golang", "rst", "kotln", "hskell",
         "rubby", "scalla", "zigg", "fortrn", "cobal", "luaa"]


def tenant_spec(i: int, n_rows: int) -> dict:
    """One tenant's plan spec: per-tenant prompt template (distinct
    qsig -> distinct compressed instance) over per-tenant data."""
    builder_sess = SimpleNamespace(pool=None, backend="auto")
    vals = [f"{WORDS[j % len(WORDS)]}{i}" for j in range(n_rows)]
    return (Query(Table({"val": vals}), builder_sess)
            .llm_correct("val", prompt=f"[tenant {i}] Fix the word: ",
                         max_new=MAX_NEW)
            .to_spec())


def make_session(params, cfg, tok, budget) -> IOLMSession:
    return IOLMSession(params, cfg, tokenizer=tok, recipes=RECIPES,
                       calib_rows=4, eval_rows=2,
                       engine_kw=dict(ENGINE_KW), pool_budget=budget)


def main(csv: Csv | None = None, *, smoke: bool = False,
         json_path: str | None = None) -> dict:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    n_tenants = 2 if smoke else 4
    n_rows = 4 if smoke else 10
    base_entry = (param_bytes(params)
                  + ENGINE_KW["slots"] * slot_state_bytes(
                      cfg, ENGINE_KW["max_len"]))
    budget = int(3 * base_entry)
    specs = {f"t{i}": tenant_spec(i, n_rows) for i in range(n_tenants)}

    print(f"\n=== Semantic query service ({n_tenants} tenants x "
          f"{n_rows} rows over HTTP, budget {budget / 1e6:.1f} MB) ===")
    sess = make_session(params, cfg, tok, budget)
    svc = SemanticQueryService(
        sess,
        slos={"capped": TenantSLO(max_inflight_rows=1, max_queries=2)},
        default_slo=TenantSLO(max_inflight_rows=512, max_queries=16))
    server, thread = serve(svc, port=0, block=False)
    host, port = server.server_address[:2]
    client = ServiceClient(host, port)
    print(f"[service] listening on {host}:{port}")

    t0 = time.time()
    rows_by_tenant = {t: client.query(t, spec)
                      for t, spec in specs.items()}
    dt = time.time() - t0
    total_rows = sum(len(r) for r in rows_by_tenant.values())
    assert total_rows == n_tenants * n_rows
    rows_per_s = total_rows / dt
    print(f"[service] {total_rows} rows over HTTP in {dt:.2f}s "
          f"({rows_per_s:.2f} rows/s)")
    csv.add("service/http", 1e6 * dt / total_rows,
            f"tenants={n_tenants};rows_per_s={rows_per_s:.2f}")

    # --- byte-identity vs the library path ----------------------------
    ref = make_session(params, cfg, tok, budget)
    res = Scheduler(ref.pool, share=4).run_queries(
        {t: query_from_spec(s, ref) for t, s in specs.items()})
    for t in specs:
        assert rows_by_tenant[t] == table_rows(res[t]), \
            f"{t}: HTTP rows diverge from Scheduler.run_queries"
    print("[service] HTTP rows byte-identical to Scheduler.run_queries")

    # --- SLO shedding --------------------------------------------------
    shed_seen = False
    try:
        ServiceClient(host, port, max_retries=0).query(
            "capped", specs["t0"])
    except ShedError as e:
        shed_seen = True
        print(f"[service] capped tenant shed as expected: "
              f"{e.verdict['reason']}")
    assert shed_seen, "capped tenant was not shed"

    # --- stats ---------------------------------------------------------
    stats = client.stats()
    for t in specs:
        lat = stats["scheduler"]["tenants"][t]["latency"]
        assert lat["p50"] is not None \
            and lat["p50"] <= lat["p95"] <= lat["p99"]
    assert stats["admission"]["capped"]["shed"] >= 1
    print(f"[service] /stats ok: queries={stats['service']['queries']} "
          f"shed={stats['service']['shed']} "
          f"recalibrations={stats['session']['recalibrations']}")

    # --- warm restart --------------------------------------------------
    ckpt = os.path.join(tempfile.mkdtemp(prefix="iolm_service_"), "warm")
    client.checkpoint(ckpt)
    client.shutdown()
    thread.join(timeout=10)
    server.server_close()
    svc.stop()
    warm = make_session(params, cfg, tok, budget)
    restore_warm_state(warm, ckpt)
    t1 = time.time()
    rows_again = table_rows(query_from_spec(specs["t0"], warm).run())
    warm_dt = time.time() - t1
    assert warm.recalibrations == 0, \
        f"warm restart recalibrated {warm.recalibrations}x"
    assert warm.cascade_fits == 0
    assert rows_again == rows_by_tenant["t0"]
    print(f"[service] warm restart: seen query re-answered in "
          f"{warm_dt:.2f}s with 0 recalibrations")
    csv.add("service/warm_restart", 1e6 * warm_dt / n_rows,
            "recalibrations=0")

    result = {
        "smoke": smoke, "tenants": n_tenants, "rows_per_tenant": n_rows,
        "rows_per_s": rows_per_s, "shed_seen": shed_seen,
        "warm_restart_s": warm_dt, "warm_recalibrations": 0,
        "stats": stats, "csv": csv.lines,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[service] wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (2 tenants, 4 rows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results (incl. /stats payload) as JSON")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
