"""Multi-tenant pool benchmark: aggregate rows/s vs tenant count under a
fixed byte budget — the paper's parallelism-dividend claim, reproduced.

  PYTHONPATH=src python benchmarks/multi_tenant.py [--smoke] [--json PATH]

Each tenant runs the data-correction workload through its OWN
instance-optimized model (distinct per-tenant prompt template ->
distinct query signature -> distinct compressed instance), submitted to
one shared ``ModelPool`` + ``Scheduler`` (serving/scheduler.py).  Two
fleets compete under the SAME pool byte budget:

  base   per-tenant *uncompressed* instances (the identity recipe —
         stand-in for a full-precision specialized model): few fit,
         extra tenants queue head-of-line and evicted engines must be
         rebuilt (the swap cost shows up in the measured numbers)
  iolm   per-tenant int8 instances: the compressed fleet packs 2-3x
         more resident models into the identical budget, so more
         tenants make progress simultaneously

Reported per (fleet, tenant-count) cell:

  rows/s      measured end-to-end scheduler throughput on this host
              (CPU: includes engine-rebuild/swap cost for overflow
              tenants — the thrash is part of the story)
  v5e rows/s  roofline-projected aggregate on the TPU v5e target:
              each *concurrently resident* engine is projected as an
              independent accelerator partition (the byte budget is
              the fleet's HBM allocation), so the projection grows
              with resident-model count and plateaus at the budget's
              capacity — the number the serial CPU container cannot
              measure but the artifact sizes determine
  resident    models resident at steady state / evictions during run

Assertions (the acceptance bar): the iolm fleet's projected aggregate
grows with tenant count until the budget is full, beats the base fleet
at >= 4 tenants, and every tenant's greedy outputs are byte-identical
to running that tenant alone on a private single-model engine.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (Csv, load_model, reset_pool_steady_state,
                               tenant_workload, v5e_decode_rows_per_s)
from repro.core.pipeline import Recipe
from repro.olap.query import IOLMSession
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler, slot_state_bytes

MAX_NEW = 8
ENGINE_KW = dict(slots=4, max_len=128, buckets=(24, 96))
SHARE = 4

FLEETS = {
    # per-tenant full-precision instance: the identity recipe keeps the
    # weights untouched but versions the model per query, so the pool
    # must hold one full-size engine per tenant
    "base": [Recipe(name="identity")],
    "iolm": [Recipe(name="w8", wbits=8, quant_method="absmax")],
}


def make_session(params, cfg, tok, recipes, budget) -> IOLMSession:
    return IOLMSession(params, cfg, tokenizer=tok, recipes=recipes,
                       calib_rows=8, eval_rows=4,
                       engine_kw=dict(ENGINE_KW), pool_budget=budget)


def submit_all(sess, n_tenants, n_rows) -> list:
    sched = Scheduler(sess.pool, share=SHARE)
    subs = []
    for i in range(n_tenants):
        tmpl, prompts = tenant_workload(i, n_rows)
        subs.append(sched.submit(f"t{i}", prompts, qsig=f"t{i}",
                                 probe=prompts[:12], max_new=MAX_NEW,
                                 prefix=tmpl))
    return sched, subs


def run_cell(params, cfg, tok, recipes, budget, n_tenants, n_rows):
    """One (fleet, tenant-count) cell: warmup pass (optimize + compile),
    then a timed pass on the warm pool."""
    sess = make_session(params, cfg, tok, recipes, budget)
    sched, _ = submit_all(sess, n_tenants, n_rows)
    sched.run()
    reset_pool_steady_state(sess.pool)
    ev0 = sess.pool.stats.evictions        # report the timed pass only
    t0 = time.time()
    sched, subs = submit_all(sess, n_tenants, n_rows)
    sched.run()
    dt = time.time() - t0
    total_rows = sum(len(s.results()) for s in subs)
    assert total_rows == n_tenants * n_rows
    pool = sess.pool
    resident = [e.engine for e in pool._entries.values()]
    projected = sum(v5e_decode_rows_per_s(e.params, e.cfg, e.slots, MAX_NEW,
                                          max_len=ENGINE_KW["max_len"])
                    for e in resident)
    return dict(sess=sess, subs=subs, rows_per_s=total_rows / dt,
                projected=projected, resident=len(resident),
                resident_bytes=pool.resident_bytes,
                evictions=pool.stats.evictions - ev0,
                ticks=sched.stats.ticks)


def check_byte_identical(cell, n_rows) -> bool:
    """Every tenant's scheduler outputs must equal a private serial
    single-engine run of the same model — interleaving changes the
    schedule, never the tokens."""
    sess = cell["sess"]
    for sub in cell["subs"]:
        tmpl, prompts = tenant_workload(int(sub.tenant[1:]), n_rows)
        m = sess._optimize(sub.qsig, sub.probe)        # ModelCache hit
        eng = Engine(m.params, m.cfg, tokenizer=sess.tok,
                     version=m.version, **ENGINE_KW)
        ref = eng.generate_stream(iter(prompts), max_new=MAX_NEW,
                                  prefix=tmpl)
        assert sub.results() == ref, \
            f"{sub.tenant}: scheduler outputs diverge from serial run"
    return True


def main(csv: Csv | None = None, *, smoke: bool = False,
         json_path: str | None = None) -> dict:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    n_rows = 8 if smoke else 16
    tenant_grid = (1, 2, 4) if smoke else (1, 2, 4, 8)

    # Budget: ~2.7 full-precision engines -> 2 base instances fit while
    # the int8 fleet packs 4+.  Derived from real artifact sizes, not
    # hard-coded.
    from repro.core.compressed import param_bytes
    base_entry = (param_bytes(params)
                  + ENGINE_KW["slots"] * slot_state_bytes(
                      cfg, ENGINE_KW["max_len"]))
    budget = int(2.7 * base_entry)

    print(f"\n=== Multi-tenant pool ({n_rows} rows/tenant, budget "
          f"{budget / 1e6:.1f} MB ~ 2.7 base engines) ===")
    print(f"{'fleet':5s} {'tenants':>7s} {'rows/s':>7s} {'v5e r/s':>9s} "
          f"{'resident':>8s} {'MB':>6s} {'evict':>5s} {'ticks':>6s}")
    cells: dict = {}
    for fleet, recipes in FLEETS.items():
        for n in tenant_grid:
            c = run_cell(params, cfg, tok, recipes, budget, n, n_rows)
            cells[(fleet, n)] = c
            print(f"{fleet:5s} {n:7d} {c['rows_per_s']:7.2f} "
                  f"{c['projected']:9.0f} {c['resident']:8d} "
                  f"{c['resident_bytes'] / 1e6:6.2f} {c['evictions']:5d} "
                  f"{c['ticks']:6d}")
            csv.add(f"multi_tenant/{fleet}_t{n}",
                    1e6 / max(c["rows_per_s"], 1e-9),
                    f"v5e={c['projected']:.0f};resident={c['resident']};"
                    f"evict={c['evictions']}")

    # --- the acceptance bar -------------------------------------------
    # 1. compression packs strictly more resident models into the budget
    nmax = tenant_grid[-1]
    assert cells[("iolm", nmax)]["resident"] \
        > cells[("base", nmax)]["resident"], \
        "compressed fleet should fit more resident models"
    # 2. projected aggregate grows with tenant count while models fit
    proj = [cells[("iolm", n)]["projected"] for n in tenant_grid]
    res = [cells[("iolm", n)]["resident"] for n in tenant_grid]
    for a, b in zip(range(len(proj) - 1), range(1, len(proj))):
        if res[b] > res[a]:            # still under budget: must grow
            assert proj[b] > proj[a], \
                f"projected aggregate did not grow: {proj}"
    # 3. the compressed fleet wins at >= 4 tenants
    for n in [t for t in tenant_grid if t >= 4]:
        assert cells[("iolm", n)]["projected"] \
            > cells[("base", n)]["projected"], \
            f"iolm fleet should beat base fleet at {n} tenants"
        if cells[("iolm", n)]["rows_per_s"] \
                <= cells[("base", n)]["rows_per_s"]:
            print(f"[multi_tenant] note: measured rows/s at {n} tenants "
                  f"did not beat base on this host (CPU serializes "
                  f"engines; the v5e projection is the headline axis)")
    # 4. per-tenant outputs byte-identical to serial execution
    ident = check_byte_identical(cells[("iolm", 2)], n_rows)
    check_byte_identical(cells[("base", 2)], n_rows)
    print("[multi_tenant] per-tenant outputs byte-identical to serial "
          "single-engine runs")

    result = {
        "smoke": smoke, "budget": budget, "rows_per_tenant": n_rows,
        "cells": [
            {"fleet": f, "tenants": n, "rows_per_s": c["rows_per_s"],
             "v5e_rows_per_s": c["projected"], "resident": c["resident"],
             "resident_bytes": c["resident_bytes"],
             "evictions": c["evictions"]}
            for (f, n), c in cells.items()],
        "outputs_identical": ident,
        "csv": csv.lines,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[multi_tenant] wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (fewer tenants, fewer rows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measured cells as a JSON artifact")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
