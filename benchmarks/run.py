"""Benchmark entry point: one section per paper table/figure.

  table1       paper Table 1  (throughput / size / accuracy x 3 workloads)
  ablation     compression-recipe grid (extends the paper's 2 variants)
  runtime_opts caching + batching gains (paper §3.3)
  serving      async core grid: rows/s + slot utilization vs slots x
               buckets x sampler, base vs int8
  multi_tenant aggregate rows/s vs tenant count under a fixed pool byte
               budget, per-tenant base vs instance-optimized fleets
  device_parallel
               the fleet across a (forced) 4-device mesh: 1 vs 4
               devices, TP base vs compressed replicas
  roofline     dry-run roofline table (§Roofline; needs results/dryrun.json)

Prints ``name,us_per_call,derived`` CSV lines throughout.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (ablation, device_parallel, multi_tenant,
                            roofline, runtime_opts, serving, table1)
    from benchmarks.common import Csv
    csv = Csv()
    print("== IOLM-DB benchmark suite ==")
    table1.main(csv)
    ablation.main(csv)
    runtime_opts.main(csv)
    serving.main(csv)
    multi_tenant.main(csv)
    device_parallel.main(csv)
    roofline.main(csv)
    print("\n== CSV summary ==")
    for line in csv.lines:
        print(line)


if __name__ == '__main__':
    main()
