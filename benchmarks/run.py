"""Benchmark entry point: one section per paper table/figure.

  python benchmarks/run.py                 # run every section
  python benchmarks/run.py --list          # enumerate sections
  python benchmarks/run.py --only serving  # run one section

Prints ``name,us_per_call,derived`` CSV lines throughout.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# section name -> (module, one-line description); order is run order
SECTIONS = {
    "table1": ("benchmarks.table1",
               "paper Table 1 (throughput / size / accuracy x 3 "
               "workloads)"),
    "ablation": ("benchmarks.ablation",
                 "compression-recipe grid (extends the paper's 2 "
                 "variants)"),
    "runtime_opts": ("benchmarks.runtime_opts",
                     "caching + batching gains (paper §3.3)"),
    "serving": ("benchmarks.serving",
                "async core grid: rows/s + slot utilization vs slots x "
                "buckets x sampler, base vs int8"),
    "optimizer": ("benchmarks.optimizer",
                  "semantic plan rules on vs off: LLM row invocations "
                  "(pushdown + dedup + fusion)"),
    "cascade": ("benchmarks.cascade",
                "confidence-calibrated proxy->base cascade vs "
                "base-only: full-model row invocations"),
    "multi_tenant": ("benchmarks.multi_tenant",
                     "aggregate rows/s vs tenant count under a fixed "
                     "pool byte budget"),
    "service": ("benchmarks.service",
                "always-on HTTP service: rows/s over the socket path, "
                "SLO shedding, warm restart with 0 recalibrations"),
    "device_parallel": ("benchmarks.device_parallel",
                        "the fleet across a (forced) 4-device mesh: 1 "
                        "vs 4 devices, TP base vs compressed replicas"),
    "roofline": ("benchmarks.roofline",
                 "dry-run roofline table (needs results/dryrun.json)"),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="enumerate benchmark sections and exit")
    ap.add_argument("--only", default=None, choices=sorted(SECTIONS),
                    help="run a single section")
    args = ap.parse_args()

    if args.list:
        for name, (_, desc) in SECTIONS.items():
            print(f"{name:16s} {desc}")
        return

    import importlib

    from benchmarks.common import Csv
    csv = Csv()
    print("== IOLM-DB benchmark suite ==")
    names = [args.only] if args.only else list(SECTIONS)
    for name in names:
        importlib.import_module(SECTIONS[name][0]).main(csv)
    print("\n== CSV summary ==")
    for line in csv.lines:
        print(line)


if __name__ == '__main__':
    main()
