"""Compression-recipe ablation: quant x sparsity x structural grid.

Extends the paper's evaluation (which reports only the two picked
variants) with the full design-space sweep the policy searches over:
per recipe -> model bytes, baseline-normalized accuracy, rows/s.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Csv, load_model, make_engine, task_accuracy,
                               timed_rows)
from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.training import data as D

TASK = "correct"
GRID = [
    Recipe(name="identity"),
    Recipe(name="w8-gptq", wbits=8),
    Recipe(name="w8-absmax", wbits=8, quant_method="absmax"),
    Recipe(name="w8-smooth.5", wbits=8, smooth_alpha=0.5),
    Recipe(name="w4-gptq", wbits=4, group=64),
    Recipe(name="24-sparse", nm=(2, 4)),
    Recipe(name="w8+24", wbits=8, nm=(2, 4)),
    Recipe(name="w8+ffn75", wbits=8, ffn_keep_frac=0.75),
    Recipe(name="w8+kv50", wbits=8, kv_keep_frac=0.5),
    Recipe(name="w8+drop1", wbits=8, drop_units=1),
    Recipe(name="w8+emb8", wbits=8, quant_embed=True),
    Recipe(name="bs16@75", block_bs=16, block_density=0.75),
]


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    rows = D.eval_rows(TASK, 32)
    prompts = [D.PROMPTS[TASK] + r.text for r in rows]
    sample, _ = tok.pad_batch(
        [tok.encode(p, bos=True) for p in prompts[:16]], seq_len=96)
    opt = InstanceOptimizer(params, cfg)
    opt.run_calibration({"tokens": jnp.asarray(sample)})

    eng = make_engine(params, cfg, tok)
    outs, rps_base = timed_rows(eng, prompts, 12)
    acc_base = task_accuracy(outs, rows) or 1e-9

    print(f"\n=== Recipe ablation ({TASK}) ===")
    print(f"{'recipe':14s} {'MB':>7s} {'acc':>6s} {'rows/s':>8s}")
    for r in GRID:
        try:
            p2, c2, rep = opt.apply(r)
        except Exception as e:
            print(f"{r.name:14s} inapplicable: {e}")
            continue
        eng2 = make_engine(p2, c2, tok)
        outs2, rps2 = timed_rows(eng2, prompts, 12)
        acc2 = task_accuracy(outs2, rows) / acc_base
        print(f"{r.name:14s} {rep.bytes_after / 1e6:7.2f} {acc2:6.2f} "
              f"{rps2:8.2f}")
        csv.add(f"ablation/{r.name}", 1e6 / max(rps2, 1e-9),
                f"acc={acc2:.2f};MB={rep.bytes_after / 1e6:.2f}")


if __name__ == "__main__":
    main()
