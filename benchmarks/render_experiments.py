"""Render benchmark artifacts into the committed docs.

Two targets:

  --readme   regenerate the README.md §Results table from the
             ``results/BENCH_*.json`` artifacts written by
             ``benchmarks/{serving,multi_tenant,device_parallel}.py
             --json`` (each spliced between RESULTS_BEGIN/END markers)
  (default)  render results/dryrun.json into EXPERIMENTS.md §Dry-run +
             §Roofline — skipped with a message when either file is
             absent (the dry-run artifact is not part of the tree)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "results", "dryrun.json")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")
README = os.path.join(ROOT, "README.md")
HBM = 16e9

# rwkv/zamba inner sequence recurrences stay as rolled scans even in the
# unrolled analysis build -> their HLO compute term undercounts
RECURRENT = ("rwkv6-3b", "zamba2-7b")


def fmt_t(v):
    if v >= 1:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.1f}ms"
    return f"{v * 1e6:.0f}us"


def dryrun_table(data):
    lines = ["| arch | shape | single-pod (256) | multi-pod (512) | "
             "GB/dev (s/m) |", "|---|---|---|---|---|"]
    archs, shapes = [], []
    for k in data:
        a, s, m = k.split(":")
        if a not in archs:
            archs.append(a)
        if s not in shapes:
            shapes.append(s)
    for a in archs:
        for s in shapes:
            rs = data.get(f"{a}:{s}:single")
            rm = data.get(f"{a}:{s}:multi")
            if rs is None and rm is None:
                continue
            if rs and rs["status"] == "skipped":
                lines.append(f"| {a} | {s} | skip | skip | — |")
                continue

            def st(r):
                if r is None:
                    return "—", ""
                if r["status"] != "ok":
                    return r["status"].upper(), ""
                gb = r["bytes_per_device"] / 1e9
                tag = "ok" if r["bytes_per_device"] <= HBM else "ok†"
                return tag, f"{gb:.1f}"
            s1, g1 = st(rs)
            s2, g2 = st(rm)
            lines.append(f"| {a} | {s} | {s1} | {s2} | {g1}/{g2} |")
    n_ok = sum(1 for v in data.values() if v["status"] == "ok")
    n_skip = sum(1 for v in data.values() if v["status"] == "skipped")
    lines.append("")
    lines.append(f"**{n_ok} / {len(data)} cells compile** "
                 f"({n_skip} documented long_500k skips, "
                 f"{len(data) - n_ok - n_skip} failures).  "
                 "† = exceeds the 16 GB/device HBM budget in the "
                 "paper-faithful BASELINE lowering — each is brought under "
                 "budget by the §Perf optimizations (A2/B1/C2), kept "
                 "baseline here per the reproduce-then-optimize protocol.")
    return "\n".join(lines)


def roofline_table(data):
    lines = ["| arch | shape | t_comp | t_mem | t_coll | bound | useful | "
             "GB/dev |", "|---|---|---|---|---|---|---|---|"]
    for k in sorted(data):
        if not k.endswith(":single"):
            continue
        r = data[k]
        if r["status"] != "ok":
            continue
        a, s, _ = k.split(":")
        ro = r["roofline"]
        useful = r["useful_compute_frac"]
        note = "‡" if a in RECURRENT and s in ("train_4k", "prefill_32k") \
            else ""
        lines.append(
            f"| {a} | {s} | {fmt_t(ro['t_compute'])} | "
            f"{fmt_t(ro['t_memory'])} | {fmt_t(ro['t_collective'])} | "
            f"{ro['bound']} | {useful:.2f}{note} | "
            f"{r['bytes_per_device'] / 1e9:.1f} |")
    lines.append("")
    lines.append(
        "‡ recurrent archs: the WKV/SSD chunk scans stay rolled even in "
        "the unrolled analysis build, so the HLO compute term undercounts "
        "the recurrence — MODEL_FLOPS (the `useful` numerator) is the "
        "reference for those cells.  Dominant-term one-liners: train cells "
        "are memory-bound (remat re-reads + FSDP gathers — cut by larger "
        "microbatches or 2.5-D sharding); prefill cells memory-bound "
        "(flash tiles already minimal — next lever is int8 weights); "
        "decode cells collective-bound in the baseline (cache re-gather — "
        "fixed in §Perf by sequence-sharded caches); MoE cells "
        "dispatch-bound (fixed in §Perf by sharded dispatch buffers).")
    return "\n".join(lines)


def _splice(text, begin, end, body):
    i, j = text.index(begin) + len(begin), text.index(end)
    return text[:i] + "\n" + body + "\n" + text[j:]


# ---------------------------------------------------------------------------
# README §Results from results/BENCH_*.json
# ---------------------------------------------------------------------------

def _latest(pattern):
    """Newest artifact matching results/BENCH_<pattern>*.json, parsed."""
    hits = sorted(glob.glob(os.path.join(ROOT, "results",
                                         f"BENCH_{pattern}*.json")),
                  key=os.path.getmtime)
    if not hits:
        return None
    with open(hits[-1]) as f:
        return json.load(f)


def readme_results_table() -> str:
    lines = ["| benchmark | cell | rows/s | v5e rows/s | notes |",
             "|---|---|---|---|---|"]
    n = 0
    serving = _latest("serving")
    if serving:
        for mname, p in serving.get("prefix", {}).items():
            lines.append(
                f"| serving (prefix cache) | {mname} off→on | "
                f"{p['rows_per_s_off']:.1f} → {p['rows_per_s_on']:.1f} | "
                f"— | {p['prefill_token_reduction'] * 100:.0f}% prefill "
                f"tokens saved, outputs identical="
                f"{p['outputs_identical']} |")
            n += 1
    mt = _latest("multitenant")
    mt_cells = (mt or {}).get("cells") or []
    if mt_cells:
        nmax = max(c["tenants"] for c in mt_cells)
        for c in mt_cells:
            if c["tenants"] != nmax:
                continue
            lines.append(
                f"| multi-tenant | {c['fleet']} x{c['tenants']} tenants | "
                f"{c['rows_per_s']:.1f} | {c['v5e_rows_per_s']:.0f} | "
                f"{c['resident']} resident models |")
            n += 1
    casc = _latest("cascade")
    if casc:
        lines.append(
            f"| cascade | budget {casc['budget']:g} | "
            f"{casc['rows'] / max(casc['wall_s_cascade'], 1e-9):.1f} | — | "
            f"{casc['ratio']:g}x fewer full-model rows "
            f"({casc['full_rows_base']} → {casc['full_rows_cascade']}), "
            f"acc {casc['acc_base']:.2f} → {casc['acc_cascade']:.2f}, "
            f"escalation {casc['escalation_rate'] * 100:.0f}% |")
        n += 1
    dp = _latest("device_parallel")
    for c in (dp or {}).get("cells") or []:
        lines.append(
            f"| device-parallel | {c['cell']} | "
            f"{c['rows_per_s']:.1f} | {c['v5e_rows_per_s']:.0f} | "
            f"{c['resident']} resident, "
            f"{c['concurrent_devices']} devices in flight |")
        n += 1
    if n == 0:
        return ("_No `results/BENCH_*.json` artifacts found — run the "
                "benchmarks with `--json` first (see below)._")
    lines.append("")
    lines.append("_CPU `--smoke` numbers from this container; `v5e` is "
                 "the roofline projection on the TPU target (aggregate "
                 "over resident engines).  Regenerate: run the four "
                 "benchmarks with `--json results/BENCH_<name>.json`, "
                 "then `python benchmarks/render_experiments.py "
                 "--readme`._")
    return "\n".join(lines)


def render_readme() -> None:
    with open(README) as f:
        text = f.read()
    text = _splice(text, "<!-- RESULTS_BEGIN -->", "<!-- RESULTS_END -->",
                   readme_results_table())
    with open(README, "w") as f:
        f.write(text)
    print(f"rendered results/BENCH_*.json into {README}")


def main(readme: bool = False):
    if readme:
        render_readme()
        return
    if not (os.path.exists(RESULTS) and os.path.exists(EXP)):
        print(f"skipping EXPERIMENTS render: needs {RESULTS} and {EXP} "
              "(run the dry-run first); use --readme for the README "
              "results table")
        return
    with open(RESULTS) as f:
        data = json.load(f)
    with open(EXP) as f:
        text = f.read()
    text = _splice(text, "<!-- DRYRUN_BEGIN -->", "<!-- DRYRUN_END -->",
                   dryrun_table(data))
    text = _splice(text, "<!-- ROOFLINE_BEGIN -->", "<!-- ROOFLINE_END -->",
                   roofline_table(data))
    with open(EXP, "w") as f:
        f.write(text)
    print(f"rendered {RESULTS} into {EXP}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--readme", action="store_true",
                    help="regenerate README.md §Results from "
                         "results/BENCH_*.json")
    args = ap.parse_args()
    main(readme=args.readme)
