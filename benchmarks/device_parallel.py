"""Device-parallel serving benchmark: the fleet across a jax device mesh.

  PYTHONPATH=src python benchmarks/device_parallel.py [--smoke] [--json P]

The paper's parallelism claim, taken literally at the *device* level:
per-query compression shrinks each specialized model until many fit on
existing hardware, so a device-aware ``ModelPool`` places one fleet of
instance-optimized engines across ``jax.devices()`` (per-device byte
budget, least-loaded placement) and the ``Scheduler`` fan-out dispatches
every device's decode step before blocking on any result.  Two axes:

  1 vs N devices   the SAME per-device budget over 1 vs N devices:
                   resident capacity — and therefore the projected
                   aggregate — scales with the device count, and
                   measured rows/s gains whatever decode overlap the
                   host's cores allow (forced CPU "devices" share
                   silicon; the v5e projection is the headline axis,
                   as in benchmarks/multi_tenant.py)
  base-TP vs fleet under a budget where the UNCOMPRESSED model fits no
                   single device, the pool admits it tensor-parallel
                   over the whole mesh (distributed/sharding.py rules)
                   — one sharded engine every tenant queues behind —
                   while the compressed fleet still places independent
                   per-tenant replicas; aggregate rows/s compares the
                   two ways of spending identical hardware

Outputs of the device-parallel scheduler runs are asserted
**byte-identical** to serial single-device private-engine runs.

Needs >= ``NDEV`` jax devices; when the current process has fewer (the
usual laptop/CI case) it re-runs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the "fake
multi-device recipe" documented in the README.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MAX_NEW = 8
NDEV = 4
ENGINE_KW = dict(slots=4, max_len=128, buckets=(24, 96))
SHARE = 4
W8 = dict(name="w8", wbits=8, quant_method="absmax")


# ---------------------------------------------------------------------------
# multi-device bootstrap: re-exec with forced host devices when needed
# ---------------------------------------------------------------------------

def _respawn(csv, *, smoke: bool, json_path: str | None) -> dict:
    """Re-run this benchmark in a subprocess whose XLA platform is
    forced to NDEV CPU devices (jax device count is fixed at first
    backend init, so the current process cannot grow devices).  The
    marker env var makes a second respawn impossible: if the forced
    child still comes up short of devices we fail loudly instead of
    forking forever."""
    if os.environ.get("_DEVICE_PARALLEL_RESPAWNED"):
        raise RuntimeError(
            f"respawned child still has fewer than {NDEV} devices — "
            "the forced CPU platform did not take effect")
    env = dict(os.environ)
    env["_DEVICE_PARALLEL_RESPAWNED"] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={NDEV}"
                        ).strip()
    # the flag only multiplies the CPU host platform: pin the child to
    # it even when the parent was aimed at an accelerator
    env["JAX_PLATFORMS"] = "cpu"
    out = json_path or os.path.join(tempfile.mkdtemp(), "device_parallel.json")
    cmd = [sys.executable, os.path.abspath(__file__), "--json", out]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd, env=env, check=True)
    with open(out) as f:
        result = json.load(f)
    if csv is not None:       # child already printed its lines
        csv.lines.extend(result.get("csv", []))
    return result


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------

def tenant_workload(i: int, n_rows: int):
    """benchmarks/common.py's shared fleet workload, seeded apart from
    the multi-tenant benchmark's tenants."""
    from benchmarks.common import tenant_workload as shared
    return shared(i, n_rows, seed0=300)


def make_session(params, cfg, tok, recipes, budget, *, devices=None,
                 mesh=None):
    from repro.core.pipeline import Recipe
    from repro.olap.query import IOLMSession
    return IOLMSession(params, cfg, tokenizer=tok,
                       recipes=[Recipe(**r) for r in recipes]
                       if recipes else None,
                       calib_rows=8, eval_rows=4,
                       engine_kw=dict(ENGINE_KW), pool_budget=budget,
                       devices=devices, mesh=mesh)


def submit_all(sess, n_tenants, n_rows, *, optimize=True):
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(sess.pool, share=SHARE)
    subs = []
    for i in range(n_tenants):
        tmpl, prompts = tenant_workload(i, n_rows)
        subs.append(sched.submit(f"t{i}", prompts, qsig=f"t{i}",
                                 probe=prompts[:12], max_new=MAX_NEW,
                                 prefix=tmpl, optimize=optimize))
    return sched, subs


def projected_rows_per_s(pool) -> float:
    """v5e roofline aggregate: every resident single-device engine is
    an independent accelerator partition; a sharded (TP) engine streams
    1/ndev of its weights per device, so its step is ndev-times less
    memory-bound but it remains ONE model (``ndev`` passed through to
    the shared roofline in benchmarks/common.py)."""
    from benchmarks.common import v5e_decode_rows_per_s
    total = 0.0
    for entry in pool._entries.values():
        e = entry.engine
        total += v5e_decode_rows_per_s(e.params, e.cfg, e.slots, MAX_NEW,
                                       max_len=ENGINE_KW["max_len"],
                                       ndev=len(entry.devices) or 1)
    return total


def run_cell(params, cfg, tok, recipes, budget, n_tenants, n_rows, *,
             devices=None, mesh=None, optimize=True):
    """One cell: warmup pass (optimize + compile + place), then a timed
    pass on the warm pool."""
    from benchmarks.common import reset_pool_steady_state
    sess = make_session(params, cfg, tok, recipes, budget,
                        devices=devices, mesh=mesh)
    sched, _ = submit_all(sess, n_tenants, n_rows, optimize=optimize)
    sched.run()
    reset_pool_steady_state(sess.pool)
    ev0 = sess.pool.stats.evictions
    t0 = time.time()
    sched, subs = submit_all(sess, n_tenants, n_rows, optimize=optimize)
    sched.run()
    dt = time.time() - t0
    total_rows = sum(len(s.results()) for s in subs)
    assert total_rows == n_tenants * n_rows
    pool = sess.pool
    return dict(sess=sess, subs=subs, rows_per_s=total_rows / dt,
                projected=projected_rows_per_s(pool),
                resident=len(pool._entries),
                sharded=pool.stats.sharded_admissions,
                evictions=pool.stats.evictions - ev0,
                concurrent_devices=sched.stats.peak_concurrent_devices,
                ticks=sched.stats.ticks)


def check_byte_identical(cell, n_rows, params, cfg, tok) -> bool:
    """Every tenant's device-parallel outputs must equal a private
    serial single-device run of the same model — placement and fan-out
    change the schedule, never the tokens."""
    from repro.serving.engine import Engine
    sess = cell["sess"]
    for sub in cell["subs"]:
        tmpl, prompts = tenant_workload(int(sub.tenant[1:]), n_rows)
        if sub.optimize:
            m = sess._optimize(sub.qsig, sub.probe)    # ModelCache hit
            mp, mc, mv = m.params, m.cfg, m.version
        else:
            mp, mc, mv = params, cfg, "base"
        eng = Engine(mp, mc, tokenizer=tok, version=mv, **ENGINE_KW)
        ref = eng.generate_stream(iter(prompts), max_new=MAX_NEW,
                                  prefix=tmpl)
        assert sub.results() == ref, \
            f"{sub.tenant}: device-parallel outputs diverge from serial"
    return True


# ---------------------------------------------------------------------------
# the grid
# ---------------------------------------------------------------------------

def _run(csv, *, smoke: bool, json_path: str | None) -> dict:
    import jax
    from benchmarks.common import Csv, load_model
    from repro.core.compressed import param_bytes
    from repro.serving.scheduler import slot_state_bytes

    csv = csv or Csv()
    cfg, params, tok = load_model()
    devices = jax.devices()[:NDEV]
    assert len(devices) >= NDEV, \
        f"need {NDEV} devices, have {jax.devices()}"
    n_rows = 6 if smoke else 12
    n_tenants = 4 if smoke else 8

    base_entry = (param_bytes(params)
                  + ENGINE_KW["slots"] * slot_state_bytes(
                      cfg, ENGINE_KW["max_len"]))
    # per-DEVICE budget: 1.5 base engines -> 1 resident base or 2 int8
    # per device; capacity scales with the device count
    budget = int(1.5 * base_entry)

    print(f"\n=== Device-parallel fleet ({n_tenants} tenants x {n_rows} "
          f"rows, {budget / 1e6:.1f} MB/device ~ 1.5 base engines) ===")
    hdr = (f"{'cell':14s} {'dev':>3s} {'rows/s':>7s} {'v5e r/s':>9s} "
           f"{'resident':>8s} {'conc':>4s} {'evict':>5s} {'ticks':>6s}")
    print(hdr)
    cells: dict = {}

    def show(name, ndev, c):
        cells[name] = c
        print(f"{name:14s} {ndev:3d} {c['rows_per_s']:7.2f} "
              f"{c['projected']:9.0f} {c['resident']:8d} "
              f"{c['concurrent_devices']:4d} {c['evictions']:5d} "
              f"{c['ticks']:6d}")
        csv.add(f"device_parallel/{name}",
                1e6 / max(c["rows_per_s"], 1e-9),
                f"v5e={c['projected']:.0f};resident={c['resident']};"
                f"conc={c['concurrent_devices']}")

    # --- axis 1: the same int8 fleet on 1 vs NDEV devices -------------
    for ndev in (1, NDEV):
        c = run_cell(params, cfg, tok, [W8], budget, n_tenants, n_rows,
                     devices=devices[:ndev])
        show(f"iolm_d{ndev}", ndev, c)

    # --- axis 2: TP base vs compressed replicas on one mesh -----------
    # budget where the uncompressed model fits NO single device: the
    # pool admits it tensor-parallel; int8 replicas still place 1:1
    mesh = jax.make_mesh((1, NDEV), ("data", "model"),
                         devices=devices)
    tp_budget = int(0.8 * base_entry)
    c = run_cell(params, cfg, tok, None, tp_budget, n_tenants, n_rows,
                 mesh=mesh, optimize=False)
    assert c["sharded"] >= 1, "base model should have admitted sharded"
    show("base_tp", NDEV, c)
    c = run_cell(params, cfg, tok, [W8], tp_budget, n_tenants, n_rows,
                 devices=devices)
    assert c["sharded"] == 0
    show("iolm_replicas", NDEV, c)

    # --- the acceptance bar -------------------------------------------
    # 1. device-parallel placement multiplies resident capacity and the
    #    projected aggregate with it
    assert cells[f"iolm_d{NDEV}"]["resident"] > cells["iolm_d1"]["resident"]
    assert cells[f"iolm_d{NDEV}"]["projected"] > cells["iolm_d1"]["projected"], \
        "projected aggregate must grow 1 -> 4 devices"
    # 2. the tick fan-out actually overlapped devices
    assert cells[f"iolm_d{NDEV}"]["concurrent_devices"] > 1
    # 3. compressed replicas beat the one TP base model on aggregate
    assert cells["iolm_replicas"]["projected"] > cells["base_tp"]["projected"], \
        "replica fleet should out-aggregate the single TP base model"
    if cells[f"iolm_d{NDEV}"]["rows_per_s"] <= cells["iolm_d1"]["rows_per_s"]:
        print("[device_parallel] note: measured rows/s did not grow with "
              "forced host devices on this machine (they share the same "
              "cores; the v5e projection is the headline axis)")
    # 4. outputs byte-identical to serial single-device runs
    ident = check_byte_identical(cells[f"iolm_d{NDEV}"], n_rows,
                                 params, cfg, tok)
    check_byte_identical(cells["iolm_replicas"], n_rows, params, cfg, tok)
    print("[device_parallel] outputs byte-identical to serial "
          "single-device runs")

    result = {
        "smoke": smoke, "budget_per_device": budget,
        "tp_budget_per_device": tp_budget, "devices": NDEV,
        "tenants": n_tenants, "rows_per_tenant": n_rows,
        "cells": [
            {"cell": name, "rows_per_s": c["rows_per_s"],
             "v5e_rows_per_s": c["projected"], "resident": c["resident"],
             "concurrent_devices": c["concurrent_devices"],
             "sharded_admissions": c["sharded"],
             "evictions": c["evictions"]}
            for name, c in cells.items()],
        "outputs_identical": ident,
        "csv": csv.lines,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[device_parallel] wrote {json_path}")
    return result


def main(csv=None, *, smoke: bool = False,
         json_path: str | None = None) -> dict:
    import jax
    if jax.device_count() < NDEV:
        return _respawn(csv, smoke=smoke, json_path=json_path)
    return _run(csv, smoke=smoke, json_path=json_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (fewer tenants, fewer rows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measured cells as a JSON artifact")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
