"""Paper Table 1: throughput / model size / accuracy per workload.

Three workloads (summarization, data correction, fuzzy join) x three
models (Baseline, IOLM-DB-Perf, IOLM-DB-Acc).  Accuracy is normalized to
the baseline (baseline = 1), exactly like the paper; model size is the
stored parameter bytes; throughput is end-to-end engine rows/s with
batching + result caching active.

The Perf/Acc variants come from the full IOLM-DB workflow: calibrate on
a sample of the workload's own prompts -> recipe search -> pick by
objective (core/policy.py).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Csv, budget_engine, load_model, make_engine,
                               slots_for_budget, task_accuracy, timed_rows,
                               v5e_decode_rows_per_s)
from repro.core import policy as POL
from repro.core.compressed import param_bytes
from repro.core.pipeline import InstanceOptimizer
from repro.training import data as D

N_ROWS = 48
MAX_NEW = {"summarize": 20, "correct": 12, "join": 8}


def optimize_for(task: str, cfg, params, tok):
    """IOLM-DB workflow for one workload; returns {perf, acc} models."""
    rows = D.workload_rows(task, 24, seed=5)
    prompts = [D.PROMPTS[task] + r.text for r in rows]
    sample = prompts[:16]
    toks, _ = tok.pad_batch([tok.encode(p, bos=True) for p in sample],
                            seq_len=96)
    opt = InstanceOptimizer(params, cfg)
    opt.run_calibration({"tokens": jnp.asarray(toks)})
    hold = prompts[16:24]
    htoks, hlens = tok.pad_batch(
        [tok.encode(p, bos=True) + [tok.SEP] for p in hold], seq_len=96)
    eval_fn = POL.make_agreement_eval(params, cfg, jnp.asarray(htoks),
                                      max_new=MAX_NEW[task],
                                      lengths=jnp.asarray(hlens))
    outcome = POL.search(opt, eval_fn, POL.default_recipe_space(cfg),
                         acc_floor=0.85, keep_params=True)
    return outcome


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    base_bytes = param_bytes(params)
    # fixed accelerator memory budget: model + a handful of decode slots
    # (mirrors the paper's H100 setup where the 14.98 GB model + vLLM KV
    # cache share 80 GB; compression converts freed bytes into slots)
    budget = int(base_bytes * 1.5)
    print(f"\n=== Table 1 (baseline {base_bytes / 1e6:.1f} MB, "
          f"memory budget {budget / 1e6:.1f} MB) ===")
    header = (f"{'workload':14s} {'model':14s} {'size MB':>8s} "
              f"{'acc(norm)':>9s} {'slots':>5s} {'cpu r/s':>8s} "
              f"{'v5e r/s':>9s} {'v5e x':>6s}")
    print(header)
    for task in ("summarize", "correct", "join"):
        rows = D.eval_rows(task, N_ROWS)
        prompts = [D.PROMPTS[task] + r.text for r in rows]

        # baseline
        eng = budget_engine(params, cfg, tok, budget)
        outs, rps_base = timed_rows(eng, prompts, MAX_NEW[task])
        acc_base = task_accuracy(outs, rows) or 1e-9
        v5e_base = v5e_decode_rows_per_s(params, cfg, eng.slots,
                                         MAX_NEW[task])

        outcome = optimize_for(task, cfg, params, tok)
        variants = {"Baseline": (params, cfg, base_bytes, 1.0, rps_base,
                                 eng.slots, v5e_base)}
        for name, cand in (("IOLM-DB-Perf", outcome.perf),
                           ("IOLM-DB-Acc", outcome.acc)):
            if cand is None:
                continue
            eng2 = budget_engine(cand.params, cand.cfg, tok, budget)
            outs2, rps2 = timed_rows(eng2, prompts, MAX_NEW[task])
            acc2 = task_accuracy(outs2, rows)
            v5e2 = v5e_decode_rows_per_s(cand.params, cand.cfg, eng2.slots,
                                         MAX_NEW[task])
            variants[name] = (cand.params, cand.cfg, cand.result.bytes,
                              acc2 / acc_base, rps2, eng2.slots, v5e2)
        for name, (_, _, nbytes, acc_norm, rps, slots,
                   v5e) in variants.items():
            print(f"{task:14s} {name:14s} {nbytes / 1e6:8.1f} "
                  f"{acc_norm:9.2f} {slots:5d} {rps:8.2f} "
                  f"{v5e:9.0f} {v5e / v5e_base:5.2f}x")
            csv.add(f"table1/{task}/{name}", 1e6 / max(rps, 1e-9),
                    f"acc={acc_norm:.2f};MB={nbytes / 1e6:.1f};"
                    f"slots={slots};v5e_x={v5e / v5e_base:.2f}")


if __name__ == "__main__":
    main()
