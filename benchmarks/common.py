"""Shared benchmark harness: the trained OLAP model + helpers."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving.engine import Engine
from repro.training import checkpoint as CK
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training import train_loop as TL

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                        "tiny_olap_ckpt")

MODEL_CFG = ModelConfig(name="tiny-olap", family="dense", n_layers=4,
                        d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                        vocab_size=260, rope_theta=10000.0, max_seq=512)


def load_model(min_steps: int = 300) -> Tuple[ModelConfig, dict,
                                              D.ByteTokenizer]:
    """The benchmark LLM: trained on the three OLAP tasks (train if no
    checkpoint exists yet)."""
    tok = D.ByteTokenizer(MODEL_CFG.vocab_size)
    step = CK.latest_step(CKPT_DIR)
    if step is None or step < min_steps:
        out = TL.train(MODEL_CFG,
                       TL.TrainConfig(steps=max(min_steps, 300), batch=16,
                                      seq_len=96, log_every=100,
                                      ckpt_dir=CKPT_DIR, ckpt_every=300),
                       OPT.adamw(lr=2e-3, warmup=30,
                                 total_steps=max(min_steps, 300)))
        return MODEL_CFG, out["params"], tok
    params0 = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), MODEL_CFG))
    opt = OPT.adamw()
    opt0 = jax.eval_shape(opt.init, params0)
    (params, _), _, _ = CK.restore(CKPT_DIR, (params0, opt0))
    return MODEL_CFG, params, tok


def make_engine(params, cfg, tok, **kw) -> Engine:
    kw.setdefault("slots", 8)
    kw.setdefault("max_len", 160)
    kw.setdefault("buckets", (48, 96, 128))
    return Engine(params, cfg, tokenizer=tok, **kw)


def slot_bytes(cfg, max_len: int = 160) -> int:
    """Per-decode-slot state bytes (KV cache / recurrent state, batch=1);
    single source of truth lives next to the ModelPool that budgets it."""
    from repro.serving.scheduler import slot_state_bytes
    return slot_state_bytes(cfg, max_len)


def slots_for_budget(params, cfg, mem_budget: int, *, max_len: int = 160,
                     max_slots: int = 32) -> int:
    """The paper's parallelism dividend: a fixed accelerator memory budget
    holds the model + N decode slots; compressing the model converts the
    freed bytes directly into more concurrent rows."""
    from repro.core.compressed import param_bytes
    free = mem_budget - param_bytes(params)
    return int(max(1, min(max_slots, free // max(slot_bytes(cfg, max_len),
                                                 1))))


def budget_engine(params, cfg, tok, mem_budget: int, **kw) -> Engine:
    s = slots_for_budget(params, cfg, mem_budget,
                         max_len=kw.get("max_len", 160))
    kw["slots"] = s
    return make_engine(params, cfg, tok, **kw)


def v5e_decode_rows_per_s(params, cfg, slots: int, avg_new: int,
                          *, max_len: int = 160, ndev: int = 1) -> float:
    """Roofline-predicted serving throughput on the TPU v5e target.

    One decode step streams the (compressed) weights + every live slot's
    cache from HBM and spends 2·N_active FLOPs per row; rows/s =
    slots / (step_time · tokens_per_row).  This is the number the CPU
    container cannot measure (serial core, no HBM) but the compiled
    artifact sizes determine: int8 weights halve the memory term, freed
    budget raises ``slots`` — the paper's two throughput mechanisms.

    ``ndev > 1`` models a tensor-parallel engine: each device streams
    and computes 1/ndev of the weights AND 1/ndev of the per-slot
    cache (cache_shardings shards KV over the model axis) per step,
    but the result is still ONE model's decode stream (the
    device-parallel benchmark compares it against ndev independent
    compressed replicas).
    """
    from repro.core.compressed import param_bytes
    from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS
    n = max(ndev, 1)
    wb = param_bytes(params) / n
    kv = slot_bytes(cfg, max_len) / n
    flops = 2.0 * cfg.active_param_count() * slots / n
    t_step = max((wb + slots * kv) / HBM_BW, flops / PEAK_FLOPS)
    return slots / (t_step * avg_new)


def tenant_workload(i: int, n_rows: int, *, seed0: int = 100):
    """Distinct prompt template per tenant -> distinct qsig -> distinct
    compressed instance; unique row suffixes keep the result cache out
    of fleet measurements.  Shared by the multi-tenant and
    device-parallel benchmarks (different ``seed0`` per benchmark)."""
    tmpl = (f"tenant-{i} data cleaning: reply with only the canonical "
            f"category for value: ")
    rows = D.workload_rows("correct", n_rows, seed=seed0 + i)
    prompts = [f"{tmpl}{r.text}#{j}" for j, r in enumerate(rows)]
    return tmpl, prompts


def reset_pool_steady_state(pool) -> None:
    """Clear per-engine result caches + stats after a fleet benchmark's
    warmup pass, so the timed pass measures the warm pool (resident
    engines, built jit executables) rather than compilation."""
    from repro.serving.engine import EngineStats
    for entry in pool._entries.values():
        if entry.engine.result_cache is not None:
            entry.engine.result_cache.clear()
        entry.engine.stats = EngineStats()


def task_accuracy(outs: List[str], rows) -> float:
    return float(np.mean([o.strip().startswith(r.target)
                          for o, r in zip(outs, rows)]))


def timed_rows(engine: Engine, prompts: List[str], max_new: int = 20):
    t0 = time.time()
    outs = engine.generate(prompts, max_new=max_new)
    return outs, len(prompts) / (time.time() - t0)


class Csv:
    """name,us_per_call,derived accumulator (the run.py contract)."""

    def __init__(self):
        self.lines: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        line = f"{name},{us_per_call:.1f},{derived}"
        self.lines.append(line)
        print(line, flush=True)
