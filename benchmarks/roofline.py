"""Roofline report: renders results/dryrun.json into the §Roofline table.

Per (arch x shape) single-pod cell: the three terms (seconds), the
dominant bottleneck, MODEL_FLOPS / HLO_FLOPS (useful-compute ratio), and
bytes-per-device vs the 16 GB v5e HBM budget.

  python benchmarks/roofline.py                     # render dryrun.json
  python benchmarks/roofline.py --smoke [--json P]  # kernel-backend gate

``--smoke`` is the CI decode-path gate: it serves a duplicate-free
greedy workload through a paged engine on the **reference** backend and
again on the **pallas** backend (interpret-mode kernels off-TPU), for
the base AND an int8-compressed model, and asserts the outputs are
byte-identical — the acceptance bar for routing the Pallas kernels into
serving.  ``--json`` writes the timings + paged-KV stats artifact the
bench-smoke job uploads per commit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import Csv

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")
HBM = 16e9


def fmt(v: float) -> str:
    if v >= 1:
        return f"{v:7.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:6.1f}ms"
    return f"{v * 1e6:6.0f}us"


def load() -> Dict:
    if not os.path.exists(RESULTS):
        return {}
    with open(RESULTS) as f:
        return json.load(f)


def main(csv: Csv | None = None, mesh: str = "single") -> None:
    csv = csv or Csv()
    data = load()
    cells = {k: v for k, v in data.items() if k.endswith(":" + mesh)}
    if not cells:
        print(f"[roofline] no dry-run results yet at {RESULTS}")
        return
    print(f"\n=== Roofline ({mesh}-pod, per-device terms) ===")
    print(f"{'arch':18s} {'shape':12s} {'st':>2s} {'t_comp':>9s} "
          f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} "
          f"{'GB/dev':>7s} {'fits':>4s}")
    for key in sorted(cells):
        r = cells[key]
        arch, shape, _ = key.split(":")
        if r["status"] == "skipped":
            print(f"{arch:18s} {shape:12s} -- ({r['reason'][:48]})")
            continue
        if r["status"] != "ok":
            print(f"{arch:18s} {shape:12s} {r['status'].upper()}")
            continue
        ro = r["roofline"]
        gb = r["bytes_per_device"] / 1e9
        fits = "yes" if r["bytes_per_device"] <= HBM else "NO"
        print(f"{arch:18s} {shape:12s} ok {fmt(ro['t_compute']):>9s} "
              f"{fmt(ro['t_memory']):>9s} {fmt(ro['t_collective']):>9s} "
              f"{ro['bound']:>10s} {r['useful_compute_frac']:7.2f} "
              f"{gb:7.2f} {fits:>4s}")
        csv.add(f"roofline/{arch}/{shape}",
                max(ro["t_compute"], ro["t_memory"],
                    ro["t_collective"]) * 1e6,
                f"bound={ro['bound']};useful={r['useful_compute_frac']:.2f};"
                f"GB={gb:.2f}")


def smoke(json_path: Optional[str] = None) -> Dict:
    """Reference-vs-pallas byte-identity gate on the serving decode path
    (see module docstring); raises on any output divergence."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.core.pipeline import InstanceOptimizer, Recipe
    from repro.models import api
    from repro.serving.engine import Engine

    cfg = ModelConfig(name="smoke", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    p8, c8, _ = InstanceOptimizer(params, cfg).apply(
        Recipe(name="w8", wbits=8, quant_method="absmax"))
    tmpl = "canonicalize the category value to lowercase: "
    prompts = [f"{tmpl}Row-Value {i:03d}" for i in range(12)]

    def cell(p, c, backend):
        eng = Engine(p, c, slots=4, max_len=128, buckets=(48, 64),
                     use_result_cache=False, backend=backend)
        for q in prompts:
            eng.submit(q, max_new=12, prefix=tmpl)
        t0 = time.time()
        outs = {r.rid: r.text for r in eng.drain()}
        return outs, eng.stats, time.time() - t0

    result: Dict = {"cells": {}}
    print("\n=== Kernel-backend smoke (paged decode, greedy) ===")
    for mname, (p, c) in {"base": (params, cfg), "int8": (p8, c8)}.items():
        ref, _, _ = cell(p, c, "reference")
        pal, st, dt = cell(p, c, "pallas")
        if ref != pal:
            bad = [k for k in ref if ref[k] != pal[k]]
            raise AssertionError(
                f"{mname}: pallas diverged from reference on "
                f"{len(bad)}/{len(ref)} rows (rids {bad[:4]}...)")
        print(f"{mname:5s} identical across backends "
              f"({len(ref)} rows, kv_blocks={st.kv_blocks_in_use} "
              f"shared={st.kv_blocks_shared}, pallas {dt:.2f}s)")
        result["cells"][mname] = {
            "rows": len(ref), "identical": True,
            "pallas_wall_s": dt, "backend": st.backend,
            "kv_blocks_in_use": st.kv_blocks_in_use,
            "kv_blocks_shared": st.kv_blocks_shared,
            "prefix_hits": st.prefix_hits,
        }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[roofline] wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reference-vs-pallas identity gate on the "
                         "paged serving decode path")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the smoke result as a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        smoke(json_path=args.json)
    else:
        main()
        main(mesh="multi")
