"""Roofline report: renders results/dryrun.json into the §Roofline table.

Per (arch x shape) single-pod cell: the three terms (seconds), the
dominant bottleneck, MODEL_FLOPS / HLO_FLOPS (useful-compute ratio), and
bytes-per-device vs the 16 GB v5e HBM budget.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from benchmarks.common import Csv

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")
HBM = 16e9


def fmt(v: float) -> str:
    if v >= 1:
        return f"{v:7.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:6.1f}ms"
    return f"{v * 1e6:6.0f}us"


def load() -> Dict:
    if not os.path.exists(RESULTS):
        return {}
    with open(RESULTS) as f:
        return json.load(f)


def main(csv: Csv | None = None, mesh: str = "single") -> None:
    csv = csv or Csv()
    data = load()
    cells = {k: v for k, v in data.items() if k.endswith(":" + mesh)}
    if not cells:
        print(f"[roofline] no dry-run results yet at {RESULTS}")
        return
    print(f"\n=== Roofline ({mesh}-pod, per-device terms) ===")
    print(f"{'arch':18s} {'shape':12s} {'st':>2s} {'t_comp':>9s} "
          f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} "
          f"{'GB/dev':>7s} {'fits':>4s}")
    for key in sorted(cells):
        r = cells[key]
        arch, shape, _ = key.split(":")
        if r["status"] == "skipped":
            print(f"{arch:18s} {shape:12s} -- ({r['reason'][:48]})")
            continue
        if r["status"] != "ok":
            print(f"{arch:18s} {shape:12s} {r['status'].upper()}")
            continue
        ro = r["roofline"]
        gb = r["bytes_per_device"] / 1e9
        fits = "yes" if r["bytes_per_device"] <= HBM else "NO"
        print(f"{arch:18s} {shape:12s} ok {fmt(ro['t_compute']):>9s} "
              f"{fmt(ro['t_memory']):>9s} {fmt(ro['t_collective']):>9s} "
              f"{ro['bound']:>10s} {r['useful_compute_frac']:7.2f} "
              f"{gb:7.2f} {fits:>4s}")
        csv.add(f"roofline/{arch}/{shape}",
                max(ro["t_compute"], ro["t_memory"],
                    ro["t_collective"]) * 1e6,
                f"bound={ro['bound']};useful={r['useful_compute_frac']:.2f};"
                f"GB={gb:.2f}")


if __name__ == "__main__":
    main()
    main(mesh="multi")
