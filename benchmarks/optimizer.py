"""Semantic-optimizer benchmark: LLM row invocations with the plan
rewriter on vs off — the paper-adjacent claim (Liu et al., 2403.05821)
that deduplication and SQL/LLM-operator reordering cut LLM invocation
cost by large factors, reproduced on the IOLM-DB plan pipeline.

  PYTHONPATH=src python benchmarks/optimizer.py [--smoke] [--json PATH]

Workload (pushdown + dedup): a review table whose ``category`` column
has few distinct values and whose ``status`` column fails half the
rows.  The query maps an LLM label over ``category`` and then filters
on ``status`` (declared read set) — exactly the shape where the
optimizer's two headline rules stack:

  pushdown   the status filter moves below the LLM map, so the model
             never labels rows the filter would discard (2x)
  dedup      the surviving rows collapse to their distinct categories,
             one model invocation each, outputs scattered back

A second query fuses two same-template maps (fusion rule) on top of
the same pipeline.  Reported per cell: LLM row invocations (prompts
actually sent to an engine, from ``Query.last_run_stats``), measured
wall time, and the estimated plan cost from EXPLAIN.  Assertions (the
acceptance bar): optimizer-on outputs are byte-identical to
optimizer-off on both workloads, and the pushdown+dedup workload makes
>= 2x fewer LLM row invocations with the optimizer on.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import Csv, load_model
from repro.core.pipeline import Recipe
from repro.olap.query import IOLMSession, Query
from repro.olap.table import Table
from repro.training import data as D

MAX_NEW = 6
ENGINE_KW = dict(slots=4, max_len=128, buckets=(48, 96))
CATEGORIES = ("books", "garden tools", "kitchen", "lamps")


def workload(n_rows: int) -> Table:
    """Deterministic table: ``category`` cycles through few distinct
    values (dedup headroom), ``status`` fails every other row
    (pushdown headroom)."""
    rows = D.workload_rows("summarize", n_rows)
    return Table({
        "review": [r.text for r in rows],
        "category": [CATEGORIES[i % len(CATEGORIES)]
                     for i in range(n_rows)],
        "status": ["ok" if i % 2 == 0 else "spam"
                   for i in range(n_rows)],
    })


def pushdown_dedup_query(t, session, *, optimize_plan):
    return (Query(t, session, optimize=True, optimize_plan=optimize_plan)
            .llm_map("category", prompt="label the product category: ",
                     out_col="label", max_new=MAX_NEW)
            .filter(lambda r: r["status"] == "ok", columns=["status"]))


def fusion_query(t, session, *, optimize_plan):
    return (Query(t, session, optimize=True, optimize_plan=optimize_plan)
            .llm_map("category", prompt="label the product category: ",
                     out_col="label", max_new=MAX_NEW)
            .llm_map("category", prompt="label the product category: ",
                     out_col="tag", max_new=MAX_NEW)
            .filter(lambda r: r["status"] == "ok", columns=["status"]))


def run_cell(build, t, session, *, optimize_plan):
    q = build(t, session, optimize_plan=optimize_plan)
    t0 = time.time()
    out = q.run()
    wall = time.time() - t0
    return {
        "invocations": sum(s.invocations for s in q.last_run_stats),
        "wall_s": round(wall, 3),
        "est_cost": q.physical_plan().optimized_cost,
        "rules": [f.rule for f in q.physical_plan().firings],
        "table": out,
    }


def main(csv: Csv | None = None, *, smoke: bool = False,
         json_path: str | None = None) -> dict:
    csv = csv or Csv()
    n_rows = 16 if smoke else 64
    print(f"\n== semantic optimizer: plan rules on vs off "
          f"({n_rows} rows) ==")
    cfg, params, tok = load_model()
    recipes = [Recipe(name="w8", wbits=8, quant_method="absmax")]
    t = workload(n_rows)

    cells = {}
    for name, build in (("pushdown_dedup", pushdown_dedup_query),
                        ("fusion", fusion_query)):
        per = {}
        for mode, opt_on in (("off", False), ("on", True)):
            # fresh session per cell: no model/result-cache carryover
            session = IOLMSession(params, cfg, tokenizer=tok,
                                  acc_floor=0.85, recipes=list(recipes),
                                  engine_kw=dict(ENGINE_KW))
            per[mode] = run_cell(build, t, session, optimize_plan=opt_on)
        on, off = per["on"], per["off"]
        assert on["table"].columns == off["table"].columns, \
            f"{name}: optimizer changed query output"
        ratio = off["invocations"] / max(1, on["invocations"])
        print(f"  {name:16s} invocations {off['invocations']:4d} -> "
              f"{on['invocations']:4d}  ({ratio:.1f}x fewer)  "
              f"rules={on['rules']}")
        csv.add(f"optimizer/{name}/off", off["wall_s"] * 1e6,
                f"invocations={off['invocations']}")
        csv.add(f"optimizer/{name}/on", on["wall_s"] * 1e6,
                f"invocations={on['invocations']};ratio={ratio:.1f}x")
        cells[name] = {
            "invocations_off": off["invocations"],
            "invocations_on": on["invocations"],
            "ratio": round(ratio, 2),
            "wall_s_off": off["wall_s"], "wall_s_on": on["wall_s"],
            "est_cost_off": off["est_cost"], "est_cost_on": on["est_cost"],
            "rules_fired": on["rules"],
            "outputs_identical": True,
        }

    pd = cells["pushdown_dedup"]
    assert pd["ratio"] >= 2.0, \
        f"pushdown+dedup must cut invocations >= 2x, got {pd['ratio']}x"
    assert set(pd["rules_fired"]) == {"pushdown", "dedup"}
    assert "fusion" in cells["fusion"]["rules_fired"]
    print(f"  [ok] byte-identical outputs; pushdown+dedup = "
          f"{pd['ratio']}x fewer LLM row invocations")

    result = {"bench": "optimizer", "smoke": smoke, "rows": n_rows,
              "cells": cells}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[optimizer] wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
