"""Model-cascade benchmark: full-model row invocations with the
confidence-calibrated cascade on vs base-only — the instance-optimized
proxy answers the easy rows and only low-confidence rows escalate to
the base model (the physical-plan strategy in olap/physical.py, fitted
by core/calibrate.fit_confidence_threshold).

  PYTHONPATH=src python benchmarks/cascade.py [--smoke] [--json PATH]

Workload (skewed confidence): the ``correct`` task from the training
mixture, whose prompts the benchmark model answers with high
confidence on most rows — exactly the shape a cascade exploits: the
8-bit proxy agrees with the base model on the bulk of the column and
the fitted threshold routes only the disagreeing tail to the base
engine.  Reported per cell: full-model (base-engine) row invocations,
task accuracy against the workload targets, escalation rate, and the
fitted threshold.  Assertions (the acceptance bar):

  - cascade makes >= 2x fewer full-model row invocations than
    base-only at equal accuracy within the configured budget;
  - accuracy budget 0 produces output byte-identical to base-only
    (the exactness contract).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import Csv, load_model, task_accuracy
from repro.core.pipeline import Recipe
from repro.olap.query import IOLMSession, Query
from repro.olap.table import Table
from repro.training import data as D

MAX_NEW = 8
BUDGET = 0.2
ENGINE_KW = dict(slots=4, max_len=128, buckets=(48, 96))
PROMPT = "fix the typo: "


def workload(n_rows: int):
    rows = D.workload_rows("correct", n_rows)
    return Table({"text": [r.text for r in rows]}), rows


def cascade_query(t, session, *, budget, cascade="force"):
    return (Query(t, session, cascade_budget=budget, cascade=cascade)
            .llm_correct("text", prompt=PROMPT, out_col="fixed",
                         max_new=MAX_NEW))


def base_query(t, session):
    return (Query(t, session, optimize=False)
            .llm_correct("text", prompt=PROMPT, out_col="fixed",
                         max_new=MAX_NEW))


def fresh_session(cfg, params, tok):
    # fresh session per cell: no model/result-cache carryover
    return IOLMSession(params, cfg, tokenizer=tok, acc_floor=0.85,
                       recipes=[Recipe(name="w8", wbits=8,
                                       quant_method="absmax")],
                       engine_kw=dict(ENGINE_KW))


def run_cell(q):
    t0 = time.time()
    out = q.run()
    wall = time.time() - t0
    # full-model rows: every row of a base-engine op, only the
    # escalated rows of a cascade op, none of a pure proxy op
    full = sum(s.invocations if s.engine == "base" else s.escalated
               for s in q.last_run_stats)
    return {"outs": out["fixed"], "wall_s": round(wall, 3),
            "full_rows": full, "stats": q.last_run_stats}


def main(csv: Csv | None = None, *, smoke: bool = False,
         json_path: str | None = None) -> dict:
    csv = csv or Csv()
    n_rows = 16 if smoke else 64
    print(f"\n== model cascade: proxy + calibrated escalation vs "
          f"base-only ({n_rows} rows, budget {BUDGET:g}) ==")
    cfg, params, tok = load_model()
    t, rows = workload(n_rows)

    base = run_cell(base_query(t, fresh_session(cfg, params, tok)))
    prox = run_cell(cascade_query(t, fresh_session(cfg, params, tok),
                                  budget=None, cascade="off"))
    casc = run_cell(cascade_query(t, fresh_session(cfg, params, tok),
                                  budget=BUDGET))
    zero = run_cell(cascade_query(t, fresh_session(cfg, params, tok),
                                  budget=0.0))

    (cs,) = casc["stats"]
    acc_base = task_accuracy(base["outs"], rows)
    acc_prox = task_accuracy(prox["outs"], rows)
    acc_casc = task_accuracy(casc["outs"], rows)
    esc_rate = cs.escalated / n_rows
    ratio = base["full_rows"] / max(1, casc["full_rows"])
    thr = cs.threshold if cs.threshold is not None else float("nan")

    print(f"  base-only  full-model rows {base['full_rows']:4d}  "
          f"acc {acc_base:.2f}  wall {base['wall_s']:.2f}s")
    print(f"  proxy-only full-model rows {prox['full_rows']:4d}  "
          f"acc {acc_prox:.2f}  wall {prox['wall_s']:.2f}s")
    print(f"  cascade    full-model rows {casc['full_rows']:4d}  "
          f"acc {acc_casc:.2f}  wall {casc['wall_s']:.2f}s  "
          f"(escalation {esc_rate:.0%}, threshold "
          f"{'inf' if math.isinf(thr) else f'{thr:.4f}'})")
    csv.add("cascade/base_only", base["wall_s"] * 1e6,
            f"full_rows={base['full_rows']};acc={acc_base:.2f}")
    csv.add("cascade/proxy_only", prox["wall_s"] * 1e6,
            f"full_rows={prox['full_rows']};acc={acc_prox:.2f}")
    csv.add("cascade/cascade", casc["wall_s"] * 1e6,
            f"full_rows={casc['full_rows']};acc={acc_casc:.2f};"
            f"ratio={ratio:.1f}x;escalation={esc_rate:.2f}")

    assert ratio >= 2.0, \
        f"cascade must cut full-model rows >= 2x, got {ratio:.1f}x"
    assert acc_casc >= acc_base - BUDGET, \
        f"cascade accuracy {acc_casc} fell below base {acc_base} - {BUDGET}"
    assert zero["outs"] == base["outs"], \
        "budget-0 cascade must be byte-identical to base-only"
    (zs,) = zero["stats"]
    assert zs.escalated == zs.invocations  # every (deduped) row escalated
    print(f"  [ok] {ratio:.1f}x fewer full-model rows at accuracy "
          f"{acc_casc:.2f} (base {acc_base:.2f}, budget {BUDGET:g}); "
          f"budget-0 byte-identical to base-only")

    result = {"bench": "cascade", "smoke": smoke, "rows": n_rows,
              "budget": BUDGET,
              "full_rows_base": base["full_rows"],
              "full_rows_cascade": casc["full_rows"],
              "ratio": round(ratio, 2),
              "escalation_rate": round(esc_rate, 3),
              "threshold": None if math.isinf(thr) else round(thr, 4),
              "acc_base": round(acc_base, 3),
              "acc_proxy": round(acc_prox, 3),
              "acc_cascade": round(acc_casc, 3),
              "wall_s_base": base["wall_s"],
              "wall_s_proxy": prox["wall_s"],
              "wall_s_cascade": casc["wall_s"],
              "budget0_byte_identical": True}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[cascade] wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
