"""Serving-core benchmark: rows/s + decode-step utilization across the
async engine's knobs (slots x bucket ladder x sampler), base vs
instance-optimized (int8) model — the Table-1-adjacent serving numbers —
plus the prefix-sharing KV cache axis (template-heavy prompts, cache on
vs off).

  PYTHONPATH=src python benchmarks/serving.py [--smoke] [--json PATH]

``--smoke`` shrinks both grids to a CI-sized cell set; ``--json`` writes
every measured cell (plus the prefix-reduction summary) as a JSON
artifact — the CI bench-smoke job uploads it per commit so the perf
trajectory accumulates as build evidence.

Each core cell streams the duplicate-heavy correction workload through
``submit()``/``step()``/``drain()`` in bounded chunks (the operator
contract) and reports:

  rows/s       end-to-end streamed throughput (result cache ON: dedup is
               part of the serving story, per Liu et al.)
  util         slot utilization = busy slot-steps / total slot-steps of
               the vmapped decode (ragged retirement leaves idle lanes)
  hit          result-cache hit rate
  v5e rows/s   roofline-projected throughput on the TPU v5e target

The prefix cells render rows through a long fixed template (suffix <<
template — the OLAP operator shape) and report rows/s, prefill tokens
processed, and the prefill-token reduction of prefix sharing.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import Csv, load_model, v5e_decode_rows_per_s
from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.serving.engine import Engine, EngineStats
from repro.serving.sampler import SamplingConfig
from repro.training import data as D

MAX_NEW = 12
N_ROWS = 48
CHUNK = 16

SAMPLERS = {
    "greedy": SamplingConfig(),
    "t0.8k8": SamplingConfig(temperature=0.8, top_k=8, seed=0),
}

# The template-heavy workload: a realistic operator instruction whose
# rendered prefix dwarfs the per-row value (suffix << template).
TEMPLATE = ("You are a data cleaning operator for an OLAP pipeline. "
            "Given a noisy category value, reply with only the canonical "
            "category name in lowercase. Value: ")


def _bench_cell(params, cfg, tok, prompts, *, slots, buckets, sampling):
    eng = Engine(params, cfg, tokenizer=tok, slots=slots, max_len=160,
                 buckets=buckets, sampling=sampling)
    # warmup: jit executables are per-Engine closures, so run the full
    # prompt set once untimed, then reset caches/stats — the timed pass
    # measures serving, not tracing/compilation
    eng.generate_stream(iter(prompts), max_new=MAX_NEW, chunk=CHUNK)
    eng.result_cache.clear()
    eng.stats = EngineStats()
    t0 = time.time()
    outs = eng.generate_stream(iter(prompts), max_new=MAX_NEW, chunk=CHUNK)
    dt = time.time() - t0
    assert len(outs) == len(prompts)
    return eng, len(prompts) / dt


def _prefix_cell(params, cfg, tok, prompts, *, prefix_on):
    """One template-heavy run; prefix sharing toggled by ``prefix_on``.
    The top bucket (176) holds the full template+suffix prompt so the
    off-run never truncates; with sharing on, rows bucket on their
    suffix (16) and only the template miss prefills at full length."""
    eng = Engine(params, cfg, tokenizer=tok, slots=8, max_len=192,
                 buckets=(16, 64, 176), use_prefix_cache=prefix_on)
    # warmup compiles the per-bucket executables AND builds the template's
    # prefix entry; the timed pass measures steady state — the entry
    # persists across queries exactly like the jit cache does (one eager
    # template prefill per (template, version) over the engine lifetime)
    eng.generate_stream(iter(prompts), max_new=MAX_NEW, chunk=CHUNK,
                        prefix=TEMPLATE)
    eng.result_cache.clear()
    eng.stats = EngineStats()
    t0 = time.time()
    outs = eng.generate_stream(iter(prompts), max_new=MAX_NEW, chunk=CHUNK,
                               prefix=TEMPLATE)
    dt = time.time() - t0
    assert len(outs) == len(prompts)
    return eng, outs, len(prompts) / dt


def _prefix_section(csv, models, tok, *, n_rows):
    rows = D.workload_rows("correct", n_rows, seed=3)
    # unique suffixes: keep the result cache out of the prefix story
    prompts = [f"{TEMPLATE}{r.text}#{i}" for i, r in enumerate(rows)]
    print(f"\n=== Prefix-sharing KV cache (template {len(TEMPLATE)} chars, "
          f"{n_rows} rows) ===")
    print(f"{'model':6s} {'prefix':6s} {'rows/s':>7s} {'ptok':>7s} "
          f"{'hits':>5s} {'saved':>7s} {'reduction':>9s}")
    summary = {}
    for mname, (p, c) in models.items():
        cells = {}
        for on in (False, True):
            eng, outs, rps = _prefix_cell(p, c, tok, prompts, prefix_on=on)
            cells[on] = (eng, outs, rps)
        (e0, o0, r0), (e1, o1, r1) = cells[False], cells[True]
        # outputs_identical is recorded (and asserted deterministically
        # in tests/test_serving_cache.py); here a low-order-bit argmax
        # tie between the two attention paths must not abort the whole
        # bench job, so divergence is reported loudly instead
        if o0 != o1:
            ndiff = sum(a != b for a, b in zip(o0, o1))
            print(f"[serving] WARNING: {mname}: {ndiff}/{len(o0)} outputs "
                  f"diverged with prefix sharing on (argmax tie?)")
        # guard against a silent split-refusal (template/bucket drift
        # making every row fall back to full prefill): the cell must
        # actually exercise the prefix path, not trivially match
        assert e1.stats.prefix_hits > 0, \
            "prefix sharing never activated — check TEMPLATE vs buckets"
        red = 1.0 - e1.stats.prefill_tokens / max(e0.stats.prefill_tokens, 1)
        assert red >= 0.4, f"prefill-token reduction {red:.0%} below floor"
        for on, (e, _, r) in cells.items():
            tag = "on" if on else "off"
            print(f"{mname:6s} {tag:6s} {r:7.2f} {e.stats.prefill_tokens:7d} "
                  f"{e.stats.prefix_hits:5d} "
                  f"{e.stats.prefill_tokens_saved:7d} "
                  f"{(red if on else 0.0):8.0%}")
            csv.add(f"serving/prefix_{mname}_{tag}", 1e6 / max(r, 1e-9),
                    f"ptok={e.stats.prefill_tokens};"
                    f"hits={e.stats.prefix_hits};"
                    f"saved={e.stats.prefill_tokens_saved};"
                    f"red={red if on else 0.0:.2f};x={r / r0:.2f}")
        summary[mname] = {
            "rows_per_s_off": r0, "rows_per_s_on": r1,
            "prefill_tokens_off": e0.stats.prefill_tokens,
            "prefill_tokens_on": e1.stats.prefill_tokens,
            "prefill_tokens_saved": e1.stats.prefill_tokens_saved,
            "prefix_hits": e1.stats.prefix_hits,
            "prefill_token_reduction": red,
            "outputs_identical": o0 == o1,
        }
    return summary


def main(csv: Csv | None = None, *, smoke: bool = False,
         json_path: str | None = None) -> dict:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    n_rows = 16 if smoke else N_ROWS
    rows = D.workload_rows("correct", n_rows, seed=0)   # ~20% dup rows
    prompts = [D.PROMPTS["correct"] + r.text for r in rows]

    opt = InstanceOptimizer(params, cfg)
    p8, c8, _ = opt.apply(Recipe(name="w8", wbits=8, quant_method="absmax"))
    models = {"base": (params, cfg), "int8": (p8, c8)}

    samplers = {"greedy": SAMPLERS["greedy"]} if smoke else SAMPLERS
    slot_grid = (8,) if smoke else (2, 8)
    bucket_grid = ((48, 96, 128),) if smoke else ((96,), (48, 96, 128))

    print("\n=== Serving core (async streamed, chunk="
          f"{CHUNK}, {n_rows} rows) ===")
    print(f"{'model':6s} {'sampler':7s} {'slots':>5s} {'buckets':>12s} "
          f"{'rows/s':>7s} {'util':>5s} {'hit':>5s} {'v5e r/s':>9s}")
    base_rps = None
    for mname, (p, c) in models.items():
        for sname, scfg in samplers.items():
            for slots in slot_grid:
                for buckets in bucket_grid:
                    eng, rps = _bench_cell(p, c, tok, prompts, slots=slots,
                                           buckets=buckets, sampling=scfg)
                    base_rps = base_rps or rps
                    util = eng.stats.slot_utilization
                    hit = (eng.result_cache.hit_rate
                           if eng.result_cache else 0.0)
                    v5e = v5e_decode_rows_per_s(p, c, slots, MAX_NEW)
                    bs = "x".join(str(b) for b in buckets)
                    print(f"{mname:6s} {sname:7s} {slots:5d} {bs:>12s} "
                          f"{rps:7.2f} {util:5.2f} {hit:5.2f} {v5e:9.0f}")
                    csv.add(f"serving/{mname}_{sname}_s{slots}_b{bs}",
                            1e6 / max(rps, 1e-9),
                            f"util={util:.2f};hit={hit:.2f};"
                            f"v5e={v5e:.0f};x={rps / base_rps:.2f}")

    prefix_summary = _prefix_section(csv, models, tok,
                                     n_rows=16 if smoke else 32)
    result = {"smoke": smoke, "cells": csv.lines,
              "prefix": prefix_summary}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[serving] wrote {json_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (fewer cells, fewer rows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measured cells as a JSON artifact")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
