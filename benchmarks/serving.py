"""Serving-core benchmark: rows/s + decode-step utilization across the
async engine's knobs (slots x bucket ladder x sampler), base vs
instance-optimized (int8) model — the Table-1-adjacent serving numbers.

  PYTHONPATH=src python benchmarks/serving.py

Each cell streams the duplicate-heavy correction workload through
``submit()``/``step()``/``drain()`` in bounded chunks (the operator
contract) and reports:

  rows/s       end-to-end streamed throughput (result cache ON: dedup is
               part of the serving story, per Liu et al.)
  util         slot utilization = busy slot-steps / total slot-steps of
               the vmapped decode (ragged retirement leaves idle lanes)
  hit          result-cache hit rate
  v5e rows/s   roofline-projected throughput on the TPU v5e target
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import Csv, load_model, v5e_decode_rows_per_s
from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.serving.engine import Engine
from repro.serving.sampler import SamplingConfig
from repro.training import data as D

MAX_NEW = 12
N_ROWS = 48
CHUNK = 16

SAMPLERS = {
    "greedy": SamplingConfig(),
    "t0.8k8": SamplingConfig(temperature=0.8, top_k=8, seed=0),
}


def _bench_cell(params, cfg, tok, prompts, *, slots, buckets, sampling):
    from repro.serving.engine import EngineStats
    eng = Engine(params, cfg, tokenizer=tok, slots=slots, max_len=160,
                 buckets=buckets, sampling=sampling)
    # warmup: jit executables are per-Engine closures, so run the full
    # prompt set once untimed, then reset caches/stats — the timed pass
    # measures serving, not tracing/compilation
    eng.generate_stream(iter(prompts), max_new=MAX_NEW, chunk=CHUNK)
    eng.result_cache.clear()
    eng.stats = EngineStats()
    t0 = time.time()
    outs = eng.generate_stream(iter(prompts), max_new=MAX_NEW, chunk=CHUNK)
    dt = time.time() - t0
    assert len(outs) == len(prompts)
    return eng, len(prompts) / dt


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    cfg, params, tok = load_model()
    rows = D.workload_rows("correct", N_ROWS, seed=0)   # ~20% dup rows
    prompts = [D.PROMPTS["correct"] + r.text for r in rows]

    opt = InstanceOptimizer(params, cfg)
    p8, c8, _ = opt.apply(Recipe(name="w8", wbits=8, quant_method="absmax"))
    models = {"base": (params, cfg), "int8": (p8, c8)}

    print("\n=== Serving core (async streamed, chunk="
          f"{CHUNK}, {N_ROWS} rows) ===")
    print(f"{'model':6s} {'sampler':7s} {'slots':>5s} {'buckets':>12s} "
          f"{'rows/s':>7s} {'util':>5s} {'hit':>5s} {'v5e r/s':>9s}")
    base_rps = None
    for mname, (p, c) in models.items():
        for sname, scfg in SAMPLERS.items():
            for slots in (2, 8):
                for buckets in ((96,), (48, 96, 128)):
                    eng, rps = _bench_cell(p, c, tok, prompts, slots=slots,
                                           buckets=buckets, sampling=scfg)
                    base_rps = base_rps or rps
                    util = eng.stats.slot_utilization
                    hit = (eng.result_cache.hit_rate
                           if eng.result_cache else 0.0)
                    v5e = v5e_decode_rows_per_s(p, c, slots, MAX_NEW)
                    bs = "x".join(str(b) for b in buckets)
                    print(f"{mname:6s} {sname:7s} {slots:5d} {bs:>12s} "
                          f"{rps:7.2f} {util:5.2f} {hit:5.2f} {v5e:9.0f}")
                    csv.add(f"serving/{mname}_{sname}_s{slots}_b{bs}",
                            1e6 / max(rps, 1e-9),
                            f"util={util:.2f};hit={hit:.2f};"
                            f"v5e={v5e:.0f};x={rps / base_rps:.2f}")


if __name__ == "__main__":
    main()
