"""Minimal columnar table (the pandas stand-in of the prototype)."""
from __future__ import annotations

from itertools import compress
from typing import Any, Callable, Dict, List, Optional, Sequence


class Table:
    def __init__(self, columns: Dict[str, List[Any]]):
        columns = dict(columns)
        lens = {k: len(v) for k, v in columns.items()}
        if len(set(lens.values())) > 1:
            raise ValueError(
                "ragged columns: every column must have the same length, "
                f"got {lens}")
        self.columns = columns

    @classmethod
    def from_rows(cls, rows: Sequence[Dict[str, Any]]) -> "Table":
        rows = list(rows)
        if not rows:
            return cls({})
        keys = list(rows[0])
        cols: Dict[str, List[Any]] = {k: [] for k in keys}
        for i, r in enumerate(rows):
            if set(r) != set(keys):
                missing = sorted(set(keys) - set(r))
                extra = sorted(set(r) - set(keys))
                raise ValueError(
                    f"from_rows: row {i} does not match row 0's schema "
                    f"(missing {missing}, unexpected {extra}) — a silent "
                    f"mismatch would build ragged columns")
            for k in keys:
                cols[k].append(r[k])
        return cls(cols)

    def __len__(self) -> int:
        return len(next(iter(self.columns.values()), []))

    def __getitem__(self, col: str) -> List[Any]:
        try:
            return self.columns[col]
        except KeyError:
            raise KeyError(f"no column {col!r}; available: "
                           f"{sorted(self.columns)}") from None

    def with_column(self, name: str, values: List[Any]) -> "Table":
        values = list(values)
        if len(values) != len(self):
            raise ValueError(
                f"with_column({name!r}): {len(values)} values for "
                f"{len(self)} rows")
        out = dict(self.columns)
        out[name] = values
        return Table(out)

    def select(self, cols: Sequence[str]) -> "Table":
        cols = list(cols)
        if not cols:
            raise ValueError(
                "select() needs at least one column — a zero-column "
                "table cannot represent its row count")
        missing = [c for c in cols if c not in self.columns]
        if missing:
            raise KeyError(f"select: no column(s) {missing}; available: "
                           f"{sorted(self.columns)}")
        return Table({c: self.columns[c] for c in cols})

    def take(self, idxs: Sequence[int]) -> "Table":
        """Row subset by index, in the given order."""
        return Table({k: [v[i] for i in idxs]
                      for k, v in self.columns.items()})

    def filter(self, pred: Callable[[Dict[str, Any]], bool]) -> "Table":
        """Keep rows where ``pred(row_dict)`` is truthy.

        Columnar fast path: rows are assembled via one ``zip`` sweep
        over the column lists (C-speed) instead of per-index random
        access into every column, and surviving columns are rebuilt
        with ``itertools.compress`` — same observable semantics (the
        pred still receives a real per-row dict), several times fewer
        Python-level operations per row.
        """
        if not self.columns:
            return Table({})
        names = tuple(self.columns)
        cols = tuple(self.columns.values())
        keep = [bool(pred(dict(zip(names, vals))))
                for vals in zip(*cols)]
        return Table({k: list(compress(c, keep))
                      for k, c in zip(names, cols)})

    def row(self, i: int) -> Dict[str, Any]:
        return {k: v[i] for k, v in self.columns.items()}

    def rows(self) -> List[Dict[str, Any]]:
        return [self.row(i) for i in range(len(self))]

    def head(self, n: int = 5) -> "Table":
        return Table({k: v[:n] for k, v in self.columns.items()})

    def __repr__(self) -> str:
        cols = list(self.columns)
        lines = [" | ".join(cols)]
        for i in range(min(len(self), 8)):
            lines.append(" | ".join(str(self.columns[c][i])[:32]
                                    for c in cols))
        if len(self) > 8:
            lines.append(f"... ({len(self)} rows)")
        return "\n".join(lines)
