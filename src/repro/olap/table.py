"""Minimal columnar table (the pandas stand-in of the prototype)."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence


class Table:
    def __init__(self, columns: Dict[str, List[Any]]):
        lens = {len(v) for v in columns.values()}
        assert len(lens) <= 1, "ragged columns"
        self.columns = dict(columns)

    @classmethod
    def from_rows(cls, rows: Sequence[Dict[str, Any]]) -> "Table":
        cols: Dict[str, List[Any]] = {}
        for r in rows:
            for k, v in r.items():
                cols.setdefault(k, []).append(v)
        return cls(cols)

    def __len__(self) -> int:
        return len(next(iter(self.columns.values()), []))

    def __getitem__(self, col: str) -> List[Any]:
        return self.columns[col]

    def with_column(self, name: str, values: List[Any]) -> "Table":
        assert len(values) == len(self)
        out = dict(self.columns)
        out[name] = list(values)
        return Table(out)

    def select(self, cols: Sequence[str]) -> "Table":
        return Table({c: self.columns[c] for c in cols})

    def filter(self, pred: Callable[[Dict[str, Any]], bool]) -> "Table":
        keep = [i for i in range(len(self)) if pred(self.row(i))]
        return Table({k: [v[i] for i in keep]
                      for k, v in self.columns.items()})

    def row(self, i: int) -> Dict[str, Any]:
        return {k: v[i] for k, v in self.columns.items()}

    def rows(self) -> List[Dict[str, Any]]:
        return [self.row(i) for i in range(len(self))]

    def head(self, n: int = 5) -> "Table":
        return Table({k: v[:n] for k, v in self.columns.items()})

    def __repr__(self) -> str:
        cols = list(self.columns)
        lines = [" | ".join(cols)]
        for i in range(min(len(self), 8)):
            lines.append(" | ".join(str(self.columns[c][i])[:32]
                                    for c in cols))
        if len(self) > 8:
            lines.append(f"... ({len(self)} rows)")
        return "\n".join(lines)
