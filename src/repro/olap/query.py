"""Query pipeline with LLM-operator interception (the IOLM-DB workflow).

``Query`` is a lazy plan over a Table; when the plan contains an LLM
operator and instance-optimization is enabled, execution:

  1. draws a **calibration sample** from the operator's actual input
     column (prompt-formatted — the model sees exactly the query's
     distribution),
  2. runs the InstanceOptimizer (calibrate -> recipe search -> Perf/Acc
     variant per the requested objective),
  3. executes the operator on an Engine wrapping the compressed model,
  4. memoizes the compressed model per (query signature, data signature)
     so repeated/interactive queries skip re-optimization (paper §2
     "recurring or predictable patterns").
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.core import policy as POL
from repro.olap import operators as OPS
from repro.olap.table import Table
from repro.serving.engine import Engine
from repro.training.data import ByteTokenizer, PROMPTS


@dataclass
class OptimizedModel:
    params: Any
    cfg: Any
    report: Any
    recipe: Recipe
    version: str


class ModelCache:
    """(query signature, data signature) -> compressed model."""

    def __init__(self):
        self._d: Dict[Tuple[str, str], OptimizedModel] = {}
        self.hits = 0

    @staticmethod
    def data_signature(values: List[str], k: int = 64) -> str:
        h = hashlib.sha256()
        for v in values[:k]:
            h.update(str(v)[:128].encode())
        return h.hexdigest()[:16]

    def get(self, qsig: str, dsig: str) -> Optional[OptimizedModel]:
        m = self._d.get((qsig, dsig))
        if m is not None:
            self.hits += 1
        return m

    def put(self, qsig: str, dsig: str, m: OptimizedModel) -> None:
        self._d[(qsig, dsig)] = m


class IOLMSession:
    """Holds the base model + optimization machinery across queries."""

    def __init__(self, params, cfg, *, tokenizer: Optional[ByteTokenizer] = None,
                 objective: str = "perf", acc_floor: float = 0.9,
                 recipes: Optional[List[Recipe]] = None,
                 calib_rows: int = 16, eval_rows: int = 8,
                 engine_kw: Optional[Dict] = None):
        self.params = params
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer(max(cfg.vocab_size, 260))
        self.objective = objective
        self.acc_floor = acc_floor
        self.recipes = recipes
        self.calib_rows = calib_rows
        self.eval_rows = eval_rows
        self.model_cache = ModelCache()
        self.engine_kw = engine_kw or {}
        self.log: List[str] = []

    # -- engines --------------------------------------------------------
    def base_engine(self) -> Engine:
        return Engine(self.params, self.cfg, tokenizer=self.tok,
                      version="base", **self.engine_kw)

    def optimized_engine(self, qsig: str, prompts: List[str]) -> Engine:
        m = self._optimize(qsig, prompts)
        return Engine(m.params, m.cfg, tokenizer=self.tok,
                      version=m.version, **self.engine_kw)

    # -- the instance-optimization workflow ------------------------------
    def _optimize(self, qsig: str, prompts: List[str]) -> OptimizedModel:
        dsig = ModelCache.data_signature(prompts)
        cached = self.model_cache.get(qsig, dsig)
        if cached is not None:
            self.log.append(f"[iolm] model cache hit for {qsig}")
            return cached
        t0 = time.time()
        sample = prompts[: self.calib_rows]
        toks, _ = self.tok.pad_batch(
            [self.tok.encode(p, bos=True) for p in sample],
            seq_len=max(16, max(len(p) + 2 for p in sample)))
        batch = {"tokens": jnp.asarray(toks)}
        opt = InstanceOptimizer(self.params, self.cfg)
        opt.run_calibration(batch)
        recipes = self.recipes or POL.default_recipe_space(self.cfg)
        hold = prompts[self.calib_rows:
                       self.calib_rows + self.eval_rows] or sample
        htoks, hlens = self.tok.pad_batch(
            [self.tok.encode(p, bos=True) + [self.tok.SEP] for p in hold],
            seq_len=max(16, max(len(p) + 3 for p in hold)))
        eval_fn = POL.make_agreement_eval(self.params, self.cfg,
                                          jnp.asarray(htoks), max_new=12,
                                          lengths=jnp.asarray(hlens))
        outcome = POL.search(opt, eval_fn, recipes,
                             acc_floor=self.acc_floor, keep_params=True)
        pick = outcome.perf if self.objective == "perf" else outcome.acc
        if pick is None:  # nothing survived: identity model
            m = OptimizedModel(self.params, self.cfg, None,
                               Recipe(name="identity"), "base")
        else:
            m = OptimizedModel(pick.params, pick.cfg, pick.report,
                               pick.recipe,
                               f"{qsig}:{pick.recipe.name}")
            self.log.append(
                f"[iolm] {qsig}: picked {pick.recipe.name} "
                f"acc={pick.result.accuracy:.2f} "
                f"{pick.result.bytes / 1e6:.1f}MB "
                f"({time.time() - t0:.1f}s to optimize)")
        self.model_cache.put(qsig, dsig, m)
        return m


# ---------------------------------------------------------------------------
# lazy query plan
# ---------------------------------------------------------------------------

@dataclass
class _Op:
    kind: str
    kwargs: Dict


class Query:
    def __init__(self, table: Table, session: IOLMSession, *,
                 optimize: bool = True):
        self.table = table
        self.session = session
        self.optimize = optimize
        self._plan: List[_Op] = []

    def llm_map(self, col: str, *, prompt: str = PROMPTS["summarize"],
                out_col: str = "summary", max_new: int = 24) -> "Query":
        self._plan.append(_Op("map", dict(col=col, prompt=prompt,
                                          out_col=out_col, max_new=max_new)))
        return self

    def llm_correct(self, col: str, *, prompt: str = PROMPTS["correct"],
                    out_col: Optional[str] = None,
                    max_new: int = 16) -> "Query":
        self._plan.append(_Op("correct", dict(col=col, prompt=prompt,
                                              out_col=out_col,
                                              max_new=max_new)))
        return self

    def llm_join(self, right: Table, on: Tuple[str, str], *,
                 prompt: str = PROMPTS["join"], max_new: int = 12) -> "Query":
        self._plan.append(_Op("join", dict(right=right, on=on, prompt=prompt,
                                           max_new=max_new)))
        return self

    def filter(self, pred: Callable) -> "Query":
        self._plan.append(_Op("filter", dict(pred=pred)))
        return self

    def _qsig(self, op: _Op) -> str:
        base = f"{op.kind}:{op.kwargs.get('prompt', '')}"
        return hashlib.sha256(base.encode()).hexdigest()[:12]

    def run(self) -> Table:
        t = self.table
        for op in self._plan:
            if op.kind == "filter":
                t = t.filter(op.kwargs["pred"])
                continue
            # --- LLM operator interception ---
            # The probe is a bounded calibration sample (the optimizer
            # reads at most calib+eval rows and a 64-row data signature);
            # the full column streams through the engine chunk-wise
            # inside the operator, never materialized as prompts here.
            n_probe = max(64, self.session.calib_rows
                          + self.session.eval_rows)
            if op.kind == "join":
                probe = [f"{op.kwargs['prompt']}{a} | {b}"
                         for a in t[op.kwargs["on"][0]][:32]
                         for b in op.kwargs["right"][op.kwargs["on"][1]][:2]]
            else:
                probe = [op.kwargs["prompt"] + str(v)
                         for v in t[op.kwargs["col"]][:n_probe]]
            engine = (self.session.optimized_engine(self._qsig(op), probe)
                      if self.optimize else self.session.base_engine())
            if op.kind == "map":
                t = OPS.llm_map(t, op.kwargs["col"], engine,
                                prompt=op.kwargs["prompt"],
                                out_col=op.kwargs["out_col"],
                                max_new=op.kwargs["max_new"])
            elif op.kind == "correct":
                t = OPS.llm_correct(t, op.kwargs["col"], engine,
                                    prompt=op.kwargs["prompt"],
                                    out_col=op.kwargs["out_col"],
                                    max_new=op.kwargs["max_new"])
            elif op.kind == "join":
                t = OPS.llm_join(t, op.kwargs["right"], op.kwargs["on"],
                                 engine, prompt=op.kwargs["prompt"],
                                 max_new=op.kwargs["max_new"])
            st = getattr(engine, "stats", None)
            if st is not None and getattr(st, "prefix_hits", 0):
                # the compressed variant's prefix entries are keyed by
                # engine.version, so a recompression never reuses stale
                # prefix state — hits here are same-version by construction
                self.session.log.append(
                    f"[prefix] {op.kind}: {st.prefix_hits} rows seeded "
                    f"from shared prefix, {st.prefill_tokens_saved} "
                    f"prefill tokens saved (v={engine.version})")
        return t
