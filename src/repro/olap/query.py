"""Query pipeline with LLM-operator interception (the IOLM-DB workflow).

``Query`` is a fluent builder over the declarative logical plan IR
(olap/plan.py).  Execution is staged: the plan is rewritten by the
rule-based semantic optimizer (olap/optimizer.py — non-LLM predicate
pushdown below LLM ops, distinct-input dedup, same-template fusion),
lowered to annotated physical ops (olap/physical.py), and only then
driven through engines; ``Query.explain()`` renders the whole pipeline
without executing.  When the plan contains an LLM operator and
instance-optimization is enabled, execution:

  1. draws a **calibration sample** from the operator's actual input
     column (prompt-formatted — the model sees exactly the query's
     distribution),
  2. runs the InstanceOptimizer (calibrate -> recipe search -> Perf/Acc
     variant per the requested objective),
  3. executes the operator on an Engine wrapping the compressed model,
  4. memoizes the compressed model per (query signature, data signature)
     so repeated/interactive queries skip re-optimization (paper §2
     "recurring or predictable patterns").
"""
from __future__ import annotations

import hashlib
import math
import time
from collections import OrderedDict
from dataclasses import dataclass
from textwrap import indent
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.calibrate import CascadeCalibration, fit_confidence_threshold
from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.core import policy as POL
from repro.kernels.backend import normalize_backend
from repro.olap import operators as OPS
from repro.olap import physical as PHYS
from repro.olap import plan as PLAN
from repro.olap.table import Table
from repro.serving.engine import Engine
from repro.serving.scheduler import ModelPool
from repro.training.data import ByteTokenizer, PROMPTS


@dataclass
class OptimizedModel:
    params: Any
    cfg: Any
    report: Any
    recipe: Recipe
    version: str


class ModelCache:
    """(query signature, data signature) -> compressed model.

    LRU with a capacity cap: a multi-tenant session sees an unbounded
    stream of (query, data) pairs, and each entry holds a full
    compressed parameter set — without eviction the cache would grow
    with tenant count forever.
    """

    def __init__(self, capacity: int = 32):
        self.capacity = capacity
        self._d: "OrderedDict[Tuple[str, str], OptimizedModel]" = \
            OrderedDict()
        self.hits = 0
        self.evictions = 0

    @staticmethod
    def data_signature(values: List[str], k: int = 64) -> str:
        """Order-sensitive digest of a value sample.

        Collision-resistant beyond the head: mixes in the total value
        count, a tail sample (columns often share a head — e.g. sorted
        or defaulted values — and differ late), and each value's length
        so that truncated long values with a common 256-char prefix
        still separate.
        """
        h = hashlib.sha256()
        h.update(f"n={len(values)}".encode())
        sample = list(values[:k])
        if len(values) > k:
            sample += list(values[-k:])
        for v in sample:
            s = str(v)
            h.update(f"|{len(s)}:".encode())
            h.update(s[:256].encode())
        return h.hexdigest()[:16]

    def get(self, qsig: str, dsig: str) -> Optional[OptimizedModel]:
        m = self._d.get((qsig, dsig))
        if m is not None:
            self._d.move_to_end((qsig, dsig))
            self.hits += 1
        return m

    def put(self, qsig: str, dsig: str, m: OptimizedModel) -> None:
        self._d[(qsig, dsig)] = m
        self._d.move_to_end((qsig, dsig))
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)


class IOLMSession:
    """Holds the base model + optimization machinery across queries.

    With ``pool_budget`` set (or an explicit ``pool``), the session
    stops building a private engine per operator and instead draws
    engines from a shared byte-budgeted ``ModelPool``
    (serving/scheduler.py): engines persist across queries (jit
    executables and caches are reused), many tenants' compressed
    models co-reside under one budget, and identical (model-version,
    prompt) work dedups across tenants through each pooled engine's
    result cache.

    ``devices=``/``mesh=`` make that pool device-aware (the budget
    turns per-device, engines are placed across the fleet, and with a
    mesh an oversize model admits tensor-parallel); both default to
    ``None`` ≡ the single-device behavior, with no API change for
    existing callers.
    """

    def __init__(self, params, cfg, *, tokenizer: Optional[ByteTokenizer] = None,
                 objective: str = "perf", acc_floor: float = 0.9,
                 recipes: Optional[List[Recipe]] = None,
                 calib_rows: int = 16, eval_rows: int = 8,
                 engine_kw: Optional[Dict] = None,
                 pool_budget: Optional[int] = None,
                 pool: Optional[ModelPool] = None,
                 devices: Optional[List] = None,
                 mesh=None,
                 placement: str = "least_loaded",
                 backend: str = "auto"):
        self.params = params
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer(max(cfg.vocab_size, 260))
        self.objective = objective
        self.acc_floor = acc_floor
        self.recipes = recipes
        self.calib_rows = calib_rows
        self.eval_rows = eval_rows
        self.model_cache = ModelCache()
        # fitted cascade thresholds, keyed (qsig, dsig, budget): the
        # same proxy model serves every budget (budget is not in qsig),
        # but each budget has its own acceptance threshold
        self.cascade_cache: Dict[Tuple[str, str, float],
                                 CascadeCalibration] = {}
        # KernelBackend for every engine this session builds (directly
        # or through its pool); an explicit engine_kw["backend"] wins
        self.backend = normalize_backend(backend)
        self.engine_kw = dict(engine_kw or {})
        self.engine_kw.setdefault("backend", self.backend)
        # pipeline counters for the warm-restart contract (service/
        # checkpoint.py): a restored session must answer previously
        # seen (qsig, dsig) work with both counters unchanged
        self.recalibrations = 0       # full InstanceOptimizer runs
        self.cascade_fits = 0         # cascade threshold fits
        self.log: List[str] = []
        self.pool = pool
        if pool is not None and (devices is not None or mesh is not None):
            raise ValueError("devices=/mesh= configure a NEW ModelPool and "
                             "are ignored with an explicit pool= — "
                             "construct the pool with them instead")
        if self.pool is None and pool_budget is not None:
            self.pool = ModelPool(self, pool_budget,
                                  engine_kw=self.engine_kw,
                                  devices=devices, mesh=mesh,
                                  placement=placement)
        elif pool is None and (devices is not None or mesh is not None):
            raise ValueError("devices=/mesh= require pool_budget= "
                             "(they configure the shared ModelPool)")

    # -- engines --------------------------------------------------------
    def base_engine(self) -> Engine:
        if self.pool is not None:
            return self.pool.engine_for("base", optimize=False)
        return Engine(self.params, self.cfg, tokenizer=self.tok,
                      version="base", **self.engine_kw)

    def optimized_engine(self, qsig: str, prompts: List[str]) -> Engine:
        if self.pool is not None:
            return self.pool.engine_for(qsig, prompts, optimize=True)
        m = self._optimize(qsig, prompts)
        return Engine(m.params, m.cfg, tokenizer=self.tok,
                      version=m.version, **self.engine_kw)

    # -- cascade calibration --------------------------------------------
    def _cascade(self, qsig: str, prompts: List[str], budget: float, *,
                 max_new: int = 12) -> CascadeCalibration:
        """Fit (and memoize) the cascade acceptance threshold for one
        operator: run the held-out slice of the probe through BOTH the
        instance-optimized proxy and the base model, score agreement,
        and pick the smallest confidence threshold whose
        accepted-but-disagreeing fraction stays within ``budget``
        (core/calibrate.py).  Deterministic for a fixed probe: greedy
        decode on both sides, and the fit is a pure function of the
        (confidence, agreement) sample."""
        dsig = ModelCache.data_signature(prompts)
        key = (qsig, dsig, float(budget))
        hit = self.cascade_cache.get(key)
        if hit is not None:
            return hit
        self.cascade_fits += 1
        if budget <= 0.0:
            cal = fit_confidence_threshold([], [], 0.0)
        else:
            hold = (prompts[self.calib_rows:
                            self.calib_rows + self.eval_rows]
                    or prompts[: self.eval_rows])
            proxy = self.optimized_engine(qsig, prompts)
            if hasattr(proxy, "generate_stream"):
                reqs = proxy.generate_stream(list(hold), max_new=max_new,
                                             return_requests=True)
                proxy_outs = [r.text for r in reqs]
                confs = [r.confidence for r in reqs]
            else:                       # fakes / remote backends
                proxy_outs = proxy.generate(list(hold), max_new=max_new)
                confs = [0.0] * len(proxy_outs)   # no signal: escalate
            base_outs = OPS._invoke(self.base_engine(), list(hold),
                                    max_new=max_new)
            agree = [p == b for p, b in zip(proxy_outs, base_outs)]
            cal = fit_confidence_threshold(confs, agree, budget)
        self.cascade_cache[key] = cal
        self.log.append(
            f"[cascade] {qsig}: threshold={cal.threshold:.4f} "
            f"est_escalation={cal.expected_escalation:.2f} "
            f"(budget={budget:g}, {cal.n_fit} holdout rows)")
        return cal

    def cascade_threshold_for(self, qsig: str,
                              budget: Optional[float]) -> Optional[float]:
        """The fitted threshold for (qsig, budget) if any probe has been
        calibrated yet, else None (EXPLAIN renders 'unfit')."""
        if budget is None:
            return None
        for (q, _, b), cal in self.cascade_cache.items():
            if q == qsig and b == float(budget):
                return cal.threshold
        return None

    # -- the instance-optimization workflow ------------------------------
    def _optimize(self, qsig: str, prompts: List[str]) -> OptimizedModel:
        dsig = ModelCache.data_signature(prompts)
        cached = self.model_cache.get(qsig, dsig)
        if cached is not None:
            self.log.append(f"[iolm] model cache hit for {qsig}")
            return cached
        self.recalibrations += 1
        t0 = time.time()
        sample = prompts[: self.calib_rows]
        toks, _ = self.tok.pad_batch(
            [self.tok.encode(p, bos=True) for p in sample],
            seq_len=max(16, max(len(p) + 2 for p in sample)))
        batch = {"tokens": jnp.asarray(toks)}
        opt = InstanceOptimizer(self.params, self.cfg)
        opt.run_calibration(batch)
        recipes = self.recipes or POL.default_recipe_space(self.cfg)
        hold = prompts[self.calib_rows:
                       self.calib_rows + self.eval_rows] or sample
        htoks, hlens = self.tok.pad_batch(
            [self.tok.encode(p, bos=True) + [self.tok.SEP] for p in hold],
            seq_len=max(16, max(len(p) + 3 for p in hold)))
        eval_fn = POL.make_agreement_eval(self.params, self.cfg,
                                          jnp.asarray(htoks), max_new=12,
                                          lengths=jnp.asarray(hlens))
        outcome = POL.search(opt, eval_fn, recipes,
                             acc_floor=self.acc_floor, keep_params=True)
        pick = outcome.perf if self.objective == "perf" else outcome.acc
        if pick is None:  # nothing survived: identity model
            m = OptimizedModel(self.params, self.cfg, None,
                               Recipe(name="identity"), "base")
        else:
            # the version carries the DATA signature too: compression is
            # calibration-dependent, so same-prompt queries over
            # different data are different models — pool residency,
            # result-cache and prefix-cache keys must never collapse
            # them onto one tenant's params
            m = OptimizedModel(pick.params, pick.cfg, pick.report,
                               pick.recipe,
                               f"{qsig}:{dsig}:{pick.recipe.name}")
            self.log.append(
                f"[iolm] {qsig}: picked {pick.recipe.name} "
                f"acc={pick.result.accuracy:.2f} "
                f"{pick.result.bytes / 1e6:.1f}MB "
                f"({time.time() - t0:.1f}s to optimize)")
        self.model_cache.put(qsig, dsig, m)
        return m


# ---------------------------------------------------------------------------
# the fluent builder over the logical plan IR
# ---------------------------------------------------------------------------

@dataclass
class OpRunStats:
    """Per-LLM-operator execution record from the last ``run()``.
    ``invocations`` counts prompts actually sent to the engine — with
    the optimizer's dedup/pushdown/fusion rules on, this is the number
    the rules exist to shrink (benchmarks/optimizer.py measures it).
    For cascade ops, ``escalated`` is the subset of those rows that
    re-submitted to the base model (benchmarks/cascade.py's
    full-model-invocation metric) and ``threshold`` the fitted
    acceptance cut."""
    kind: str
    qsig: str
    invocations: int
    engine: str = ""
    escalated: int = 0
    threshold: Optional[float] = None


class Query:
    """Thin fluent builder over the logical plan IR (olap/plan.py).

    Each builder call appends one immutable plan node; nothing runs
    until an executor drives the plan.  Execution is
    plan -> optimize (olap/optimizer.py rules: pushdown, dedup,
    fusion) -> lower (olap/physical.py) -> execute; ``explain()``
    renders the whole pipeline with cost estimates and the rules that
    fired.  ``optimize=`` picks the model engine (instance-optimized
    recipe vs base); ``optimize_plan=`` toggles the plan rewriter.
    The rules only remove, reorder, or merge model invocations whose
    results are determined, so for a fixed model the outputs are
    byte-identical either way.  One caveat under ``optimize=True``:
    pushdown also shrinks the calibration probe, so
    calibration-dependent recipes may resolve to a different
    compressed instance — pin ``recipes=`` to a deterministic
    weight-only recipe when exact on-vs-off equality matters (see
    olap/README.md).
    """

    def __init__(self, table: Table, session: IOLMSession, *,
                 optimize: bool = True, optimize_plan: bool = True,
                 cascade_budget: Optional[float] = None,
                 cascade: str = "auto"):
        self.session = session
        self.optimize = optimize
        self.optimize_plan = optimize_plan
        # query-level cascade default: LLM ops without their own
        # accuracy_budget inherit this; cascade= picks the planner mode
        # ("auto" = cost inequality, "force", "off")
        self.cascade_budget = cascade_budget
        self.cascade = cascade
        self._root: PLAN.PlanNode = PLAN.Scan(table)
        self.last_run_stats: List[OpRunStats] = []
        # memoized lowering: (root, flags) -> PhysicalPlan, so
        # explain-then-run describes and executes the SAME lowering
        # instead of re-running the optimizer fixpoint per call
        self._pplan: Optional[PHYS.PhysicalPlan] = None
        self._pplan_key: Optional[Tuple] = None

    @property
    def table(self) -> Table:
        return PLAN.scan_of(self._root).table

    # -- builders -------------------------------------------------------
    def llm_map(self, col: str, *, prompt: str = PROMPTS["summarize"],
                out_col: str = "summary", max_new: int = 24,
                accuracy_budget: Optional[float] = None) -> "Query":
        self._root = PLAN.LLMMap(input=self._root, col=col, prompt=prompt,
                                 out_col=out_col, max_new=max_new,
                                 accuracy_budget=accuracy_budget)
        return self

    def llm_correct(self, col: str, *, prompt: str = PROMPTS["correct"],
                    out_col: Optional[str] = None,
                    max_new: int = 16,
                    accuracy_budget: Optional[float] = None) -> "Query":
        self._root = PLAN.LLMCorrect(input=self._root, col=col,
                                     prompt=prompt, out_col=out_col,
                                     max_new=max_new,
                                     accuracy_budget=accuracy_budget)
        return self

    def llm_join(self, right: Table, on: Tuple[str, str], *,
                 prompt: str = PROMPTS["join"], max_new: int = 12,
                 accuracy_budget: Optional[float] = None) -> "Query":
        self._root = PLAN.LLMJoin(input=self._root, right=right, on=on,
                                  prompt=prompt, max_new=max_new,
                                  accuracy_budget=accuracy_budget)
        return self

    def llm_filter(self, col: str, *, prompt: str, max_new: int = 8,
                   keep: Optional[Callable[[str], bool]] = None,
                   accuracy_budget: Optional[float] = None) -> "Query":
        """Semantic predicate: keep rows whose model output for
        ``prompt + value`` passes ``keep`` (default: affirmative
        prefix)."""
        self._root = PLAN.LLMFilter(input=self._root, col=col,
                                    prompt=prompt, max_new=max_new,
                                    keep=keep or PLAN.default_keep,
                                    accuracy_budget=accuracy_budget)
        return self

    def filter(self, pred: Callable, *,
               columns: Optional[Iterable[str]] = None) -> "Query":
        """Non-LLM predicate.  Declaring ``columns`` (the set the pred
        reads) is what licenses the optimizer to push the filter below
        column-adding LLM ops; without it the pred is opaque and only
        moves past row-set-only ops."""
        self._root = PLAN.Filter(
            input=self._root, pred=pred,
            columns=frozenset(columns) if columns is not None else None)
        return self

    def select(self, cols: Iterable[str]) -> "Query":
        self._root = PLAN.Select(input=self._root, cols=tuple(cols))
        return self

    # -- plan access ----------------------------------------------------
    def logical_plan(self) -> PLAN.PlanNode:
        return self._root

    def physical_plan(self) -> PHYS.PhysicalPlan:
        """plan -> optimize -> lower, annotated with engine choice
        (base vs instance-optimized recipe), prefix template, and pool
        placement.  Memoized until the plan or a routing flag changes
        (builder calls reassign ``_root``, invalidating the key)."""
        backend = getattr(self.session, "backend", "auto")
        flags = (self.optimize, self.optimize_plan,
                 self.session.pool is not None, backend,
                 self.cascade_budget, self.cascade)
        if (self._pplan is None or self._pplan_key is None
                or self._pplan_key[0] is not self._root
                or self._pplan_key[1] != flags):
            self._pplan = PHYS.lower(
                self._root, optimize_models=self.optimize,
                pooled=self.session.pool is not None,
                use_optimizer=self.optimize_plan,
                backend=backend,
                cascade_budget=self.cascade_budget,
                cascade=self.cascade)
            self._pplan_key = (self._root, flags)
        return self._pplan

    def explain(self) -> str:
        """Render the optimized plan with per-node cost estimates, the
        rules that fired, and the physical ops — without executing."""
        pplan = self.physical_plan()
        est = pplan.est

        def annotate(node):
            e = est.get(id(node))
            if e is None:
                return ""
            if PLAN.is_llm(node):
                return (f"(rows {e.rows_in} -> {e.rows_out}, "
                        f"{e.invocations} calls x {e.prompt_tokens} tok "
                        f"= cost {e.cost})")
            return f"(rows {e.rows_in} -> {e.rows_out})"

        # the cost unit is part of the EXPLAIN header so readers (and
        # the snapshot test) can never mistake the raw ints for row
        # counts or milliseconds
        lines = [
            f"EXPLAIN (models: {'optimized' if self.optimize else 'base'}, "
            f"placement: "
            f"{'pool' if self.session.pool is not None else 'private'}, "
            f"plan optimizer: "
            f"{'on' if self.optimize_plan else 'off'}, "
            f"cost unit: rows x prompt_tokens)",
            "",
            "logical plan:",
            indent(PLAN.render(pplan.logical), "  "),
            "",
            "optimized plan:",
            indent(PLAN.render(pplan.optimized, annotate=annotate), "  "),
            "",
            "rules fired:",
        ]
        if pplan.firings:
            # ``[verified]`` = the independent plan verifier re-proved
            # this rewrite's legality (olap/analysis.py), not just the
            # rule's own guard
            lines += [f"  {i}. {f.rule}: {f.desc} "
                      f"(cost {f.cost_before} -> {f.cost_after} "
                      f"rows x prompt_tokens)"
                      + (" [verified]" if f.verified else "")
                      for i, f in enumerate(pplan.firings, 1)]
        else:
            lines.append("  (none)")
        lines += ["", "physical plan:"]
        for i, step in enumerate(pplan.steps, 1):
            if isinstance(step, PHYS.TableStep):
                lines.append(f"  {i}. table {step.node.kind}")
            else:
                line = (
                    f"  {i}. llm {step.node.kind} qsig={step.qsig} "
                    f"engine={step.engine} backend={step.backend} "
                    f"placement={step.placement} "
                    f"dedup={'on' if step.dedup else 'off'} "
                    f"est_calls={step.est.invocations} "
                    f"prefix={step.prefix!r}")
                if step.engine == "cascade":
                    # the fitted threshold appears once a probe has been
                    # calibrated (run() / the scheduler fit it); before
                    # that EXPLAIN shows the planner's escalation prior
                    thr = self.session.cascade_threshold_for(
                        step.qsig, step.accuracy_budget)
                    line += (
                        f" budget={step.accuracy_budget:g}"
                        f" est_escalation={step.est_escalation:.2f}"
                        f" threshold="
                        + (f"{thr:.4f}" if thr is not None else "unfit"))
                lines.append(line)
        ratio = (pplan.logical_cost / pplan.optimized_cost
                 if pplan.optimized_cost else 1.0)
        lines += ["",
                  f"estimated LLM cost: {pplan.logical_cost} -> "
                  f"{pplan.optimized_cost} prompt-tokens "
                  f"({ratio:.1f}x)"]
        return "\n".join(lines)

    # -- execution ------------------------------------------------------
    def _ops(self):
        """The physical plan as a coroutine of LLM-operator
        submissions: yields one ``ExecutableOp`` (olap/physical.py) per
        LLM step — carrying qsig, probe, dedup-wrapped OpSpec, and the
        engine-choice routing bit — and expects the executor to
        ``send`` back the output rows; table steps run inline.
        Returns (via StopIteration.value) the final Table.  Both
        executors drive this one generator: ``run()`` serially, and
        ``Scheduler.run_queries`` interleaving many tenants' plans
        concurrently.
        """
        n_probe = max(64, self.session.calib_rows + self.session.eval_rows)
        return PHYS.execute(self.physical_plan(), n_probe=n_probe)

    def _log_prefix_savings(self, engine, kind: str, hits0: int,
                            saved0: int) -> None:
        """Pooled engines persist across queries, so savings are logged
        as deltas over this operator, not lifetime engine totals."""
        st = getattr(engine, "stats", None)
        if st is None:
            return
        hits = getattr(st, "prefix_hits", 0) - hits0
        saved = getattr(st, "prefill_tokens_saved", 0) - saved0
        if hits > 0:
            # the compressed variant's prefix entries are keyed by
            # engine.version, so a recompression never reuses stale
            # prefix state — hits here are same-version by construction
            self.session.log.append(
                f"[prefix] {kind}: {hits} rows seeded from shared "
                f"prefix, {saved} prefill tokens saved "
                f"(v={engine.version})")

    def _run_cascade(self, op) -> List[str]:
        """One cascade op: every row through the instance-optimized
        proxy, rows below the fitted confidence threshold re-submitted
        to the base engine.  Escalated rows are answered by the same
        greedy base decode a base-only run would use, so their outputs
        are byte-identical; with an unsatisfiable budget (threshold =
        inf) the proxy pass is skipped entirely and the op degenerates
        to base-only."""
        sess = self.session
        spec = op.spec
        budget = op.op.accuracy_budget or 0.0
        cal = sess._cascade(op.qsig, op.probe, budget,
                            max_new=spec.max_new)
        prompts = list(spec.prompts)
        if not math.isfinite(cal.threshold):
            outs = OPS._invoke(sess.base_engine(), prompts,
                               max_new=spec.max_new, prefix=spec.prefix)
            self.last_run_stats.append(OpRunStats(
                kind=spec.kind, qsig=op.qsig, invocations=len(outs),
                engine="cascade", escalated=len(outs),
                threshold=cal.threshold))
            return outs
        proxy = sess.optimized_engine(op.qsig, op.probe)
        reqs = proxy.generate_stream(prompts, max_new=spec.max_new,
                                     prefix=spec.prefix,
                                     return_requests=True)
        outs = [r.text for r in reqs]
        reject = [i for i, r in enumerate(reqs)
                  if r.confidence < cal.threshold]
        if reject:
            fixed = OPS._invoke(sess.base_engine(),
                                [prompts[i] for i in reject],
                                max_new=spec.max_new, prefix=spec.prefix)
            for i, o in zip(reject, fixed):
                outs[i] = o
        self.last_run_stats.append(OpRunStats(
            kind=spec.kind, qsig=op.qsig, invocations=len(prompts),
            engine="cascade", escalated=len(reject),
            threshold=cal.threshold))
        return outs

    def run(self) -> Table:
        """Serial execution: drive the plan coroutine op by op through
        the session's engines (pooled when the session has a
        ModelPool, private otherwise)."""
        gen = self._ops()
        send = None
        self.last_run_stats = []
        while True:
            try:
                op = gen.send(send)
            except StopIteration as stop:
                return stop.value
            if op.op.engine == "cascade":
                send = self._run_cascade(op)
                continue
            engine = (self.session.optimized_engine(op.qsig, op.probe)
                      if op.optimize else self.session.base_engine())
            st = getattr(engine, "stats", None)
            hits0 = getattr(st, "prefix_hits", 0) if st else 0
            saved0 = getattr(st, "prefill_tokens_saved", 0) if st else 0
            spec = op.spec
            send = OPS._invoke(engine, spec.prompts, max_new=spec.max_new,
                               prefix=spec.prefix)
            self.last_run_stats.append(
                OpRunStats(kind=spec.kind, qsig=op.qsig,
                           invocations=len(send), engine=op.op.engine))
            self._log_prefix_savings(engine, spec.kind, hits0, saved0)

    # -- JSON round-trip ------------------------------------------------
    def to_spec(self) -> Dict[str, Any]:
        """The query as a JSON-serializable spec dict — the wire format
        of the always-on service (repro/service): inline table data,
        one entry per plan node (scan-first order), plus the query-
        level routing flags.  ``query_from_spec(spec, session)``
        rebuilds an equivalent ``Query``; the round-trip is exact for
        every builder surface except opaque Python callables —
        ``filter()`` predicates must be ``PLAN.ColumnPredicate`` and
        ``llm_filter`` must use the default ``keep`` parser.  Raises
        ``ValueError`` on a non-serializable plan (an opaque callable,
        or an optimizer-annotated node that only the rewriter emits).
        """
        nodes = PLAN.chain(self._root)[::-1]        # scan first
        scan = nodes[0]
        ops: List[Dict[str, Any]] = []
        for n in nodes[1:]:
            if n.kind == "map":
                ops.append({"op": "llm_map", "col": n.col,
                            "prompt": n.prompt, "out_col": n.out_col,
                            "max_new": n.max_new,
                            "accuracy_budget": n.accuracy_budget})
            elif n.kind == "correct":
                ops.append({"op": "llm_correct", "col": n.col,
                            "prompt": n.prompt, "out_col": n.out_col,
                            "max_new": n.max_new,
                            "accuracy_budget": n.accuracy_budget})
            elif n.kind == "llm_filter":
                if n.keep is not PLAN.default_keep:
                    raise ValueError(
                        "to_spec: llm_filter with a custom keep= "
                        "callable is not JSON-serializable")
                ops.append({"op": "llm_filter", "col": n.col,
                            "prompt": n.prompt, "max_new": n.max_new,
                            "accuracy_budget": n.accuracy_budget})
            elif n.kind == "join":
                ops.append({"op": "llm_join",
                            "right": dict(n.right.columns),
                            "on": list(n.on), "prompt": n.prompt,
                            "max_new": n.max_new,
                            "accuracy_budget": n.accuracy_budget})
            elif n.kind == "filter":
                if not isinstance(n.pred, PLAN.ColumnPredicate):
                    raise ValueError(
                        "to_spec: filter() with an opaque callable is "
                        "not JSON-serializable — use "
                        "plan.ColumnPredicate")
                ops.append({"op": "filter",
                            "pred": n.pred.to_dict()})
            elif n.kind == "select":
                ops.append({"op": "select", "cols": list(n.cols)})
            else:
                raise ValueError(
                    f"to_spec: node kind {n.kind!r} has no wire form "
                    "(optimizer-annotated plans are not serializable; "
                    "serialize the builder-level plan)")
        return {"version": 1,
                "table": {"columns": dict(scan.table.columns)},
                "ops": ops,
                "optimize": self.optimize,
                "optimize_plan": self.optimize_plan,
                "cascade_budget": self.cascade_budget,
                "cascade": self.cascade}


def query_from_spec(spec: Dict[str, Any],
                    session: IOLMSession) -> Query:
    """Rebuild a ``Query`` from its ``to_spec()`` wire form (the
    service's request body).  Strict: unknown spec versions, op names,
    or missing fields raise ``ValueError``/``KeyError`` so a malformed
    request fails at admission, not mid-plan."""
    if spec.get("version") != 1:
        raise ValueError(
            f"unsupported query spec version {spec.get('version')!r}")
    table = Table({k: list(v)
                   for k, v in spec["table"]["columns"].items()})
    q = Query(table, session,
              optimize=bool(spec.get("optimize", True)),
              optimize_plan=bool(spec.get("optimize_plan", True)),
              cascade_budget=spec.get("cascade_budget"),
              cascade=spec.get("cascade", "auto"))
    for o in spec.get("ops", []):
        kind = o.get("op")
        if kind == "llm_map":
            q.llm_map(o["col"], prompt=o["prompt"],
                      out_col=o.get("out_col", "summary"),
                      max_new=int(o.get("max_new", 24)),
                      accuracy_budget=o.get("accuracy_budget"))
        elif kind == "llm_correct":
            q.llm_correct(o["col"], prompt=o["prompt"],
                          out_col=o.get("out_col"),
                          max_new=int(o.get("max_new", 16)),
                          accuracy_budget=o.get("accuracy_budget"))
        elif kind == "llm_filter":
            q.llm_filter(o["col"], prompt=o["prompt"],
                         max_new=int(o.get("max_new", 8)),
                         accuracy_budget=o.get("accuracy_budget"))
        elif kind == "llm_join":
            q.llm_join(Table({k: list(v)
                              for k, v in o["right"].items()}),
                       tuple(o["on"]), prompt=o["prompt"],
                       max_new=int(o.get("max_new", 12)),
                       accuracy_budget=o.get("accuracy_budget"))
        elif kind == "filter":
            pred = PLAN.ColumnPredicate.from_dict(o["pred"])
            q.filter(pred, columns=(pred.col,))
        elif kind == "select":
            q.select(o["cols"])
        else:
            raise ValueError(f"unknown query spec op {kind!r}")
    return q
