"""Rule-based semantic query optimizer over the logical plan IR.

Three rewrite rules, each of which provably preserves query output
byte-for-byte (greedy decode is deterministic per prompt, so any
rewrite that keeps the per-row (prompt -> output) mapping and the
final row set/order unchanged is an identity on results):

``pushdown``
    Move a non-LLM ``Filter`` below an adjacent LLM op so the model
    never sees rows the filter would discard.  Legal below row-wise
    column-adding ops (map/correct/fused) only when the filter's
    declared read set is disjoint from the op's output columns;
    always legal below ``LLMFilter`` (two filters commute — the final
    row set is the intersection either way).  Never crosses a join
    (row identity changes).

``dedup``
    Annotate a row-wise LLM op with ``dedup=True``: the physical plan
    invokes the model once per *unique* input value and scatters the
    outputs back to rows.  Fires when the Scan column feeding the op
    has duplicate values (for optimizer-derived columns the unique
    count is unknown, so the rule stays off and the engine's result
    cache picks up residual duplicates at runtime).

``fusion``
    Collapse adjacent row-wise LLM ops reading the same column through
    the *identical* prompt template into one ``LLMFused`` pass that
    writes every output column.  Template equality is the guard that
    keeps outputs byte-identical — fusing different templates into one
    prompt would change what the model sees.

Rule order is driven by the cost model, not a fixed sequence: each
step evaluates every applicable rewrite, scores the rewritten plan by
``sum(est_rows x prompt_tokens)`` over its LLM nodes, and applies the
cheapest strictly-improving candidate (ties break on rule priority,
then textual description — fully deterministic).  Costs are integers
and every firing strictly decreases total cost, so the loop
terminates.

Every applied rewrite is additionally re-proved by the independent
plan verifier (olap/analysis.py): ``optimize`` hands the before/after
plans to ``verify_rewrite``, which derives the rule's legality
conditions from the evidence rather than trusting the guard that
fired.  A failed obligation raises ``PlanVerificationError`` with
structured diagnostics (stable ``PLAN0xx`` codes) — a buggy rule can
never silently ship a semantics-changing plan.  ``RuleFiring.verified``
records the proof and surfaces as a per-rule badge in
``Query.explain()``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from repro.olap import analysis as ANA
from repro.olap import plan as P

# Deterministic planning knobs: a non-LLM filter and a semantic filter
# are both assumed to keep half their input; a fuzzy join's blocker is
# assumed to emit ~2 candidates per left row (matches _block_key's
# behavior on the paper workloads).
FILTER_SELECTIVITY = 0.5
JOIN_FANOUT = 2
DEFAULT_VALUE_TOKENS = 32   # derived columns: value length unknown
SAMPLE = 64                 # rows sampled for column statistics

# --- cascade cost model (olap/physical.py reads these) ---------------------
# A cascade runs EVERY row through the instance-optimized proxy and
# re-submits only low-confidence rows to the base model, so its cost is
#   est_escalation * base_cost + CASCADE_PROXY_COST_FACTOR * base_cost
# and the planner picks engine="cascade" exactly when that beats
# base_cost alone, i.e. est_escalation + proxy_factor < 1.  The proxy
# factor is the compressed model's relative per-row cost (quantized
# weights, smaller matmuls; benchmarks/engine.py supports ~4x).
CASCADE_PROXY_COST_FACTOR = 0.25


def predicted_escalation(accuracy_budget: Optional[float]) -> float:
    """Planner-side prior on the cascade escalation rate for a given
    accuracy budget, used BEFORE any threshold is fit (the fitted rate
    from ``core.calibrate.fit_confidence_threshold`` replaces it at run
    time).  Monotone: a tighter budget accepts fewer proxy answers, so
    more rows escalate; budget 0 (or None) escalates everything — the
    cascade degenerates to base-only and the cost inequality can never
    choose it."""
    if accuracy_budget is None or accuracy_budget <= 0.0:
        return 1.0
    return min(1.0, 0.05 + 0.05 / accuracy_budget)


def cascade_wins(accuracy_budget: Optional[float]) -> bool:
    """The cost inequality ``esc * base + proxy < base`` with both sides
    normalized by base_cost (per-row costs cancel)."""
    return (predicted_escalation(accuracy_budget)
            + CASCADE_PROXY_COST_FACTOR < 1.0)


@dataclass
class ColStats:
    avg_tokens: int          # mean value length (byte tokenizer: 1/char)
    unique_frac: float       # |unique| / |rows| over the sample


@dataclass
class NodeEst:
    rows_in: int
    rows_out: int
    prompt_tokens: int = 0   # per-invocation prompt size (LLM nodes)
    invocations: int = 0     # model calls this node will make
    cost: int = 0            # invocations x prompt_tokens


@dataclass
class RuleFiring:
    rule: str
    desc: str
    cost_before: int
    cost_after: int
    # True when the independent verifier re-proved this rewrite's
    # legality from the before/after plans (olap/analysis.py)
    verified: bool = False


def column_stats(table) -> Dict[str, ColStats]:
    """Per-column stats from the (materialized) Scan table."""
    out = {}
    for name, vals in table.columns.items():
        sample = [str(v) for v in vals[:SAMPLE]]
        if not sample:
            out[name] = ColStats(DEFAULT_VALUE_TOKENS, 1.0)
            continue
        avg = max(1, round(sum(len(s) for s in sample) / len(sample)))
        uniq = len(set(sample)) / len(sample)
        out[name] = ColStats(avg, uniq)
    return out


def estimate(plan: P.PlanNode,
             stats: Optional[Dict[str, ColStats]] = None
             ) -> Dict[int, NodeEst]:
    """Bottom-up cardinality + cost estimates, keyed by ``id(node)``.

    Row counts: Scan is exact; each (LLM)Filter keeps
    ``FILTER_SELECTIVITY``; map/correct/fused/select preserve rows;
    join emits one row per estimated candidate match.  LLM cost is
    ``invocations x prompt_tokens`` with invocations reduced to the
    estimated unique count when the node is dedup-annotated.
    """
    if stats is None:
        stats = column_stats(P.scan_of(plan).table)
    est: Dict[int, NodeEst] = {}
    for node in reversed(P.chain(plan)):
        if isinstance(node, P.Scan):
            n = len(node.table)
            est[id(node)] = NodeEst(rows_in=n, rows_out=n)
            continue
        rows = est[id(node.child)].rows_out
        if isinstance(node, (P.Filter,)):
            est[id(node)] = NodeEst(rows, math.ceil(rows *
                                                    FILTER_SELECTIVITY))
            continue
        if isinstance(node, P.Select):
            est[id(node)] = NodeEst(rows, rows)
            continue
        # LLM nodes
        col = getattr(node, "col", None) or node.on[0]
        cs = stats.get(col, ColStats(DEFAULT_VALUE_TOKENS, 1.0))
        ptoks = len(node.prompt) + cs.avg_tokens
        if isinstance(node, P.LLMJoin):
            inv = rows * JOIN_FANOUT
            rows_out = rows      # ~one surviving match per left row
        else:
            inv = rows
            if getattr(node, "dedup", False):
                inv = min(inv, max(1, math.ceil(rows * cs.unique_frac)))
            rows_out = (math.ceil(rows * FILTER_SELECTIVITY)
                        if isinstance(node, P.LLMFilter) else rows)
        est[id(node)] = NodeEst(rows, rows_out, ptoks, inv, inv * ptoks)
    return est


def total_cost(plan: P.PlanNode,
               stats: Optional[Dict[str, ColStats]] = None) -> int:
    return sum(e.cost for e in estimate(plan, stats).values())


# ---------------------------------------------------------------------------
# rules — each returns every applicable (description, rewritten plan)
# ---------------------------------------------------------------------------

def _rule_pushdown(plan: P.PlanNode) -> List[Tuple[str, P.PlanNode]]:
    out = []
    nodes = P.chain(plan)
    for i, node in enumerate(nodes):
        if not isinstance(node, P.Filter):
            continue
        below = node.child
        if below is None or not P.is_llm(below):
            continue
        if below.kind == "join":
            continue            # join rewrites row identity: never cross
        adds = P.added_cols(below)
        if adds and (node.columns is None
                     or (set(node.columns) & set(adds))):
            continue            # pred might (or does) read the op's output
        swapped = P.with_child(below,
                               P.with_child(node, below.child))
        out.append((f"{P.describe(node)} below {P.describe(below)}",
                    P.rebuild(nodes[:i] + [swapped])))
    return out


def _rule_dedup(plan: P.PlanNode,
                stats: Dict[str, ColStats]) -> List[Tuple[str, P.PlanNode]]:
    out = []
    nodes = P.chain(plan)
    for i, node in enumerate(nodes):
        if node.kind not in P.ROWWISE_LLM_KINDS or node.dedup:
            continue
        # a column (re)written by any op below this one is derived —
        # even when its name shadows a Scan column, the Scan stats no
        # longer describe the values this op will read
        derived = {c for below in nodes[i + 1:]
                   for c in P.added_cols(below)}
        cs = stats.get(node.col)
        if node.col in derived or cs is None or cs.unique_frac >= 1.0:
            continue            # derived column or no duplicates: no win
        out.append((f"unique inputs only for {P.describe(node)}",
                    P.rebuild(nodes[:i] + [replace(node, dedup=True)]
                              + nodes[i + 1:])))
    return out


def _src_kind(node: P.PlanNode) -> Optional[str]:
    """The fusable constituent kind, or None when the node cannot
    fuse.  Like-kinded only: the fused node must keep its
    constituents' model-cache signature (plan.qsig), which hashes the
    kind — merging a map with a correct would have to pick one and
    fork the other's cache."""
    if node.kind in ("map", "correct"):
        return node.kind
    if node.kind == "fused":
        return node.src_kind
    return None


def _outs(node: P.PlanNode) -> Tuple[str, ...]:
    return P.added_cols(node)


def _rule_fusion(plan: P.PlanNode) -> List[Tuple[str, P.PlanNode]]:
    out = []
    nodes = P.chain(plan)
    for i, node in enumerate(nodes):
        below = node.child
        if below is None:
            continue
        kind = _src_kind(node)
        if kind is None or kind != _src_kind(below):
            continue
        same = (node.col == below.col and node.prompt == below.prompt
                and node.max_new == below.max_new
                and node.accuracy_budget == below.accuracy_budget)
        # the upper op must read the ORIGINAL column, not the lower
        # op's freshly-written output.  Differing accuracy budgets must
        # not fuse either: one fused pass has one cascade threshold,
        # which would loosen the stricter constituent's contract.
        if not same or node.col in _outs(below):
            continue
        fused = P.LLMFused(input=below.child, col=node.col,
                           prompt=node.prompt,
                           outs=_outs(below) + _outs(node),
                           max_new=node.max_new, src_kind=kind,
                           dedup=node.dedup or below.dedup,
                           accuracy_budget=node.accuracy_budget)
        out.append((f"{P.describe(below)} + {P.describe(node)}",
                    P.rebuild(nodes[:i] + [fused])))
    return out


RULES = (
    ("pushdown", lambda plan, stats: _rule_pushdown(plan)),
    ("fusion", lambda plan, stats: _rule_fusion(plan)),
    ("dedup", _rule_dedup),
)


def optimize(plan: P.PlanNode,
             stats: Optional[Dict[str, ColStats]] = None,
             *, verify: bool = True
             ) -> Tuple[P.PlanNode, List[RuleFiring]]:
    """Cost-driven greedy rewriting to a fixpoint.

    Every step scores all applicable rewrites from all rules and
    applies the one with the lowest resulting total cost; candidates
    that do not strictly improve are discarded, so the (integer) cost
    strictly decreases and the loop terminates.  Deterministic: ties
    break on rule priority order, then description.

    With ``verify`` on (the default, and what every production caller
    uses) each applied rewrite is independently re-proved by
    ``analysis.verify_rewrite`` before it replaces the plan; a failed
    proof obligation raises ``PlanVerificationError``.  ``verify=False``
    exists only so the verifier's own tests can feed it known-illegal
    rewrites.
    """
    if stats is None:
        stats = column_stats(P.scan_of(plan).table)
    firings: List[RuleFiring] = []
    while True:
        cur = total_cost(plan, stats)
        best = None
        for prio, (name, rule) in enumerate(RULES):
            for desc, cand in rule(plan, stats):
                c = total_cost(cand, stats)
                if c >= cur:
                    continue
                key = (c, prio, desc)
                if best is None or key < best[0]:
                    best = (key, name, desc, cand, c)
        if best is None:
            return plan, firings
        _, name, desc, cand, c = best
        if verify:
            diags = [d for d in ANA.verify_rewrite(plan, cand, name)
                     if d.severity == "error"]
            if diags:
                raise ANA.PlanVerificationError(diags)
        plan = cand
        firings.append(RuleFiring(name, desc, cur, c, verified=verify))
