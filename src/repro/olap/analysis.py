"""Static plan verifier: independent proof obligations for rewrites.

The optimizer's rules (olap/optimizer.py) each carry a rule-local
legality argument.  This module re-proves that argument from the
*evidence* — the before/after plans — without trusting the rule that
fired, so a bug in a rule's guard (or a hand-mutated plan) surfaces as
a structured ``Diagnostic`` at plan time instead of wrong rows at
execution time.

Two entry points:

``verify_plan(plan)``
    Full schema/column-flow inference over the IR (independent of
    ``plan.schema_at`` — this module derives schemas itself) plus the
    standing invariants of optimizer annotations: every read resolves,
    dedup only on row-wise ops over pristine Scan columns that
    actually contain duplicates, fused nodes structurally sound.

``verify_rewrite(before, after, rule)``
    Proof obligations for one rewrite step.  The changed window of the
    chain is recovered by diffing node signatures (nodes are
    reconstructed by rebinding ``input``, so signatures exclude it),
    then the window must match the claimed rule's shape AND satisfy
    the rule's legality conditions re-derived from scratch:
    read-set/output-column disjointness for pushdown, cardinality and
    pristine-column invariants for dedup, byte-identical templates and
    dependency-freedom for fusion.  Every rewrite additionally
    preserves the output schema and the scan table.

``optimize(..., verify=True)`` runs ``verify_rewrite`` after every
firing (always-on), and ``physical.lower`` runs ``verify_plan`` on the
optimized plan; failures raise ``PlanVerificationError`` carrying the
diagnostics.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import FrozenSet, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, render_text
from repro.olap import plan as P


class PlanVerificationError(ValueError):
    """An illegal plan or rewrite, with the proof that it is illegal."""

    def __init__(self, diagnostics: List[Diagnostic]):
        self.diagnostics = list(diagnostics)
        super().__init__("plan verification failed:\n"
                         + render_text(self.diagnostics))


# ---------------------------------------------------------------------------
# independent schema / column-flow inference
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NodeFlow:
    """Column flow at one node, derived from the IR alone.

    ``reads is None`` means the node's read set is unknowable (an
    opaque non-LLM filter); ``row_effect`` classifies what the node
    does to the row set: ``source`` (Scan), ``preserve`` (1:1),
    ``subset`` (filters), ``rewrite`` (join — row identity changes).
    """
    node: P.PlanNode
    schema_in: FrozenSet[str]
    schema_out: FrozenSet[str]
    reads: Optional[FrozenSet[str]]
    writes: FrozenSet[str]
    row_effect: str


def infer_flow(plan: P.PlanNode) -> List[NodeFlow]:
    """Scan-first column-flow inference over the chain."""
    flows: List[NodeFlow] = []
    schema: FrozenSet[str] = frozenset()
    for node in reversed(P.chain(plan)):
        schema_in = schema
        if isinstance(node, P.Scan):
            schema = frozenset(node.table.columns)
            flows.append(NodeFlow(node, frozenset(), schema, frozenset(),
                                  schema, "source"))
            continue
        if isinstance(node, P.Filter):
            # columns is typed FrozenSet but hand-built plans pass any
            # iterable; normalize so set algebra below is total
            reads = (None if node.columns is None
                     else frozenset(node.columns))
            flows.append(NodeFlow(node, schema_in, schema_in, reads,
                                  frozenset(), "subset"))
            continue
        if isinstance(node, P.Select):
            schema = frozenset(node.cols)
            flows.append(NodeFlow(node, schema_in, schema,
                                  frozenset(node.cols), frozenset(),
                                  "preserve"))
            continue
        if isinstance(node, P.LLMJoin):
            schema = (frozenset(f"l_{c}" for c in schema_in)
                      | frozenset(f"r_{c}" for c in node.right.columns))
            flows.append(NodeFlow(node, schema_in, schema,
                                  frozenset((node.on[0],)), schema,
                                  "rewrite"))
            continue
        # row-wise LLM ops: map / correct / llm_filter / fused
        writes = frozenset(P.added_cols(node))
        schema = schema_in | writes
        effect = "subset" if isinstance(node, P.LLMFilter) else "preserve"
        flows.append(NodeFlow(node, schema_in, schema,
                              frozenset((node.col,)), writes, effect))
    return flows


def output_schema(plan: P.PlanNode) -> FrozenSet[str]:
    return infer_flow(plan)[-1].schema_out


# ---------------------------------------------------------------------------
# node signatures — structural equality modulo the ``input`` rebind
# ---------------------------------------------------------------------------

def node_sig(node: P.PlanNode) -> Tuple:
    """The node's identity with its child excluded: rewrites rebuild
    chains by rebinding ``input``, so two nodes are "the same node
    moved" iff their non-input fields are equal (callables compare by
    identity — rebuilds carry the original objects through)."""
    vals = tuple(getattr(node, f.name)
                 for f in dataclasses.fields(node) if f.name != "input")
    return (node.kind,) + vals


def _diff_window(before: P.PlanNode, after: P.PlanNode
                 ) -> Tuple[List[P.PlanNode], List[P.PlanNode]]:
    """The minimal changed windows of the two chains (root-first):
    strip the longest common signature prefix and suffix."""
    cb, ca = P.chain(before), P.chain(after)
    sb, sa = [node_sig(n) for n in cb], [node_sig(n) for n in ca]
    lo = 0
    while lo < min(len(sb), len(sa)) and sb[lo] == sa[lo]:
        lo += 1
    hi = 0
    while (hi < min(len(sb), len(sa)) - lo
           and sb[len(sb) - 1 - hi] == sa[len(sa) - 1 - hi]):
        hi += 1
    return cb[lo:len(cb) - hi], ca[lo:len(ca) - hi]


# ---------------------------------------------------------------------------
# standing plan invariants
# ---------------------------------------------------------------------------

def _scan_table(plan: P.PlanNode):
    leaf = P.chain(plan)[-1]
    return leaf.table if isinstance(leaf, P.Scan) else None


def _has_duplicates(values) -> bool:
    seen = set()
    for v in values:
        s = str(v)
        if s in seen:
            return True
        seen.add(s)
    return False


def verify_plan(plan: P.PlanNode) -> List[Diagnostic]:
    """Standing invariants any executable plan must satisfy."""
    diags: List[Diagnostic] = []
    leaf = P.chain(plan)[-1]
    if not isinstance(leaf, P.Scan):
        return [Diagnostic("PLAN003",
                           f"plan does not bottom out at a Scan: "
                           f"{type(leaf).__name__}",
                           "plan.chain")]
    flows = infer_flow(plan)
    writes_below: set = set()
    for flow in flows:
        node = flow.node
        where = P.describe(node)
        # every declared read must resolve in the input schema
        if flow.reads is not None and not isinstance(node, P.Scan):
            missing = sorted(flow.reads - flow.schema_in)
            if missing:
                diags.append(Diagnostic(
                    "PLAN004",
                    f"reads missing column(s) {missing}; available: "
                    f"{sorted(flow.schema_in)}", where,
                    hint="the rewrite moved this node above/below the "
                         "op that provides the column"))
        if isinstance(node, P.LLMJoin) and \
                node.on[1] not in node.right.columns:
            diags.append(Diagnostic(
                "PLAN004",
                f"join column {node.on[1]!r} not in right table "
                f"(has {sorted(node.right.columns)})", where))
        # dedup annotations: row-wise, pristine scan column, duplicates
        if getattr(node, "dedup", False):
            diags.extend(_check_dedup_node(node, writes_below, leaf.table,
                                           where))
        if isinstance(node, P.LLMFused):
            diags.extend(_check_fused_node(node, where))
        writes_below |= set(flow.writes) if flow.row_effect != "source" \
            else set()
    return diags


def _check_dedup_node(node: P.PlanNode, writes_below: set, table,
                      where: str) -> List[Diagnostic]:
    diags = []
    if node.kind not in P.ROWWISE_LLM_KINDS:
        diags.append(Diagnostic(
            "PLAN020", f"dedup annotation on non-row-wise op "
            f"{node.kind!r}", where,
            hint="dedup's scatter only preserves outputs when each "
                 "row's result is a pure function of its value"))
        return diags
    if node.col in writes_below:
        diags.append(Diagnostic(
            "PLAN021",
            f"dedup reads {node.col!r}, which an op below (re)writes — "
            "the Scan column's value distribution no longer applies",
            where,
            hint="drop the annotation; the engine's result cache "
                 "picks up residual duplicates at runtime"))
    elif node.col not in table.columns:
        diags.append(Diagnostic(
            "PLAN021",
            f"dedup reads {node.col!r}, which is not a Scan column",
            where))
    elif not _has_duplicates(table.columns[node.col]):
        diags.append(Diagnostic(
            "PLAN022",
            f"dedup on {node.col!r}, but the column's values are all "
            "unique — the rewrite's cardinality premise is false",
            where,
            hint="the rule only fires when the Scan column has "
                 "duplicate values"))
    return diags


def _check_fused_node(node: P.LLMFused, where: str) -> List[Diagnostic]:
    diags = []
    if len(node.outs) < 2:
        diags.append(Diagnostic(
            "PLAN030", f"fused node writes {len(node.outs)} column(s); "
            "fusion merges at least two ops", where))
    if len(set(node.outs)) != len(node.outs):
        diags.append(Diagnostic(
            "PLAN030", f"fused node writes duplicate columns "
            f"{list(node.outs)}", where))
    if node.col in node.outs:
        diags.append(Diagnostic(
            "PLAN033",
            f"fused node reads {node.col!r} and also writes it — a "
            "constituent depended on another's output", where,
            hint="fusion is only byte-identical when every constituent "
                 "reads the original column"))
    if node.src_kind not in ("map", "correct"):
        diags.append(Diagnostic(
            "PLAN030", f"fused src_kind {node.src_kind!r} is not a "
            "fusable row-wise kind", where))
    return diags


# ---------------------------------------------------------------------------
# per-rewrite proof obligations
# ---------------------------------------------------------------------------

def verify_rewrite(before: P.PlanNode, after: P.PlanNode,
                   rule: str) -> List[Diagnostic]:
    """Re-prove one rewrite's legality from the before/after plans."""
    where = f"optimizer.{rule}"
    diags: List[Diagnostic] = []
    # generic obligations first — they hold for every rule
    if _scan_table(before) is not _scan_table(after):
        diags.append(Diagnostic(
            "PLAN002", "rewrite replaced the scan table", where))
    sb, sa = output_schema(before), output_schema(after)
    if sb != sa:
        diags.append(Diagnostic(
            "PLAN001",
            f"output schema changed: {sorted(sb)} -> {sorted(sa)}",
            where,
            hint="a legal rewrite removes/reorders/merges model "
                 "invocations; it never changes what columns come out"))
    diags.extend(verify_plan(after))
    checker = {"pushdown": _verify_pushdown, "dedup": _verify_dedup,
               "fusion": _verify_fusion}.get(rule)
    if checker is None:
        diags.append(Diagnostic(
            "PLAN099", f"no proof obligations registered for rule "
            f"{rule!r}", where,
            hint="add a checker in olap/analysis.py before shipping a "
                 "new rewrite rule"))
        return diags
    diags.extend(checker(before, after, where))
    return diags


def _verify_pushdown(before: P.PlanNode, after: P.PlanNode,
                     where: str) -> List[Diagnostic]:
    wb, wa = _diff_window(before, after)
    shape_ok = (len(wb) == 2 and len(wa) == 2
                and isinstance(wb[0], P.Filter)
                and node_sig(wb[0]) == node_sig(wa[1])
                and node_sig(wb[1]) == node_sig(wa[0]))
    if not shape_ok:
        return [Diagnostic(
            "PLAN010",
            f"changed window is not a filter/op swap: "
            f"{[n.kind for n in wb]} -> {[n.kind for n in wa]}", where)]
    filt, op = wb[0], wb[1]
    diags: List[Diagnostic] = []
    if not P.is_llm(op):
        # pushing below a non-LLM op never fires today; treat as a
        # shape violation so a rule drift is loud
        diags.append(Diagnostic(
            "PLAN010", f"filter crossed a non-LLM op {op.kind!r}",
            where))
        return diags
    if op.kind == "join":
        diags.append(Diagnostic(
            "PLAN011",
            "filter crossed a join — join output rows are not the "
            "filter's input rows (l_/r_ renaming, fanout)", where,
            hint="pushdown must stop above any join"))
        return diags
    adds = set(P.added_cols(op))
    if adds:
        if filt.columns is None:
            diags.append(Diagnostic(
                "PLAN013",
                f"filter with an undeclared read set crossed "
                f"{op.kind!r}, which adds columns {sorted(adds)} — the "
                "predicate might read them", where,
                hint="declare the filter's read set via "
                     "Query.filter(..., columns=[...])"))
        elif set(filt.columns) & adds:
            diags.append(Diagnostic(
                "PLAN012",
                f"filter reads {sorted(set(filt.columns) & adds)}, "
                f"which {op.kind!r} produces — below the op those "
                "values do not exist yet", where))
    return diags


def _verify_dedup(before: P.PlanNode, after: P.PlanNode,
                  where: str) -> List[Diagnostic]:
    wb, wa = _diff_window(before, after)
    def _undedup_sig(n):
        return node_sig(dataclasses.replace(n, dedup=False)) \
            if hasattr(n, "dedup") else node_sig(n)
    shape_ok = (len(wb) == 1 and len(wa) == 1
                and hasattr(wa[0], "dedup")
                and not getattr(wb[0], "dedup", False)
                and getattr(wa[0], "dedup", False)
                and _undedup_sig(wb[0]) == _undedup_sig(wa[0]))
    if not shape_ok:
        return [Diagnostic(
            "PLAN020",
            f"changed window is not a single dedup annotation: "
            f"{[n.kind for n in wb]} -> {[n.kind for n in wa]}", where)]
    # the annotation's own invariants (row-wise / pristine column /
    # actual duplicates) are re-derived by verify_plan(after), which
    # the caller always runs; nothing further to prove here
    return []


def _constituents(node: P.PlanNode) -> Optional[List[P.PlanNode]]:
    """A fusable node as its flat constituent list, or None."""
    if node.kind in ("map", "correct"):
        return [node]
    if node.kind == "fused":
        return [node]
    return None


def _verify_fusion(before: P.PlanNode, after: P.PlanNode,
                   where: str) -> List[Diagnostic]:
    wb, wa = _diff_window(before, after)
    if not (len(wa) == 1 and isinstance(wa[0], P.LLMFused)
            and len(wb) >= 2):
        return [Diagnostic(
            "PLAN030",
            f"changed window is not a many-to-one fuse: "
            f"{[n.kind for n in wb]} -> {[n.kind for n in wa]}", where)]
    fused = wa[0]
    parts: List[P.PlanNode] = []
    for n in wb:
        c = _constituents(n)
        if c is None:
            return [Diagnostic(
                "PLAN030", f"constituent {n.kind!r} is not a fusable "
                "row-wise op", where)]
        parts.extend(c)
    diags: List[Diagnostic] = []
    # (1) byte-identical templates: every constituent reads the same
    # column through the same prompt with the same decode budget, and
    # its kind matches the fused node's src_kind — re-derived from the
    # nodes themselves, not from the rule's guard
    for p in parts:
        kind = p.src_kind if p.kind == "fused" else p.kind
        if kind != fused.src_kind:
            diags.append(Diagnostic(
                "PLAN031",
                f"constituent kind {kind!r} != fused src_kind "
                f"{fused.src_kind!r} — fusing across kinds forks the "
                "model-cache signature", where))
        if p.prompt != fused.prompt:
            diags.append(Diagnostic(
                "PLAN031",
                f"constituent prompt {p.prompt!r} != fused prompt "
                f"{fused.prompt!r} — one model pass would change what "
                "the model sees", where,
                hint="fusion requires byte-equal templates"))
        if getattr(p, "col", None) != fused.col:
            diags.append(Diagnostic(
                "PLAN031",
                f"constituent reads {getattr(p, 'col', None)!r} but "
                f"the fused pass reads {fused.col!r}", where))
        if p.max_new != fused.max_new:
            diags.append(Diagnostic(
                "PLAN031",
                f"constituent max_new={p.max_new} != fused "
                f"max_new={fused.max_new}", where))
        if getattr(p, "accuracy_budget", None) != fused.accuracy_budget:
            diags.append(Diagnostic(
                "PLAN031",
                f"constituent accuracy_budget="
                f"{getattr(p, 'accuracy_budget', None)} != fused "
                f"accuracy_budget={fused.accuracy_budget} — one fused "
                "pass has one cascade threshold, which would loosen the "
                "stricter constituent's contract", where))
    # (2) output fan-out: the fused outs are exactly the constituents'
    # outs in execution (scan->root) order
    expect: Tuple[str, ...] = ()
    for p in reversed(parts):          # chain windows are root-first
        expect = expect + P.added_cols(p)
    if expect != tuple(fused.outs):
        diags.append(Diagnostic(
            "PLAN032",
            f"fused outs {list(fused.outs)} != constituents' outs "
            f"{list(expect)} in execution order", where))
    # (3) dependency freedom: no constituent may read another's output
    # (all read fused.col, so it must not be among the outs)
    if fused.col in expect:
        diags.append(Diagnostic(
            "PLAN033",
            f"a constituent writes the read column {fused.col!r}; the "
            "ops were data-dependent and cannot share one prompt "
            "stream", where))
    return diags
