"""LLM operators: first-class per-row model invocation inside queries.

The paper's three workloads as relational operators, plus a semantic
predicate:
  - ``llm_map``     (summarization): prompt per row -> new column
  - ``llm_correct`` (data correction): fix each value in a column
  - ``llm_join``    (fuzzy join): semantic row matching across tables
  - ``llm_filter``  (semantic predicate): keep rows the model affirms
  - ``fused_spec``  (optimizer-only): adjacent same-template ops
    merged into one model pass writing several columns

Each operator is built from an ``OpSpec``: a lazy prompt stream plus a
``finish`` closure that turns the model outputs back into a Table.
The split exists so two executors can drive the same operator:

  - the classic synchronous path (``llm_map``/``llm_correct``/
    ``llm_join``) funnels the spec through ``_invoke`` ->
    ``Engine.generate_stream``, which **streams** prompts into the
    engine's async core in bounded chunks (at most ``chunk``
    un-finished requests resident, so ``llm_join``'s O(n·k) candidate
    prompts never fully materialize);
  - the multi-tenant ``Scheduler`` (serving/scheduler.py) consumes the
    spec's prompt stream directly, interleaving many tenants' operators
    across pooled engines tick-by-tick.

Every operator renders rows through a fixed template, so the spec
carries the template as ``prefix`` — the engine prefills the shared
prefix once per (template, model version) and seeds each row's
KV/state from it (serving/cache.py PrefixCache).  Engines without the
async API (test fakes, remote backends) fall back to ``generate``.
Blocking for the fuzzy join keeps the candidate set O(n·k) instead of
O(n·m).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

from repro.olap.table import Table
from repro.serving.engine import DEFAULT_CHUNK, Engine
from repro.training.data import PROMPTS


@dataclass
class OpSpec:
    """One LLM operator, executor-agnostic: stream ``prompts`` through
    a model, then call ``finish(outs)`` for the result Table.  The
    prompt stream is lazy; ``finish`` must only run after every prompt
    has been consumed and answered (order-aligned with ``prompts``)."""
    kind: str
    prompts: Iterator[str]
    finish: Callable[[List[str]], Table]
    max_new: int
    prefix: Optional[str]


def _dedup_plan(values) -> Tuple[List[str], Callable[[List[str]], List[str]]]:
    """Unique stringified values in first-seen order, plus a scatter
    closure mapping per-unique outputs back to per-row outputs.
    Greedy decode is deterministic per prompt, so invoking once per
    unique value is byte-identical to invoking per row."""
    first: dict = {}
    order: List[str] = []
    idx_of: List[int] = []
    for v in values:
        s = str(v)
        if s not in first:
            first[s] = len(order)
            order.append(s)
        idx_of.append(first[s])
    return order, lambda uouts: [uouts[i] for i in idx_of]


def _rowwise_spec(kind: str, table: Table, col: str, prompt: str,
                  max_new: int, finish_rows: Callable[[List[str]], Table],
                  *, dedup: bool) -> OpSpec:
    """Shared shape of map/correct/llm_filter/fused: one prompt per row
    of ``col``, with an optional dedup wrapper (submit unique values
    only, scatter outputs back before ``finish_rows``)."""
    if dedup:
        uniq, scatter = _dedup_plan(table[col])
        return OpSpec(kind, (prompt + u for u in uniq),
                      lambda outs: finish_rows(scatter(outs)),
                      max_new, prompt)
    return OpSpec(kind, (prompt + str(v) for v in table[col]),
                  finish_rows, max_new, prompt)


def map_spec(table: Table, col: str, *, prompt: str = PROMPTS["summarize"],
             out_col: str = "summary", max_new: int = 24,
             dedup: bool = False) -> OpSpec:
    return _rowwise_spec("map", table, col, prompt, max_new,
                         lambda outs: table.with_column(out_col, outs),
                         dedup=dedup)


def correct_spec(table: Table, col: str, *, prompt: str = PROMPTS["correct"],
                 out_col: Optional[str] = None, max_new: int = 16,
                 dedup: bool = False) -> OpSpec:
    return _rowwise_spec("correct", table, col, prompt, max_new,
                         lambda outs: table.with_column(
                             out_col or col + "_fixed", outs),
                         dedup=dedup)


def filter_spec(table: Table, col: str, *, prompt: str, max_new: int = 8,
                keep: Optional[Callable[[str], bool]] = None,
                dedup: bool = False) -> OpSpec:
    """Semantic predicate: keep rows whose model output passes
    ``keep`` (default: affirmative prefix — yes/keep/same/true)."""
    from repro.olap.plan import default_keep
    keep = keep or default_keep

    def finish_rows(outs: List[str]) -> Table:
        return table.take([i for i, o in enumerate(outs) if keep(o)])

    return _rowwise_spec("llm_filter", table, col, prompt, max_new,
                         finish_rows, dedup=dedup)


def fused_spec(table: Table, col: str, *, prompt: str,
               outs: Tuple[str, ...], max_new: int,
               dedup: bool = False) -> OpSpec:
    """Fusion of adjacent same-(col, prompt) ops: one prompt stream,
    outputs fanned to every column in ``outs`` (original op order)."""
    def finish_rows(vals: List[str]) -> Table:
        t = table
        for o in outs:
            t = t.with_column(o, vals)
        return t

    return _rowwise_spec("fused", table, col, prompt, max_new,
                         finish_rows, dedup=dedup)


def join_spec(left: Table, right: Table, on: Tuple[str, str], *,
              prompt: str = PROMPTS["join"], max_new: int = 12,
              blocker: Optional[Callable[[str], str]] = None) -> OpSpec:
    """Fuzzy-join spec: candidate pairs are generated by a cheap
    blocking key, prompts stream lazily (``pairs`` fills as the
    executor consumes them), and ``finish`` assembles matched rows."""
    blocker = blocker or _block_key
    lcol, rcol = on
    blocks: dict = {}
    for j, v in enumerate(right[rcol]):
        blocks.setdefault(blocker(v), []).append(j)
    pairs: List[Tuple[int, int]] = []   # index pairs only — O(n·k) ints

    def candidate_prompts():
        for i, v in enumerate(left[lcol]):
            for j in blocks.get(blocker(v), []):
                pairs.append((i, j))
                yield f"{prompt}{left[lcol][i]} | {right[rcol][j]}"

    def finish(verdicts: List[str]) -> Table:
        matched = [(i, j) for (i, j), v in zip(pairs, verdicts)
                   if v.strip().startswith("same")]
        rows = []
        for i, j in matched:
            row = {f"l_{k}": v[i] for k, v in left.columns.items()}
            row.update({f"r_{k}": v[j] for k, v in right.columns.items()})
            rows.append(row)
        if not rows:
            cols = {f"l_{k}": [] for k in left.columns}
            cols.update({f"r_{k}": [] for k in right.columns})
            return Table(cols)
        return Table.from_rows(rows)

    return OpSpec("join", candidate_prompts(), finish, max_new, prompt)


def _invoke(engine: Engine, prompts: Iterable[str], *,
            max_new: int = 24, chunk: int = DEFAULT_CHUNK,
            prefix: Optional[str] = None) -> List[str]:
    """Stream ``prompts`` (any iterable, lazily consumed) through the
    engine; returns outputs in prompt order.  ``prefix`` is the shared
    template prefix every prompt starts with — the engine prefills it
    once and seeds each row's state from the cached prefix, so per-row
    prefill covers only the row suffix."""
    if not hasattr(engine, "generate_stream"):   # plain-generate fallback
        return engine.generate(list(prompts), max_new=max_new)
    return engine.generate_stream(prompts, max_new=max_new, chunk=chunk,
                                  prefix=prefix)


def run_spec(spec: OpSpec, engine: Engine, *,
             chunk: int = DEFAULT_CHUNK) -> Table:
    """Synchronous executor: stream the spec through one engine."""
    outs = _invoke(engine, spec.prompts, max_new=spec.max_new,
                   chunk=chunk, prefix=spec.prefix)
    return spec.finish(outs)


def llm_map(table: Table, col: str, engine: Engine, *,
            prompt: str = PROMPTS["summarize"], out_col: str = "summary",
            max_new: int = 24, chunk: int = DEFAULT_CHUNK) -> Table:
    """SELECT *, LLM('<prompt> ' || col) AS out_col FROM table"""
    return run_spec(map_spec(table, col, prompt=prompt, out_col=out_col,
                             max_new=max_new), engine, chunk=chunk)


def llm_correct(table: Table, col: str, engine: Engine, *,
                prompt: str = PROMPTS["correct"],
                out_col: Optional[str] = None,
                max_new: int = 16, chunk: int = DEFAULT_CHUNK) -> Table:
    """Per-row error correction of a column (typos, format drift)."""
    return run_spec(correct_spec(table, col, prompt=prompt, out_col=out_col,
                                 max_new=max_new), engine, chunk=chunk)


def llm_filter(table: Table, col: str, engine: Engine, *, prompt: str,
               max_new: int = 8,
               keep: Optional[Callable[[str], bool]] = None,
               chunk: int = DEFAULT_CHUNK) -> Table:
    """SELECT * FROM table WHERE LLM('<prompt> ' || col) ≈ 'yes'."""
    return run_spec(filter_spec(table, col, prompt=prompt, max_new=max_new,
                                keep=keep), engine, chunk=chunk)


def _block_key(v: str) -> str:
    s = "".join(ch for ch in str(v).lower() if ch.isalnum())
    return s[:1]


def llm_join(left: Table, right: Table, on: Tuple[str, str],
             engine: Engine, *, prompt: str = PROMPTS["join"],
             max_new: int = 12, chunk: int = DEFAULT_CHUNK,
             blocker: Callable[[str], str] = _block_key) -> Table:
    """Fuzzy (semantic) join: rows match when the model says 'same'.

    Candidate pairs are generated by a cheap blocking key first; the LLM
    adjudicates only within blocks (classic entity-resolution shape).
    Candidate prompts stream lazily into the engine, so peak prompt
    residency is bounded by ``chunk``, not by the O(n·k) pair count.
    """
    return run_spec(join_spec(left, right, on, prompt=prompt,
                              max_new=max_new, blocker=blocker),
                    engine, chunk=chunk)
