"""Declarative logical plan IR for OLAP queries with LLM operators.

A plan is an immutable chain of frozen dataclass nodes rooted at a
``Scan`` (the query API is linear, so every node is unary; ``LLMJoin``
carries its right table as a parameter, not a second child).  ``Query``
(olap/query.py) is a thin fluent builder over this IR; the optimizer
(olap/optimizer.py) rewrites plans by *reconstructing* chains — nodes
are never mutated in place, so a plan can be shared, cached, and
compared across rewrites safely.

Node zoo:

  ``Scan``        the input Table (leaf)
  ``Filter``      non-LLM predicate; ``columns`` is the declared read
                  set — declaring it is what licenses the optimizer to
                  push the filter below column-adding LLM ops
  ``Select``      column projection
  ``LLMMap``      prompt per row of ``col`` -> new column ``out_col``
  ``LLMCorrect``  fix each value of ``col`` -> ``out_col`` (default
                  ``col + "_fixed"``)
  ``LLMFilter``   semantic predicate: prompt per row, keep rows whose
                  model output passes ``keep``
  ``LLMJoin``     fuzzy join against ``right`` on ``on``
  ``LLMFused``    optimizer-only: adjacent same-(col, prompt) LLM ops
                  collapsed into one model pass writing every out col

``dedup`` on the per-row LLM nodes is a physical annotation set by the
optimizer's dedup rule: invoke the model once per *unique* input value
and scatter outputs back to rows (greedy decode is deterministic per
prompt, so outputs are byte-identical to the per-row path).

``accuracy_budget`` on LLM nodes opts the op into the **model
cascade** (olap/physical.py): the max fraction of rows that may be
answered by the instance-optimized proxy *and* disagree with the base
model.  ``None`` defers to the query-level default; ``0`` forces
base-only behavior.  The budget is NOT part of ``qsig`` — the same
proxy model serves every budget — and ``describe`` does not render it,
so logical-plan snapshots are budget-independent.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace
from typing import Callable, FrozenSet, List, Optional, Tuple

from repro.olap.table import Table


def default_keep(out: str) -> bool:
    """LLMFilter's default verdict parser: affirmative prefix."""
    return out.strip().lower().startswith(("yes", "keep", "same", "true"))


_PRED_OPS: dict = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "contains": lambda a, b: str(b) in str(a),
    "prefix": lambda a, b: str(a).startswith(str(b)),
}


@dataclass(frozen=True)
class ColumnPredicate:
    """A serializable single-column comparison for ``Filter`` nodes.

    Opaque Python callables cannot cross a process boundary, so query
    plans shipped to the service as JSON (query.Query.to_spec) express
    non-LLM filters with this declarative form instead: ``col <op>
    value`` where ``op`` is one of eq/ne/lt/le/gt/ge/contains/prefix.
    It is itself a callable row predicate, so the rest of the stack
    (Table.filter, the optimizer's pushdown rule) treats it exactly
    like a lambda — with the bonus that its read set is known, so the
    builder auto-declares ``columns={col}``.
    """
    col: str
    op: str
    value: object

    def __post_init__(self):
        if self.op not in _PRED_OPS:
            raise ValueError(
                f"unknown predicate op {self.op!r}; "
                f"expected one of {sorted(_PRED_OPS)}")

    def __call__(self, row: dict) -> bool:
        return bool(_PRED_OPS[self.op](row[self.col], self.value))

    def to_dict(self) -> dict:
        return {"col": self.col, "op": self.op, "value": self.value}

    @staticmethod
    def from_dict(d: dict) -> "ColumnPredicate":
        return ColumnPredicate(col=d["col"], op=d["op"], value=d["value"])


@dataclass(frozen=True)
class PlanNode:
    """Base class; every concrete node is a frozen dataclass."""

    kind: str = field(init=False, default="node", repr=False)

    @property
    def child(self) -> Optional["PlanNode"]:
        return getattr(self, "input", None)


@dataclass(frozen=True)
class Scan(PlanNode):
    table: Table
    name: str = "scan"
    kind = "scan"


@dataclass(frozen=True)
class Filter(PlanNode):
    input: PlanNode
    pred: Callable[[dict], bool]
    # Declared read set of ``pred``.  None means "unknown": the
    # optimizer then refuses to move this filter past any op that adds
    # columns (the pred might read them).
    columns: Optional[FrozenSet[str]] = None
    kind = "filter"


@dataclass(frozen=True)
class Select(PlanNode):
    input: PlanNode
    cols: Tuple[str, ...]
    kind = "select"


@dataclass(frozen=True)
class LLMMap(PlanNode):
    input: PlanNode
    col: str
    prompt: str
    out_col: str
    max_new: int
    dedup: bool = False
    accuracy_budget: Optional[float] = None
    kind = "map"


@dataclass(frozen=True)
class LLMCorrect(PlanNode):
    input: PlanNode
    col: str
    prompt: str
    out_col: Optional[str]
    max_new: int
    dedup: bool = False
    accuracy_budget: Optional[float] = None
    kind = "correct"

    @property
    def out(self) -> str:
        return self.out_col or self.col + "_fixed"


@dataclass(frozen=True)
class LLMFilter(PlanNode):
    input: PlanNode
    col: str
    prompt: str
    max_new: int
    keep: Callable[[str], bool] = default_keep
    dedup: bool = False
    accuracy_budget: Optional[float] = None
    kind = "llm_filter"


@dataclass(frozen=True)
class LLMJoin(PlanNode):
    input: PlanNode
    right: Table
    on: Tuple[str, str]
    prompt: str
    max_new: int
    accuracy_budget: Optional[float] = None
    kind = "join"


@dataclass(frozen=True)
class LLMFused(PlanNode):
    """Fusion result: one prompt stream over ``col``, outputs fanned to
    every column in ``outs`` (in original op order).  Only created by
    the optimizer when the fused ops' templates are identical, so the
    single model pass is byte-identical to running each op alone.
    ``src_kind`` is the constituents' kind (the fusion rule only
    merges like-kinded ops), preserved so the fused node keeps its
    constituents' model-cache signature."""
    input: PlanNode
    col: str
    prompt: str
    outs: Tuple[str, ...]
    max_new: int
    src_kind: str = "map"
    dedup: bool = False
    accuracy_budget: Optional[float] = None
    kind = "fused"


LLM_KINDS = ("map", "correct", "llm_filter", "join", "fused")
# per-row LLM ops: one prompt per input row, output depends only on
# that row's value — the set the dedup rule may annotate
ROWWISE_LLM_KINDS = ("map", "correct", "llm_filter", "fused")


def is_llm(node: PlanNode) -> bool:
    return node.kind in LLM_KINDS


def with_child(node: PlanNode, child: PlanNode) -> PlanNode:
    """Immutably rebind a node's input."""
    return replace(node, input=child)


def chain(plan: PlanNode) -> List[PlanNode]:
    """The plan as a list, root first, Scan last."""
    out = []
    n: Optional[PlanNode] = plan
    while n is not None:
        out.append(n)
        n = n.child
    return out


def scan_of(plan: PlanNode) -> Scan:
    leaf = chain(plan)[-1]
    if not isinstance(leaf, Scan):
        raise ValueError(f"plan does not bottom out at a Scan: {leaf!r}")
    return leaf


def rebuild(nodes: List[PlanNode]) -> PlanNode:
    """Re-chain a root-first node list (last node must be the Scan)."""
    plan = nodes[-1]
    for n in reversed(nodes[:-1]):
        plan = with_child(n, plan)
    return plan


def added_cols(node: PlanNode) -> Tuple[str, ...]:
    """Columns this node introduces (empty for row-set-only ops)."""
    if node.kind == "map":
        return (node.out_col,)
    if node.kind == "correct":
        return (node.out,)
    if node.kind == "fused":
        return tuple(node.outs)
    return ()


def schema_at(node: PlanNode) -> FrozenSet[str]:
    """Columns available *after* this node runs (exact: the Scan's
    table is materialized, and every op's schema effect is static)."""
    if isinstance(node, Scan):
        return frozenset(node.table.columns)
    below = schema_at(node.child)
    if isinstance(node, Select):
        return frozenset(node.cols)
    if isinstance(node, LLMJoin):
        right = frozenset(f"r_{c}" for c in node.right.columns)
        return frozenset(f"l_{c}" for c in below) | right
    return below | frozenset(added_cols(node))


def qsig(node: PlanNode) -> str:
    """Query signature keying the instance-optimized model: sha256 of
    (operator kind, prompt template).  ``LLMFused`` keeps the signature
    of its constituents (same kind and identical prompts by the fusion
    rule's guard), so fusion never forks the model cache."""
    kind = node.src_kind if node.kind == "fused" else node.kind
    kind = {"llm_filter": "filter"}.get(kind, kind)
    base = f"{kind}:{getattr(node, 'prompt', '')}"
    return hashlib.sha256(base.encode()).hexdigest()[:12]


def describe(node: PlanNode) -> str:
    """One-line node rendering (stable: used by EXPLAIN snapshots)."""
    if isinstance(node, Scan):
        cols = ", ".join(node.table.columns)
        return f"Scan[{node.name}, rows={len(node.table)}, cols=({cols})]"
    if isinstance(node, Filter):
        cols = ("?" if node.columns is None
                else ", ".join(sorted(node.columns)))
        return f"Filter[reads=({cols})]"
    if isinstance(node, Select):
        return f"Select[{', '.join(node.cols)}]"
    dedup = ", dedup" if getattr(node, "dedup", False) else ""
    if isinstance(node, LLMMap):
        return (f"LLMMap[{node.col} -> {node.out_col}, "
                f"prompt={node.prompt!r}{dedup}]")
    if isinstance(node, LLMCorrect):
        return (f"LLMCorrect[{node.col} -> {node.out}, "
                f"prompt={node.prompt!r}{dedup}]")
    if isinstance(node, LLMFilter):
        return f"LLMFilter[{node.col}, prompt={node.prompt!r}{dedup}]"
    if isinstance(node, LLMJoin):
        return (f"LLMJoin[{node.on[0]} ~ {node.on[1]}, "
                f"right_rows={len(node.right)}, prompt={node.prompt!r}]")
    if isinstance(node, LLMFused):
        return (f"LLMFused[{node.col} -> ({', '.join(node.outs)}), "
                f"prompt={node.prompt!r}{dedup}]")
    return repr(node)


def render(plan: PlanNode, *, annotate=None, indent: str = "  ") -> str:
    """Tree rendering, root at top.  ``annotate(node) -> str`` appends
    per-node detail (the optimizer passes cost estimates in)."""
    lines = []
    for depth, node in enumerate(chain(plan)):
        extra = f"  {annotate(node)}" if annotate else ""
        lines.append(f"{indent * depth}{describe(node)}{extra}")
    return "\n".join(lines)


def validate(plan: PlanNode) -> None:
    """Static checks a builder bug would trip: the chain bottoms out at
    a Scan and every LLM/Filter/Select input column exists in the
    schema below it."""
    for node in chain(plan):
        if isinstance(node, Scan):
            continue
        below = schema_at(node.child)
        need: Tuple[str, ...] = ()
        if node.kind in ("map", "correct", "llm_filter", "fused"):
            need = (node.col,)
        elif isinstance(node, Select):
            need = node.cols
        elif isinstance(node, Filter) and node.columns is not None:
            need = tuple(node.columns)
        elif isinstance(node, LLMJoin):
            need = (node.on[0],)
            if node.on[1] not in node.right.columns:
                raise ValueError(
                    f"join column {node.on[1]!r} not in right table "
                    f"(has {sorted(node.right.columns)})")
        missing = [c for c in need if c not in below]
        if missing:
            raise ValueError(
                f"{describe(node)} reads missing column(s) {missing}; "
                f"available: {sorted(below)}")
    scan_of(plan)
