"""Physical planner: lower an optimized logical plan to executable ops.

Lowering walks the (optimizer-rewritten) chain Scan -> root and emits
one physical step per node:

  - non-LLM nodes (``Filter``/``Select``) become ``TableStep``s — pure
    Table -> Table functions executed inline by whichever executor
    drives the plan;
  - LLM nodes become ``PhysicalOp``s annotated with everything an
    executor needs to route the work: the model-cache query signature
    ``qsig``, the **engine choice** (``"optimized"`` = run the
    instance-optimization workflow and serve from the compressed
    recipe, ``"base"`` = the uncompressed model), the **pool
    placement** (``"pool"`` when the session schedules engines through
    a shared byte-budgeted ``ModelPool``, ``"private"`` for a
    per-operator engine), the shared **prefix template**, and the
    dedup flag + cost estimate the optimizer attached.

Execution is a *generator protocol* shared by both executors (the
serial ``Query.run`` and the multi-tenant ``Scheduler.run_queries``):
``execute(pplan)`` yields one ``ExecutableOp`` per LLM step — probe
sample and dedup-wrapped ``OpSpec`` built against the table state at
that point — and expects the executor to ``send`` back the output rows
(one per spec prompt); the final Table travels out via
``StopIteration.value``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Union

from repro.kernels.backend import resolve_backend
from repro.olap import analysis as ANA
from repro.olap import operators as OPS
from repro.olap import optimizer as OPT
from repro.olap import plan as P
from repro.olap.table import Table


@dataclass
class TableStep:
    """A non-LLM step: pure table transform, runs inline."""
    node: P.PlanNode
    apply: Callable[[Table], Table]


@dataclass
class PhysicalOp:
    """Static annotation of one LLM step (what EXPLAIN renders)."""
    node: P.PlanNode
    qsig: str
    engine: str          # "optimized" | "base" | "cascade"
    backend: str         # resolved KernelBackend: "reference" | "pallas"
    placement: str       # "pool" | "private"
    prefix: str
    dedup: bool
    max_new: int
    est: OPT.NodeEst
    # cascade annotations (engine == "cascade"): the effective per-op
    # accuracy budget (node override, else the query-level default) and
    # the planner's escalation prior — the fitted threshold replaces it
    # at run time (core/calibrate.py fit_confidence_threshold)
    accuracy_budget: Optional[float] = None
    est_escalation: float = 1.0


@dataclass
class PhysicalPlan:
    logical: P.PlanNode              # the plan as built
    optimized: P.PlanNode            # after rule rewriting
    steps: List[Union[TableStep, PhysicalOp]]     # Scan -> root order
    firings: List[OPT.RuleFiring]
    est: Dict[int, OPT.NodeEst]      # id(node) -> estimate (optimized)
    logical_cost: int
    optimized_cost: int

    @property
    def llm_ops(self) -> List[PhysicalOp]:
        return [s for s in self.steps if isinstance(s, PhysicalOp)]


@dataclass
class ExecutableOp:
    """One LLM step, bound to the live table state: ready to route to
    an engine.  ``spec.prompts`` is the (dedup-wrapped) prompt stream;
    the executor sends the aligned outputs back into the generator."""
    qsig: str
    probe: List[str]
    spec: OPS.OpSpec
    optimize: bool       # engine choice as a routing bool
    op: PhysicalOp


def lower(logical: P.PlanNode, *, optimize_models: bool = True,
          pooled: bool = False, use_optimizer: bool = True,
          verify: bool = True, backend: str = "auto",
          cascade_budget: Optional[float] = None,
          cascade: str = "auto") -> PhysicalPlan:
    """plan -> verify -> optimize (each rewrite re-proved) -> verify ->
    physical steps.

    The two verifier passes are the execution-time firewall: a
    hand-mutated plan carrying an illegal optimizer annotation (a
    dedup over a derived column, a fused node whose constituents were
    data-dependent, ...) raises ``PlanVerificationError`` with stable
    ``PLAN0xx`` diagnostics *here*, instead of producing wrong rows
    from an engine later.

    Cascades: an LLM node whose effective accuracy budget (its own
    ``accuracy_budget``, else ``cascade_budget``) is positive may be
    annotated ``engine="cascade"`` — every row runs the
    instance-optimized proxy first and only low-confidence rows
    re-submit to the base model.  ``cascade="auto"`` applies the cost
    inequality ``est_escalation * base + proxy < base``
    (olap/optimizer.py); ``"force"`` cascades every budgeted op;
    ``"off"`` disables the strategy.  Requires ``optimize_models=True``
    (the proxy IS the instance-optimized model).
    """
    if cascade not in ("auto", "force", "off"):
        raise ValueError(f"cascade must be auto/force/off, got {cascade!r}")
    P.validate(logical)
    if verify:
        pre = [d for d in ANA.verify_plan(logical)
               if d.severity == "error"]
        if pre:
            raise ANA.PlanVerificationError(pre)
    stats = OPT.column_stats(P.scan_of(logical).table)
    logical_cost = OPT.total_cost(logical, stats)
    if use_optimizer:
        optimized, firings = OPT.optimize(logical, stats, verify=verify)
    else:
        optimized, firings = logical, []
    if verify:
        post = [d for d in ANA.verify_plan(optimized)
                if d.severity == "error"]
        if post:
            raise ANA.PlanVerificationError(post)
    est = OPT.estimate(optimized, stats)
    engine = "optimized" if optimize_models else "base"
    # "auto" resolves HERE (pallas on TPU, reference elsewhere) so
    # EXPLAIN shows the kernel backend each op will actually run on
    kbackend = resolve_backend(backend)
    placement = "pool" if pooled else "private"
    steps: List[Union[TableStep, PhysicalOp]] = []
    for node in reversed(P.chain(optimized)):
        if isinstance(node, P.Scan):
            continue
        if isinstance(node, P.Filter):
            steps.append(TableStep(node,
                                   lambda t, n=node: t.filter(n.pred)))
        elif isinstance(node, P.Select):
            steps.append(TableStep(node,
                                   lambda t, n=node: t.select(n.cols)))
        else:
            budget = getattr(node, "accuracy_budget", None)
            if budget is None:
                budget = cascade_budget
            node_engine, esc = engine, 1.0
            # "force" cascades every budgeted op — including budget 0,
            # where the threshold fits to inf and the op degenerates to
            # base-only at run time (the exactness contract); "auto"
            # only cascades when the cost inequality wins, which a
            # zero budget never does
            if (engine == "optimized" and cascade != "off"
                    and budget is not None
                    and (cascade == "force"
                         or (budget > 0 and OPT.cascade_wins(budget)))):
                node_engine = "cascade"
                esc = OPT.predicted_escalation(budget)
            steps.append(PhysicalOp(
                node=node, qsig=P.qsig(node), engine=node_engine,
                backend=kbackend, placement=placement, prefix=node.prompt,
                dedup=getattr(node, "dedup", False),
                max_new=node.max_new, est=est[id(node)],
                accuracy_budget=budget if node_engine == "cascade" else None,
                est_escalation=esc))
    return PhysicalPlan(logical=logical, optimized=optimized, steps=steps,
                        firings=firings, est=est,
                        logical_cost=logical_cost,
                        optimized_cost=sum(e.cost for e in est.values()))


def build_spec(node: P.PlanNode, t: Table) -> OPS.OpSpec:
    """The node's OpSpec against the live table state (dedup-wrapped
    when the optimizer annotated the node)."""
    dedup = getattr(node, "dedup", False)
    if isinstance(node, P.LLMMap):
        return OPS.map_spec(t, node.col, prompt=node.prompt,
                            out_col=node.out_col, max_new=node.max_new,
                            dedup=dedup)
    if isinstance(node, P.LLMCorrect):
        return OPS.correct_spec(t, node.col, prompt=node.prompt,
                                out_col=node.out_col, max_new=node.max_new,
                                dedup=dedup)
    if isinstance(node, P.LLMFilter):
        return OPS.filter_spec(t, node.col, prompt=node.prompt,
                               max_new=node.max_new, keep=node.keep,
                               dedup=dedup)
    if isinstance(node, P.LLMFused):
        return OPS.fused_spec(t, node.col, prompt=node.prompt,
                              outs=node.outs, max_new=node.max_new,
                              dedup=dedup)
    if isinstance(node, P.LLMJoin):
        return OPS.join_spec(t, node.right, node.on, prompt=node.prompt,
                             max_new=node.max_new)
    raise ValueError(f"not an LLM node: {node!r}")


def build_probe(node: P.PlanNode, t: Table, n_probe: int) -> List[str]:
    """Bounded calibration sample for the operator (the optimizer
    reads at most calib+eval rows and a 64-row data signature); the
    full column streams through the engine chunk-wise, never
    materialized as prompts here."""
    if isinstance(node, P.LLMJoin):
        # honor the caller's bound: ceil(n_probe/2) left values x 2
        # right values, capped at n_probe total — the cascade threshold
        # is fit on this probe, so a hardcoded slice would silently
        # ignore a caller asking for a larger (or smaller) fit sample
        n_left = max(1, -(-n_probe // 2))
        out = [f"{node.prompt}{a} | {b}"
               for a in t[node.on[0]][:n_left]
               for b in node.right[node.on[1]][:2]]
        return out[:n_probe]
    return [node.prompt + str(v) for v in t[node.col][:n_probe]]


def execute(pplan: PhysicalPlan, *, n_probe: int = 64):
    """The physical plan as a coroutine of LLM-operator submissions
    (see module docstring); both executors drive this one generator."""
    t = P.scan_of(pplan.optimized).table
    for step in pplan.steps:
        if isinstance(step, TableStep):
            t = step.apply(t)
            continue
        spec = build_spec(step.node, t)
        probe = build_probe(step.node, t, n_probe)
        outs = yield ExecutableOp(qsig=step.qsig, probe=probe, spec=spec,
                                  optimize=step.engine in ("optimized",
                                                           "cascade"),
                                  op=step)
        t = spec.finish(outs)
    return t
