"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention+MLP block.

Layout: ``n_layers`` block applications where every (shared_attn_every+1)-th
position applies the *same* transformer block (weight sharing across all
sites).  Execution scans over (K mamba + 1 shared-attn) groups; the shared
block's weights are closed over so every scan iteration reuses them —
remaining mamba layers are appended via a second scan.

Decode state: per-mamba-layer SSD/conv states + per-site KV caches for the
shared block (same weights, distinct activations per site).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import transformer as TF

Params = Dict[str, Any]


def layout(cfg):
    """(n_groups, group_k, n_tail_mamba, n_sites)."""
    k = cfg.shared_attn_every
    n_sites = cfg.n_layers // (k + 1)
    n_mamba = cfg.n_layers - n_sites
    n_groups = n_sites
    tail = n_mamba - n_groups * k
    return n_groups, k, tail, n_sites


def init_params(key, cfg) -> Params:
    dtype = cfg.dtype
    G, K, tail, _ = layout(cfg)
    k_emb, k_m, k_shared, k_tail, k_ln = jax.random.split(key, 5)
    params = L.init_embed(k_emb, cfg, dtype)
    grouped = jax.vmap(jax.vmap(lambda k: M.init_layer(k, cfg, dtype)))(
        jax.random.split(k_m, G * K).reshape(G, K, 2))
    params["mamba_groups"] = grouped            # leaves [G, K, ...]
    params["shared"] = TF.init_block(k_shared, cfg, dtype)
    params["mamba_tail"] = jax.vmap(lambda k: M.init_layer(k, cfg, dtype))(
        jax.random.split(k_tail, tail)) if tail else None
    params["ln_f"] = L.norm_init(cfg.d_model, dtype, cfg.norm_type)
    return params


# ---------------------------------------------------------------------------
# forward (train / no-cache)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg, tokens, *, train: bool = False,
            remat: bool = True, capture: bool = False, **_):
    x = L.embed(params, cfg, tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    G, K, tail, _ = layout(cfg)
    shared = params["shared"]

    def body(xc, group):
        cap = (xc,) if capture else ()
        for u in range(K):
            p = jax.tree.map(lambda a: a[u], group)
            xc, _ = M.block_apply(p, xc, cfg)
        xc, _ = TF.block_apply(shared, xc, cfg, kind="G", positions=positions,
                               train=train)
        xc = constrain(xc)
        return xc, (jnp.zeros((), jnp.float32), cap)

    sb = jax.checkpoint(body) if (remat and not capture) else body
    x, (auxs, caps) = jax.lax.scan(sb, x, params["mamba_groups"],
                                   unroll=cfg.scan_unroll)
    if params["mamba_tail"] is not None:
        def tbody(xc, p):
            xc, _ = M.block_apply(p, xc, cfg)
            return xc, None
        tb = jax.checkpoint(tbody) if (remat and not capture) else tbody
        x, _ = jax.lax.scan(tb, x, params["mamba_tail"],
                            unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    aux = {"moe_aux": jnp.zeros((), jnp.float32)}
    if capture:
        aux["captures"] = {"blocks": [caps[0]], "tail": []}
        aux["final_hidden"] = x
    return logits, aux


# ---------------------------------------------------------------------------
# cache / decode / prefill
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, **_):
    G, K, tail, n_sites = layout(cfg)
    dt = cfg.dtype
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    one = M.init_layer_state(cfg, batch, dt)
    grouped = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (G, K) + a.shape), one)
    kv = {"k": jnp.zeros((n_sites, batch, max_len, Kh, hd), dt),
          "v": jnp.zeros((n_sites, batch, max_len, Kh, hd), dt)}
    tail_states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (tail,) + a.shape), one) if tail else None
    return {"mamba_groups": grouped, "shared_kv": kv, "mamba_tail": tail_states}


def decode_step(params: Params, cfg, cache, tokens, pos, *, max_len: int):
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(params, cfg, tokens)
    G, K, tail, _ = layout(cfg)
    shared = params["shared"]

    def body(xc, xs):
        group, states, kv = xs
        xc, stacked = M.stack_apply(group, states, xc, cfg)
        xc, kv2 = TF.block_decode(shared, kv, xc, cfg, kind="G", pos=pos,
                                  max_len=max_len)
        return xc, (stacked, kv2)

    x, (mstates, kvs) = jax.lax.scan(
        body, x, (params["mamba_groups"], cache["mamba_groups"],
                  cache["shared_kv"]), unroll=cfg.scan_unroll)
    new_tail = cache["mamba_tail"]
    if params["mamba_tail"] is not None:
        def tbody(xc, xs):
            p, st = xs
            xc, st2 = M.block_apply(p, xc, cfg, state=st)
            return xc, st2
        x, new_tail = jax.lax.scan(tbody, x,
                                   (params["mamba_tail"], cache["mamba_tail"]),
                                   unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"mamba_groups": mstates, "shared_kv": kvs,
                    "mamba_tail": new_tail}


# ---------------------------------------------------------------------------
# paged serving state
# ---------------------------------------------------------------------------
#
# Only the shared attention sites hold positional KV — the mamba states
# are O(1) recurrent — so the paged layout pools just ``shared_kv``
# ([n_sites, num_blocks, bs, Kh, hd], every site indexed by the same
# block table) and keeps the recurrent states slot-stacked with the slot
# axis *inside* the group axes ([G, K, slots, ...]) so the decode scan
# over groups sees plain batched states.

def init_paged_cache(cfg, slots: int, num_blocks: int, block_size: int):
    G, K, tail, n_sites = layout(cfg)
    dt = cfg.dtype
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    one = M.init_layer_state(cfg, slots, dt)
    grouped = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (G, K) + a.shape), one)
    kv = {"k": jnp.zeros((n_sites, num_blocks, block_size, Kh, hd), dt),
          "v": jnp.zeros((n_sites, num_blocks, block_size, Kh, hd), dt)}
    tail_states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (tail,) + a.shape), one) if tail else None
    return {"mamba_groups": grouped, "shared_kv": kv,
            "mamba_tail": tail_states}


def _scatter_rows(pool, rows, slot_idxs, axis: int):
    """rows [n, ..., 1(batch), ...] -> pool with slot axis at ``axis``."""
    r = jnp.moveaxis(rows, 0, axis)
    r = r.reshape(r.shape[:axis + 1] + r.shape[axis + 2:])  # drop batch-1 axis
    idx = (slice(None),) * axis + (slot_idxs,)
    return pool.at[idx].set(r.astype(pool.dtype))


def paged_insert(cfg, state, rows, slot_idxs, write_ids, *, block_size: int):
    """Admit a vmapped prefill batch: recurrent states scatter into their
    slots, shared-site KV scatters into the pool blocks at ``write_ids``."""
    new = {
        "mamba_groups": jax.tree.map(
            lambda s, r: _scatter_rows(s, r, slot_idxs, 2),
            state["mamba_groups"], rows["mamba_groups"]),
        "shared_kv": TF.paged_write_blocks(
            state["shared_kv"], rows["shared_kv"], write_ids,
            block_size=block_size),
        "mamba_tail": state["mamba_tail"],
    }
    if state["mamba_tail"] is not None:
        new["mamba_tail"] = jax.tree.map(
            lambda s, r: _scatter_rows(s, r, slot_idxs, 1),
            state["mamba_tail"], rows["mamba_tail"])
    return new


def paged_seed(cfg, state, entry_state, write_ids, *, block_size: int):
    """Seed shared prefix blocks from a prefix-cache entry.  Only the
    attention KV is positional; the entry's recurrent states are consumed
    per-row by ``prefill_from`` instead."""
    rows = jax.tree.map(lambda a: a[None], entry_state["shared_kv"])
    kv = TF.paged_write_blocks(state["shared_kv"], rows, write_ids,
                               block_size=block_size)
    return {"mamba_groups": state["mamba_groups"], "shared_kv": kv,
            "mamba_tail": state["mamba_tail"]}


def paged_decode_step(params: Params, cfg, cache, tables, tokens, pos, *,
                      block_size: int, max_len: int,
                      backend: str = "reference"):
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(params, cfg, tokens)
    G, K, tail, _ = layout(cfg)
    shared = params["shared"]

    def body(xc, xs):
        group, states, kv = xs
        xc, stacked = M.stack_apply(group, states, xc, cfg)
        xc, kv2 = TF.paged_block_decode(shared, kv, xc, cfg, kind="G",
                                        pos=pos, tables=tables,
                                        block_size=block_size,
                                        max_len=max_len, backend=backend)
        return xc, (stacked, kv2)

    x, (mstates, kvs) = jax.lax.scan(
        body, x, (params["mamba_groups"], cache["mamba_groups"],
                  cache["shared_kv"]), unroll=cfg.scan_unroll)
    new_tail = cache["mamba_tail"]
    if params["mamba_tail"] is not None:
        def tbody(xc, xs):
            p, st = xs
            xc, st2 = M.block_apply(p, xc, cfg, state=st)
            return xc, st2
        x, new_tail = jax.lax.scan(tbody, x,
                                   (params["mamba_tail"], cache["mamba_tail"]),
                                   unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"mamba_groups": mstates, "shared_kv": kvs,
                    "mamba_tail": new_tail}


def prefill(params: Params, cfg, tokens, *, max_len: int, lengths=None, **_):
    x = L.embed(params, cfg, tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    G, K, tail, _ = layout(cfg)
    shared = params["shared"]
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def kv_entry(k, v):
        if S >= max_len:
            return {"k": k[:, S - max_len:], "v": v[:, S - max_len:]}
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}

    def shared_prefill(xc):
        h = L.norm(xc, shared["ln1"], cfg)
        q, k, v = L._qkv(shared["attn"], h, cfg, positions, cfg.rope_theta)
        out = L.best_attention(q, k, v, kind="G", cfg=cfg)
        a = L.matmul(out.reshape(B, S, -1), shared["attn"]["wo"])
        xc = xc + a
        h = L.norm(xc, shared["ln2"], cfg)
        xc = xc + L.mlp_block(shared["mlp"], h)
        return xc, kv_entry(k, v)

    def body(xc, xs):
        group, states = xs
        xc, stacked = M.stack_apply(group, states, xc, cfg, lengths=lengths)
        xc, kv = shared_prefill(xc)
        xc = constrain(xc)
        return xc, (stacked, kv)

    cache0 = init_cache(cfg, B, max_len)
    x, (mstates, kvs) = jax.lax.scan(
        jax.checkpoint(body), x, (params["mamba_groups"],
                                  cache0["mamba_groups"]),
        unroll=cfg.scan_unroll)
    new_tail = cache0["mamba_tail"]
    if params["mamba_tail"] is not None:
        def tbody(xc, xs):
            p, st = xs
            xc, st2 = M.block_apply(p, xc, cfg, state=st, lengths=lengths)
            return xc, st2
        x, new_tail = jax.lax.scan(jax.checkpoint(tbody), x,
                                   (params["mamba_tail"], cache0["mamba_tail"]),
                                   unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"mamba_groups": mstates, "shared_kv": kvs,
                    "mamba_tail": new_tail}


def prefill_from(params: Params, cfg, cache, tokens, start, *, max_len: int,
                 lengths=None):
    """Prefill only the suffix ``tokens`` [B,S] from a prefilled prefix
    ``cache``: mamba recurrent states resume exactly where the prefix
    left off, and the shared attention sites extend their KV caches at
    absolute slots [start, start+S) (see transformer.prefill_from)."""
    x = L.embed(params, cfg, tokens)
    G, K, tail, _ = layout(cfg)
    shared = params["shared"]
    start = jnp.asarray(start, jnp.int32)

    def body(xc, xs):
        group, states, kv = xs
        xc, stacked = M.stack_apply(group, states, xc, cfg, lengths=lengths)
        xc, kv2 = TF.block_prefill_from(shared, kv, xc, cfg, kind="G",
                                        start=start, max_len=max_len)
        xc = constrain(xc)
        return xc, (stacked, kv2)

    x, (mstates, kvs) = jax.lax.scan(
        body, x, (params["mamba_groups"], cache["mamba_groups"],
                  cache["shared_kv"]), unroll=cfg.scan_unroll)
    new_tail = cache["mamba_tail"]
    if params["mamba_tail"] is not None:
        def tbody(xc, xs):
            p, st = xs
            xc, st2 = M.block_apply(p, xc, cfg, state=st, lengths=lengths)
            return xc, st2
        x, new_tail = jax.lax.scan(tbody, x,
                                   (params["mamba_tail"],
                                    cache["mamba_tail"]),
                                   unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"mamba_groups": mstates, "shared_kv": kvs,
                    "mamba_tail": new_tail}
