"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, T, d_model] directly (``input_specs``
provides them).  Learned absolute positions, LayerNorm, GELU MLPs,
MHA (kv = heads).  Unrolled layer lists (6+6) — small enough that scan
isn't needed, and this exercises the framework's non-scan path.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import matmul, norm

Params = Dict[str, Any]


def _init_gelu_mlp(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    scale = 1.0 / math.sqrt(2 * (cfg.n_enc_layers + cfg.n_dec_layers))
    return {"wi": L.dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
            "wo": L.dense_init(k2, cfg.d_ff, cfg.d_model, dtype, scale=scale)}


def _gelu_mlp(p, x):
    return matmul(jax.nn.gelu(matmul(x, p["wi"])), p["wo"])


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_init(cfg.d_model, dtype, cfg.norm_type),
            "attn": L.init_attention(k1, cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, dtype, cfg.norm_type),
            "mlp": _init_gelu_mlp(k2, cfg, dtype)}


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg.d_model, dtype, cfg.norm_type),
            "attn": L.init_attention(k1, cfg, dtype),
            "lnx": L.norm_init(cfg.d_model, dtype, cfg.norm_type),
            "xattn": L.init_attention(k2, cfg, dtype),
            "ln2": L.norm_init(cfg.d_model, dtype, cfg.norm_type),
            "mlp": _init_gelu_mlp(k3, cfg, dtype)}


def init_params(key, cfg) -> Params:
    dtype = cfg.dtype
    ks = jax.random.split(key, 6)
    params = L.init_embed(ks[0], cfg, dtype)
    params["pos_enc"] = (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model))
                         * 0.01).astype(dtype)
    params["pos_dec"] = (jax.random.normal(ks[2], (cfg.max_seq, cfg.d_model))
                         * 0.01).astype(dtype)
    params["enc_blocks"] = [_init_enc_block(jax.random.fold_in(ks[3], i), cfg, dtype)
                            for i in range(cfg.n_enc_layers)]
    params["dec_blocks"] = [_init_dec_block(jax.random.fold_in(ks[4], i), cfg, dtype)
                            for i in range(cfg.n_dec_layers)]
    params["ln_enc"] = L.norm_init(cfg.d_model, dtype, cfg.norm_type)
    params["ln_f"] = L.norm_init(cfg.d_model, dtype, cfg.norm_type)
    return params


def _mha(p, x, cfg, kv_x=None, *, causal: bool):
    """Self- or cross-attention without rope (learned positions)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    src = x if kv_x is None else kv_x
    q = matmul(x, p["wq"]).reshape(B, S, H, hd)
    k = matmul(src, p["wk"]).reshape(B, src.shape[1], K, hd)
    v = matmul(src, p["wv"]).reshape(B, src.shape[1], K, hd)
    out = L.best_attention(q, k, v, kind="G", cfg=cfg, causal=causal)
    return matmul(out.reshape(B, S, -1), p["wo"]), k, v


def _enc_block(p, x, cfg):
    a, _, _ = _mha(p["attn"], norm(x, p["ln1"], cfg), cfg, causal=False)
    x = x + a
    return x + _gelu_mlp(p["mlp"], norm(x, p["ln2"], cfg))


def encode(params: Params, cfg, enc_inputs, *, remat: bool = True):
    x = enc_inputs + params["pos_enc"][None, :enc_inputs.shape[1]]
    blk = jax.checkpoint(_enc_block, static_argnums=(2,)) if remat \
        else _enc_block
    for p in params["enc_blocks"]:
        x = blk(p, x, cfg)
    return norm(x, params["ln_enc"], cfg)


def _dec_block(p, x, enc_out, cfg):
    a, _, _ = _mha(p["attn"], norm(x, p["ln1"], cfg), cfg, causal=True)
    x = x + a
    a, _, _ = _mha(p["xattn"], norm(x, p["lnx"], cfg), cfg, kv_x=enc_out,
                   causal=False)
    x = x + a
    return x + _gelu_mlp(p["mlp"], norm(x, p["ln2"], cfg))


def decode_train(params: Params, cfg, tokens, enc_out, pos_offset: int = 0,
                 *, remat: bool = True):
    x = L.embed(params, cfg, tokens)
    x = x + params["pos_dec"][None, pos_offset:pos_offset + tokens.shape[1]]
    blk = jax.checkpoint(_dec_block, static_argnums=(3,)) if remat \
        else _dec_block
    for p in params["dec_blocks"]:
        x = blk(p, x, enc_out, cfg)
    x = norm(x, params["ln_f"], cfg)
    return L.unembed(params, cfg, x)


def forward(params: Params, cfg, tokens, *, enc_inputs=None, train: bool = False,
            remat: bool = True, **_):
    enc_out = encode(params, cfg, enc_inputs, remat=remat and train)
    logits = decode_train(params, cfg, tokens, enc_out,
                          remat=remat and train)
    return logits, {"moe_aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# serving: cache = decoder self-attn KV + precomputed cross KV
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, **_):
    dt = cfg.dtype
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "self": [{"k": jnp.zeros((batch, max_len, K, hd), dt),
                  "v": jnp.zeros((batch, max_len, K, hd), dt)}
                 for _ in range(cfg.n_dec_layers)],
        "cross": [{"k": jnp.zeros((batch, cfg.enc_ctx, K, hd), dt),
                   "v": jnp.zeros((batch, cfg.enc_ctx, K, hd), dt)}
                  for _ in range(cfg.n_dec_layers)],
        # true encoder length: cross-attn must not attend to padded slots
        "enc_len": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: Params, cfg, tokens, *, enc_inputs, max_len: int, **_):
    """Encode + decoder prompt; returns (logits, cache)."""
    B, S = tokens.shape
    enc_out = encode(params, cfg, enc_inputs)
    # pre-compute cross-attn KV once (whisper serving trick)
    cache = init_cache(cfg, B, max_len)
    hd = cfg.resolved_head_dim
    K = cfg.n_kv_heads
    Te = enc_out.shape[1]
    for i, p in enumerate(params["dec_blocks"]):
        ck = matmul(enc_out, p["xattn"]["wk"]).reshape(B, Te, K, hd)
        cv = matmul(enc_out, p["xattn"]["wv"]).reshape(B, Te, K, hd)
        if Te >= cfg.enc_ctx:
            ck, cv = ck[:, :cfg.enc_ctx], cv[:, :cfg.enc_ctx]
        else:
            pad = [(0, 0), (0, cfg.enc_ctx - Te), (0, 0), (0, 0)]
            ck, cv = jnp.pad(ck, pad), jnp.pad(cv, pad)
        cache["cross"][i] = {"k": ck, "v": cv}
    cache["enc_len"] = jnp.full((B,), min(Te, cfg.enc_ctx), jnp.int32)
    x = L.embed(params, cfg, tokens)
    x = x + params["pos_dec"][None, :S]
    for i, p in enumerate(params["dec_blocks"]):
        h = norm(x, p["ln1"], cfg)
        q = matmul(h, p["attn"]["wq"]).reshape(B, S, cfg.n_heads, hd)
        k = matmul(h, p["attn"]["wk"]).reshape(B, S, K, hd)
        v = matmul(h, p["attn"]["wv"]).reshape(B, S, K, hd)
        out = L.best_attention(q, k, v, kind="G", cfg=cfg)
        x = x + matmul(out.reshape(B, S, -1), p["attn"]["wo"])
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        cache["self"][i] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        a, _, _ = _mha(p["xattn"], norm(x, p["lnx"], cfg), cfg, kv_x=enc_out,
                       causal=False)
        x = x + a
        x = x + _gelu_mlp(p["mlp"], norm(x, p["ln2"], cfg))
    x = norm(x, params["ln_f"], cfg)
    return L.unembed(params, cfg, x), cache


def decode_step(params: Params, cfg, cache, tokens, pos, *, max_len: int):
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    hd = cfg.resolved_head_dim
    K, H = cfg.n_kv_heads, cfg.n_heads
    x = L.embed(params, cfg, tokens)
    x = x + params["pos_dec"][pos][:, None]
    new_cache = {"self": [], "cross": cache["cross"],
                 "enc_len": cache["enc_len"]}
    bidx = jnp.arange(B)
    for i, p in enumerate(params["dec_blocks"]):
        h = norm(x, p["ln1"], cfg)
        q = matmul(h, p["attn"]["wq"]).reshape(B, 1, H, hd)
        k = matmul(h, p["attn"]["wk"]).reshape(B, 1, K, hd)
        v = matmul(h, p["attn"]["wv"]).reshape(B, 1, K, hd)
        c = cache["self"][i]
        ck = c["k"].at[bidx, pos].set(k[:, 0])
        cv = c["v"].at[bidx, pos].set(v[:, 0])
        new_cache["self"].append({"k": ck, "v": cv})
        out = L.decode_attention(q, ck, cv, pos[:, None] + 1)
        x = x + matmul(out.reshape(B, 1, -1), p["attn"]["wo"])
        h = norm(x, p["lnx"], cfg)
        qx = matmul(h, p["xattn"]["wq"]).reshape(B, 1, H, hd)
        cx = cache["cross"][i]
        outx = L.decode_attention(qx, cx["k"], cx["v"], cache["enc_len"])
        x = x + matmul(outx.reshape(B, 1, -1), p["xattn"]["wo"])
        x = x + _gelu_mlp(p["mlp"], norm(x, p["ln2"], cfg))
    x = norm(x, params["ln_f"], cfg)
    return L.unembed(params, cfg, x), new_cache
