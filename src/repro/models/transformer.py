"""Decoder-only transformer LM (dense / moe / vlm families).

The layer stack is executed with ``lax.scan`` over the *repeating pattern
unit* of the architecture (e.g. gemma2's (local, global) pair, gemma3's
(5xlocal, global) sextet, or a single block for uniform stacks), with any
remainder layers unrolled.  Per-layer parameters are stacked along the
scan axis, which keeps the HLO compact for 40+ layer models and lets the
IOLM compression passes vmap over layers.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.layers import matmul, norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# pattern-unit machinery
# ---------------------------------------------------------------------------

def pattern_unit(cfg) -> Tuple[str, int, int]:
    """(unit, n_repeats, n_tail) — smallest repeating unit of the pattern."""
    pat = cfg.pattern()
    n = len(pat)
    for U in range(1, n + 1):
        R = n // U
        if R < 1:
            continue
        unit = pat[:U]
        if (unit * R == pat[:U * R] and pat[U * R:] == unit[:n - U * R]
                and (R >= 2 or U == n)):
            return unit, R, n - U * R
    return pat, 1, 0


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, cfg, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {
        "ln1": L.norm_init(d, dtype, cfg.norm_type),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": L.norm_init(d, dtype, cfg.norm_type),
    }
    if cfg.post_norms:
        p["ln1_post"] = L.norm_init(d, dtype, cfg.norm_type)
        p["ln2_post"] = L.norm_init(d, dtype, cfg.norm_type)
    if cfg.family == "moe":
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
        if cfg.n_shared_experts:
            # n parallel shared experts == one MLP with concatenated hidden
            p["shared_mlp"] = L.init_mlp(ks[2], cfg, dtype,
                                         d_ff=cfg.n_shared_experts * cfg.moe_d_ff)
        if cfg.dense_residual:
            p["dense_mlp"] = L.init_mlp(ks[3], cfg, dtype, d_ff=cfg.d_ff)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
    return p


def _theta(cfg, kind: str) -> float:
    if kind == "L" and cfg.rope_theta_local:
        return cfg.rope_theta_local
    return cfg.rope_theta


def block_apply(p: Params, x, cfg, *, kind: str, positions, train: bool,
                use_flash: bool = False):
    """Full-sequence block (train / prefill without cache)."""
    h = norm(x, p["ln1"], cfg)
    a = L.attention_block(p["attn"], h, cfg, kind=kind, positions=positions,
                          theta=_theta(cfg, kind), use_flash=use_flash)
    if "ln1_post" in p:
        a = norm(a, p["ln1_post"], cfg)
    x = x + a
    h = norm(x, p["ln2"], cfg)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, aux = L.moe_block(p["moe"], h, cfg, train=train)
        if "shared_mlp" in p:
            m = m + L.mlp_block(p["shared_mlp"], h)
        if "dense_mlp" in p:
            m = m + L.mlp_block(p["dense_mlp"], h)
    else:
        m = L.mlp_block(p["mlp"], h)
    if "ln2_post" in p:
        m = norm(m, p["ln2_post"], cfg)
    return x + m, aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg) -> Params:
    dtype = cfg.dtype
    unit, R, tail = pattern_unit(cfg)
    k_emb, k_blocks, k_tail, k_ln = jax.random.split(key, 4)
    params = L.init_embed(k_emb, cfg, dtype)
    blocks = []
    for u in range(len(unit)):
        ku = jax.random.fold_in(k_blocks, u)
        member = jax.vmap(lambda k: init_block(k, cfg, dtype))(
            jax.random.split(ku, R))
        blocks.append(member)
    params["blocks"] = blocks
    params["tail"] = [init_block(jax.random.fold_in(k_tail, i), cfg, dtype)
                      for i in range(tail)]
    params["ln_f"] = L.norm_init(cfg.d_model, dtype, cfg.norm_type)
    return params


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill logits)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg, tokens=None, *, img_embs=None, train: bool = False,
            use_flash: bool = False, remat: bool = True, capture: bool = False):
    """Returns (logits [B,S,V], aux dict).  ``capture`` additionally returns
    per-layer block inputs (for IOLM calibration) and disables remat."""
    x = L.embed(params, cfg, tokens)
    if cfg.family == "vlm" and img_embs is not None:
        x = jnp.concatenate([img_embs.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    unit, R, tail = pattern_unit(cfg)

    def body(xc, member_params):
        aux = jnp.zeros((), jnp.float32)
        cap = []
        for u, kind in enumerate(unit):
            if capture:
                cap.append(xc)
            xc, a = block_apply(member_params[u], xc, cfg, kind=kind,
                                positions=positions, train=train,
                                use_flash=use_flash)
            xc = constrain(xc)
            aux = aux + a
        ys = (aux, cap) if capture else (aux, ())
        return xc, ys

    scan_body = body
    if remat and not capture:
        scan_body = jax.checkpoint(body)
    x, (auxs, caps) = jax.lax.scan(scan_body, x, params["blocks"],
                                   unroll=cfg.scan_unroll)
    aux_total = auxs.sum()
    captures = {"blocks": caps, "tail": []}
    for i, p in enumerate(params["tail"]):
        if capture:
            captures["tail"].append(x)
        x, a = block_apply(p, x, cfg, kind=unit[i % len(unit)],
                           positions=positions, train=train, use_flash=use_flash)
        aux_total = aux_total + a
    x = norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    aux = {"moe_aux": aux_total}
    if capture:
        aux["captures"] = captures
        aux["final_hidden"] = x
    return logits, aux


def loss_fn(params: Params, cfg, tokens, labels, *, img_embs=None,
            xent_chunk: int = 0, remat: bool = True, aux_weight: float = 0.01):
    """Causal LM loss.  ``xent_chunk`` > 0 streams the vocab projection over
    sequence chunks so [B,S,V] logits are never materialized (critical for
    256k-vocab train cells)."""
    if xent_chunk:
        # run trunk without unembed by capturing final hidden
        x = L.embed(params, cfg, tokens)
        if cfg.family == "vlm" and img_embs is not None:
            x = jnp.concatenate([img_embs.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        unit, R, tail = pattern_unit(cfg)

        def body(xc, member_params):
            aux = jnp.zeros((), jnp.float32)
            for u, kind in enumerate(unit):
                xc, a = block_apply(member_params[u], xc, cfg, kind=kind,
                                    positions=positions, train=True)
                xc = constrain(xc)
                aux = aux + a
            return xc, aux

        sb = jax.checkpoint(body) if remat else body
        x, auxs = jax.lax.scan(sb, x, params["blocks"],
                               unroll=cfg.scan_unroll)
        aux_total = auxs.sum()
        for i, p in enumerate(params["tail"]):
            x, a = block_apply(p, x, cfg, kind=unit[i % len(unit)],
                               positions=positions, train=True)
            aux_total = aux_total + a
        x = norm(x, params["ln_f"], cfg)
        if cfg.family == "vlm":
            x = x[:, -tokens.shape[1]:]           # loss only on text positions
        nchunks = max(x.shape[1] // xent_chunk, 1)

        def xent_body(c, xs):
            xc, yc = xs
            logits = L.unembed(params, cfg, xc)
            ll = _xent(logits, yc)
            return c + ll, None

        xcs = x.reshape(x.shape[0], nchunks, -1, x.shape[-1]).swapaxes(0, 1)
        ycs = labels.reshape(labels.shape[0], nchunks, -1).swapaxes(0, 1)
        total, _ = jax.lax.scan(jax.checkpoint(xent_body) if remat else xent_body,
                                jnp.zeros((), jnp.float32), (xcs, ycs),
                                unroll=cfg.scan_unroll)
        loss = total / labels.size
        return loss + aux_weight * aux_total
    logits, aux = forward(params, cfg, tokens, img_embs=img_embs, train=True,
                          remat=remat)
    if cfg.family == "vlm":
        logits = logits[:, -tokens.shape[1]:]
    loss = _xent(logits, labels) / labels.size
    return loss + aux_weight * aux["moe_aux"]


def _xent(logits, labels) -> jax.Array:
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - gold.astype(jnp.float32)).sum()


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, *, compact_local: bool = True):
    """Cache pytree mirroring the block structure.

    Local ('L') layers get a circular ``window``-sized buffer when
    ``compact_local`` (dry-run decode: gemma3 long_500k keeps only ~4
    global layers at 500k); the serving engine uses absolute slots
    (``compact_local=False``) to support per-row lengths.
    """
    unit, R, tail = pattern_unit(cfg)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.dtype

    def entry(kind, stacked: bool):
        T = max_len
        if kind == "L" and compact_local:
            T = min(cfg.window_size, max_len)
        shape = (R, batch, T, K, hd) if stacked else (batch, T, K, hd)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    return {
        "blocks": [entry(kind, True) for kind in unit],
        "tail": [entry(unit[i % len(unit)], False) for i in range(tail)],
    }


def cache_spec(cfg, batch: int, max_len: int, *, compact_local: bool = True):
    """ShapeDtypeStructs matching init_cache (for dry-run lowering)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, compact_local=compact_local))


def _decode_attn_block(p, c, x, cfg, *, kind: str, pos, max_len: int):
    """One decode block: writes this step's k/v into cache, attends.

    pos: [B] int32 per-row position of the incoming token.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    h = norm(x, p["ln1"], cfg)
    positions = pos[:, None]
    q, k, v = L._qkv(p["attn"], h, cfg, positions, _theta(cfg, kind))
    T = c["k"].shape[1]
    idx = pos % T                                   # circular for compact local
    from repro.distributed.sharding import OPT
    if OPT["masked_cache_update"]:
        # §Perf: masked select keeps the cache sharding through the update
        # (a batch-indexed scatter loses it -> SPMD replicates the cache)
        onehot = (jnp.arange(T)[None, :] == idx[:, None])      # [B, T]
        m = onehot[:, :, None, None]
        ck = jnp.where(m, k[:, :1].astype(c["k"].dtype), c["k"])
        cv = jnp.where(m, v[:, :1].astype(c["v"].dtype), c["v"])
    else:
        bidx = jnp.arange(B)
        ck = c["k"].at[bidx, idx].set(k[:, 0])
        cv = c["v"].at[bidx, idx].set(v[:, 0])
    if kind == "L":
        if T < max_len:                             # compact circular buffer
            slots = jnp.arange(T)[None, :]
            valid = (slots <= pos[:, None]) | (pos[:, None] >= T)
            kv_len = jnp.where(pos + 1 < T, pos + 1, T)
            out = _masked_decode(q, ck, cv, valid, cfg.attn_softcap)
        else:                                       # absolute slots + window
            slots = jnp.arange(T)[None, :]
            valid = (slots <= pos[:, None]) & (slots > pos[:, None] - cfg.window_size)
            out = _masked_decode(q, ck, cv, valid, cfg.attn_softcap)
    else:
        slots = jnp.arange(T)[None, :]
        valid = slots <= pos[:, None]
        out = _masked_decode(q, ck, cv, valid, cfg.attn_softcap)
    a = matmul(out.reshape(B, 1, -1), p["attn"]["wo"])
    if "ln1_post" in p:
        a = norm(a, p["ln1_post"], cfg)
    return a, {"k": ck, "v": cv}


def _masked_decode(q, k_cache, v_cache, valid, cap):
    """q [B,1,H,D], cache [B,T,K,D], valid [B,T] bool."""
    B, _, H, D = q.shape
    K = k_cache.shape[2]
    qg = q.reshape(B, 1, K, H // K, D)
    mask = valid[:, None, None, None, :]
    out = L._sdpa(qg, k_cache, v_cache, mask, cap)
    return out.reshape(B, 1, H, D)


def _mlp_section(p, h, cfg):
    """Inference-mode FFN half of a block (dense / moe / shared / residual)."""
    if "moe" in p:
        m, _ = L.moe_block(p["moe"], h, cfg, train=False)
        if "shared_mlp" in p:
            m = m + L.mlp_block(p["shared_mlp"], h)
        if "dense_mlp" in p:
            m = m + L.mlp_block(p["dense_mlp"], h)
    else:
        m = L.mlp_block(p["mlp"], h)
    if "ln2_post" in p:
        m = norm(m, p["ln2_post"], cfg)
    return m


def block_decode(p, c, x, cfg, *, kind: str, pos, max_len: int):
    a, c2 = _decode_attn_block(p, c, x, cfg, kind=kind, pos=pos, max_len=max_len)
    x = x + a
    h = norm(x, p["ln2"], cfg)
    return x + _mlp_section(p, h, cfg), c2


def decode_step(params: Params, cfg, cache, tokens, pos, *, max_len: int):
    """One token for every row.  tokens [B,1]; pos scalar or [B] int32.
    Returns (logits [B,1,V], new_cache)."""
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(params, cfg, tokens)
    unit, R, tail = pattern_unit(cfg)

    def body(xc, xs):
        member_params, member_cache = xs
        new_caches = []
        for u, kind in enumerate(unit):
            xc, c2 = block_decode(member_params[u], member_cache[u], xc, cfg,
                                  kind=kind, pos=pos, max_len=max_len)
            new_caches.append(c2)
        return xc, new_caches

    x, new_block_cache = jax.lax.scan(body, x,
                                      (params["blocks"], cache["blocks"]),
                                      unroll=cfg.scan_unroll)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, c2 = block_decode(p, cache["tail"][i], x, cfg,
                             kind=unit[i % len(unit)], pos=pos, max_len=max_len)
        new_tail.append(c2)
    x = norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": new_block_cache, "tail": new_tail}


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------
#
# The serving engine's paged layout replaces the per-slot contiguous
# [slots, T, K, hd] tensors with one global pool of fixed-size blocks —
# stacked entries [R, num_blocks, bs, K, hd], tail entries
# [num_blocks, bs, K, hd] — plus a per-slot block table [slots, T // bs]
# of int32 block ids shared by every layer (block id b addresses index b
# in every layer's pool).  Admission scatters per-row prefill KV into the
# table's blocks, and a shared template prefix is seeded once and aliased
# by table entries instead of being copied per row.  Decode runs batched
# over all slots (the pool is shared, so the per-row vmap of the
# contiguous path does not apply) and attends through the table — either
# by gathering in jnp (reference backend) or inside the paged Pallas
# kernel (pallas backend).

def init_paged_cache(cfg, num_blocks: int, block_size: int):
    """Block-pool cache pytree mirroring the block structure."""
    unit, R, tail = pattern_unit(cfg)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.dtype

    def entry(stacked: bool):
        shape = ((R, num_blocks, block_size, K, hd) if stacked
                 else (num_blocks, block_size, K, hd))
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    return {"blocks": [entry(True) for _ in unit],
            "tail": [entry(False) for _ in range(tail)]}


def paged_write_blocks(pool_entry, row_entry, write_ids, *, block_size: int):
    """Scatter vmapped per-row contiguous KV into pool blocks.

    ``row_entry`` leaves are [n, R, 1, T, K, hd] (stacked) or
    [n, 1, T, K, hd] (tail); ``write_ids`` [n, T // block_size] names the
    destination block per chunk (the engine points skipped chunks — e.g.
    prefix blocks already aliased — at its trash block)."""
    bs = block_size
    ids = write_ids.reshape(-1)

    def one(pool, rows):
        n = rows.shape[0]
        K, hd = rows.shape[-2], rows.shape[-1]
        if rows.ndim == 6:                      # stacked [n, R, 1, T, K, hd]
            R, T = rows.shape[1], rows.shape[3]
            r = rows.reshape(n, R, T // bs, bs, K, hd)
            r = jnp.moveaxis(r, 0, 1).reshape(R, n * (T // bs), bs, K, hd)
            return pool.at[:, ids].set(r.astype(pool.dtype))
        T = rows.shape[2]                       # tail [n, 1, T, K, hd]
        r = rows.reshape(n * (T // bs), bs, K, hd)
        return pool.at[ids].set(r.astype(pool.dtype))

    return jax.tree.map(one, pool_entry, row_entry)


def paged_insert(cfg, state, rows, write_ids, *, block_size: int):
    """Scatter an admission batch's row caches (from vmapped prefill)
    into the paged pools at ``write_ids`` [n, T // block_size]."""
    return {
        "blocks": [paged_write_blocks(state["blocks"][u], rows["blocks"][u],
                                      write_ids, block_size=block_size)
                   for u in range(len(state["blocks"]))],
        "tail": [paged_write_blocks(state["tail"][i], rows["tail"][i],
                                    write_ids, block_size=block_size)
                 for i in range(len(state["tail"]))],
    }


def paged_seed(cfg, state, entry_state, write_ids, *, block_size: int):
    """Write a prefix-cache entry's KV (a batch=1 contiguous cache) into
    the shared blocks named by ``write_ids`` [1, T // block_size]."""
    rows = jax.tree.map(lambda a: a[None], entry_state)
    return paged_insert(cfg, state, rows, write_ids, block_size=block_size)


def _paged_attn_block(p, c, x, cfg, *, kind: str, pos, tables,
                      block_size: int, max_len: int, backend: str):
    """Decode attention against block pools ``c`` ({"k","v"}
    [nb, bs, K, hd]) through ``tables`` [B, T // bs].  pos: [B] int32."""
    B = x.shape[0]
    h = norm(x, p["ln1"], cfg)
    positions = pos[:, None]
    q, k, v = L._qkv(p["attn"], h, cfg, positions, _theta(cfg, kind))
    nb, bs, K, hd = c["k"].shape
    nblk = max_len // bs
    # scatter this step's k/v into each slot's current block; every slot
    # writes a distinct flat index (tables point active slots past any
    # aliased prefix blocks, idle slots at their private blocks).
    flat = tables[jnp.arange(B), pos // bs] * bs + pos % bs
    ck = c["k"].reshape(nb * bs, K, hd).at[flat].set(
        k[:, 0].astype(c["k"].dtype)).reshape(nb, bs, K, hd)
    cv = c["v"].reshape(nb * bs, K, hd).at[flat].set(
        v[:, 0].astype(c["v"].dtype)).reshape(nb, bs, K, hd)
    win = cfg.window_size if kind == "L" else 0
    if backend == "pallas":
        from repro.kernels import ops as kops
        out = kops.paged_attention(q, ck, cv, tables, pos + 1,
                                   softcap=cfg.attn_softcap, window=win)
    else:
        gk = ck[tables].reshape(B, nblk * bs, K, hd)
        gv = cv[tables].reshape(B, nblk * bs, K, hd)
        slots = jnp.arange(nblk * bs)[None, :]
        valid = slots <= pos[:, None]
        if win:
            valid &= slots > pos[:, None] - win
        out = _masked_decode(q, gk, gv, valid, cfg.attn_softcap)
    a = matmul(out.reshape(B, 1, -1), p["attn"]["wo"])
    if "ln1_post" in p:
        a = norm(a, p["ln1_post"], cfg)
    return a, {"k": ck, "v": cv}


def paged_block_decode(p, c, x, cfg, *, kind: str, pos, tables,
                       block_size: int, max_len: int, backend: str):
    a, c2 = _paged_attn_block(p, c, x, cfg, kind=kind, pos=pos, tables=tables,
                              block_size=block_size, max_len=max_len,
                              backend=backend)
    x = x + a
    h = norm(x, p["ln2"], cfg)
    return x + _mlp_section(p, h, cfg), c2


def paged_decode_step(params: Params, cfg, cache, tables, tokens, pos, *,
                      block_size: int, max_len: int,
                      backend: str = "reference"):
    """One token for every slot against the paged pools.  tokens [B,1];
    pos [B] int32; tables [B, max_len // block_size] int32.
    Returns (logits [B,1,V], new_cache)."""
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = L.embed(params, cfg, tokens)
    unit, R, tail = pattern_unit(cfg)

    def body(xc, xs):
        member_params, member_cache = xs
        new_caches = []
        for u, kind in enumerate(unit):
            xc, c2 = paged_block_decode(
                member_params[u], member_cache[u], xc, cfg, kind=kind,
                pos=pos, tables=tables, block_size=block_size,
                max_len=max_len, backend=backend)
            new_caches.append(c2)
        return xc, new_caches

    x, new_block_cache = jax.lax.scan(body, x,
                                      (params["blocks"], cache["blocks"]),
                                      unroll=cfg.scan_unroll)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, c2 = paged_block_decode(p, cache["tail"][i], x, cfg,
                                   kind=unit[i % len(unit)], pos=pos,
                                   tables=tables, block_size=block_size,
                                   max_len=max_len, backend=backend)
        new_tail.append(c2)
    x = norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": new_block_cache, "tail": new_tail}


# ---------------------------------------------------------------------------
# prefill: forward + cache population
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg, tokens, *, img_embs=None, max_len: int,
            compact_local: bool = True, use_flash: bool = False):
    """Run the prompt, return (logits [B,S,V], populated cache).

    Rows are assumed right-padded; the caller tracks true lengths and
    gathers last-valid-token logits (engine does this).  Cache slots are
    absolute (or circular-compact for local layers in dry-run mode).
    """
    x = L.embed(params, cfg, tokens)
    if cfg.family == "vlm" and img_embs is not None:
        x = jnp.concatenate([img_embs.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    unit, R, tail = pattern_unit(cfg)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def kv_entry(kind, k, v):
        T = max_len if not (kind == "L" and compact_local) \
            else min(cfg.window_size, max_len)
        if S >= T:
            kk, vv = k[:, S - T:], v[:, S - T:]
            shift = (S - T) % T
            kk = jnp.roll(kk, shift, axis=1)
            vv = jnp.roll(vv, shift, axis=1)
        else:
            pad = [(0, 0), (0, T - S), (0, 0), (0, 0)]
            kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": kk, "v": vv}

    def block_prefill(p, xc, kind):
        h = norm(xc, p["ln1"], cfg)
        q, k, v = L._qkv(p["attn"], h, cfg, positions, _theta(cfg, kind))
        if use_flash:
            from repro.kernels import ops as kops
            win = cfg.window_size if kind == "L" else 0
            out = kops.flash_attention(q, k, v, causal=True, window=win,
                                       softcap=cfg.attn_softcap)
        else:
            out = L.best_attention(q, k, v, kind=kind, cfg=cfg)
        a = matmul(out.reshape(B, S, -1), p["attn"]["wo"])
        if "ln1_post" in p:
            a = norm(a, p["ln1_post"], cfg)
        xc = xc + a
        h = norm(xc, p["ln2"], cfg)
        return xc + _mlp_section(p, h, cfg), kv_entry(kind, k, v)

    def body(xc, member_params):
        caches = []
        for u, kind in enumerate(unit):
            xc, c = block_prefill(member_params[u], xc, kind)
            xc = constrain(xc)
            caches.append(c)
        return xc, caches

    x, block_caches = jax.lax.scan(jax.checkpoint(body), x,
                                   params["blocks"], unroll=cfg.scan_unroll)
    tail_caches = []
    for i, p in enumerate(params["tail"]):
        x, c = block_prefill(p, x, unit[i % len(unit)])
        tail_caches.append(c)
    x = norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": block_caches, "tail": tail_caches}


# ---------------------------------------------------------------------------
# continued prefill: suffix chunk against a prefilled prefix cache
# ---------------------------------------------------------------------------

def _masked_chunk(q, k_cache, v_cache, valid, cap):
    """q [B,S,H,D], cache [B,T,K,D], valid [B,S,T] bool (True = attend)."""
    B, S, H, D = q.shape
    K = k_cache.shape[2]
    qg = q.reshape(B, S, K, H // K, D)
    mask = valid[:, None, None]                     # [B,1,1,S,T]
    out = L._sdpa(qg, k_cache, v_cache, mask, cap)
    return out.reshape(B, S, H, D)


def _chunk_attn_block(p, c, x, cfg, *, kind: str, start, max_len: int):
    """Attention half of one block over an S-token chunk whose first token
    sits at absolute position ``start`` (traced scalar): the chunk's k/v
    are written into the cache at slots [start, start+S) and queries
    attend to every cached slot <= their own position (windowed for local
    layers).  With a template prefix at slots [0, start) this IS per-row
    prefill restricted to the row suffix."""
    B, S, _ = x.shape
    h = norm(x, p["ln1"], cfg)
    positions = jnp.broadcast_to(start + jnp.arange(S, dtype=jnp.int32),
                                 (B, S))
    q, k, v = L._qkv(p["attn"], h, cfg, positions, _theta(cfg, kind))
    T = c["k"].shape[1]
    ck = jax.lax.dynamic_update_slice_in_dim(
        c["k"], k.astype(c["k"].dtype), start, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        c["v"], v.astype(c["v"].dtype), start, axis=1)
    slots = jnp.arange(T, dtype=jnp.int32)[None, None, :]       # [1,1,T]
    qpos = positions[:, :, None]                                # [B,S,1]
    valid = slots <= qpos
    if kind == "L":
        valid = valid & (slots > qpos - cfg.window_size)
    out = _masked_chunk(q, ck, cv, valid, cfg.attn_softcap)
    a = matmul(out.reshape(B, S, -1), p["attn"]["wo"])
    if "ln1_post" in p:
        a = norm(a, p["ln1_post"], cfg)
    return a, {"k": ck, "v": cv}


def block_prefill_from(p, c, x, cfg, *, kind: str, start, max_len: int):
    """Full block (attn + FFN) for a suffix chunk seeded from cache ``c``
    — the multi-token generalization of ``block_decode`` (hybrid reuses
    it for its shared attention sites)."""
    a, c2 = _chunk_attn_block(p, c, x, cfg, kind=kind, start=start,
                              max_len=max_len)
    x = x + a
    h = norm(x, p["ln2"], cfg)
    return x + _mlp_section(p, h, cfg), c2


def prefill_from(params: Params, cfg, cache, tokens, start, *, max_len: int):
    """Prefill only the suffix ``tokens`` [B,S] whose shared prefix
    (absolute positions [0, start)) is already resident in ``cache``.

    Returns (logits [B,S,V], populated cache) exactly like ``prefill``
    run on prefix+suffix, but spending trunk FLOPs on S tokens instead
    of start+S.  Cache slots are absolute (engine serving layout,
    ``compact_local=False``)."""
    x = L.embed(params, cfg, tokens)
    start = jnp.asarray(start, jnp.int32)
    unit, R, tail = pattern_unit(cfg)

    def body(xc, xs):
        member_params, member_cache = xs
        new_caches = []
        for u, kind in enumerate(unit):
            xc, c2 = block_prefill_from(member_params[u], member_cache[u],
                                        xc, cfg, kind=kind, start=start,
                                        max_len=max_len)
            xc = constrain(xc)
            new_caches.append(c2)
        return xc, new_caches

    x, new_block_cache = jax.lax.scan(body, x,
                                      (params["blocks"], cache["blocks"]),
                                      unroll=cfg.scan_unroll)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, c2 = block_prefill_from(p, cache["tail"][i], x, cfg,
                                   kind=unit[i % len(unit)], start=start,
                                   max_len=max_len)
        new_tail.append(c2)
    x = norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": new_block_cache, "tail": new_tail}
