"""Family dispatch + step builders (train_step / prefill_step / serve_step).

This is the single entry point used by the launcher, the dry-run, the
serving engine, and the benchmarks: every architecture family exposes the
same five functions (init_params / forward / init_cache / prefill /
decode_step), and the step builders here assemble them into the jittable
functions that get lowered per (arch x shape x mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, rwkv, transformer


def family_module(cfg):
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "encdec": encdec,
        "rwkv": rwkv,
        "hybrid": hybrid,
    }[cfg.family]


def init_params(key, cfg):
    return family_module(cfg).init_params(key, cfg)


def forward(params, cfg, batch: Dict[str, Any], *, train: bool = False,
            remat: bool = True, capture: bool = False, use_flash: bool = False):
    """batch: dict from configs.input_specs (tokens / labels / enc_inputs /
    img_embs).  Returns (logits, aux)."""
    mod = family_module(cfg)
    kw: Dict[str, Any] = dict(train=train, remat=remat, capture=capture)
    if cfg.family == "encdec":
        return mod.forward(params, cfg, batch["tokens"],
                           enc_inputs=batch["enc_inputs"], **kw)
    if cfg.family == "vlm":
        return mod.forward(params, cfg, batch["tokens"],
                           img_embs=batch.get("img_embs"),
                           use_flash=use_flash, **kw)
    if cfg.family in ("dense", "moe"):
        kw["use_flash"] = use_flash
    return mod.forward(params, cfg, batch["tokens"], **kw)


def loss_fn(params, cfg, batch, *, xent_chunk: int = 0, remat: bool = True,
            aux_weight: float = 0.01):
    if cfg.family in ("dense", "moe", "vlm") :
        return transformer.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                                   img_embs=batch.get("img_embs"),
                                   xent_chunk=xent_chunk, remat=remat,
                                   aux_weight=aux_weight)
    logits, aux = forward(params, cfg, batch, train=True, remat=remat)
    loss = transformer._xent(logits, batch["labels"]) / batch["labels"].size
    return loss + aux_weight * aux["moe_aux"]


def init_cache(cfg, batch: int, max_len: int, *, compact_local: bool = True):
    mod = family_module(cfg)
    return mod.init_cache(cfg, batch, max_len, compact_local=compact_local)


def prefill(params, cfg, batch, *, max_len: int, compact_local: bool = True,
            use_flash: bool = False, lengths=None):
    """``lengths`` [B] (optional): real token count per right-padded row.
    Attention families ignore it (causality already isolates the pads);
    recurrent families (rwkv/hybrid) need it so padding never leaks into
    the carried state a decode step resumes from."""
    mod = family_module(cfg)
    kw: Dict[str, Any] = dict(max_len=max_len)
    if cfg.family == "encdec":
        return mod.prefill(params, cfg, batch["tokens"],
                           enc_inputs=batch["enc_inputs"], **kw)
    if cfg.family in ("dense", "moe", "vlm"):
        kw.update(compact_local=compact_local, use_flash=use_flash)
        return mod.prefill(params, cfg, batch["tokens"],
                           img_embs=batch.get("img_embs"), **kw)
    return mod.prefill(params, cfg, batch["tokens"], lengths=lengths, **kw)


def decode_step(params, cfg, cache, tokens, pos, *, max_len: int):
    return family_module(cfg).decode_step(params, cfg, cache, tokens, pos,
                                          max_len=max_len)


# ---------------------------------------------------------------------------
# paged KV cache (serving: block pools + per-slot block tables)
# ---------------------------------------------------------------------------

def supports_paged(cfg) -> bool:
    """Whether the family can serve from a paged (block pool + block
    table) KV layout.  rwkv carries no positional KV, and vlm/encdec
    take the full-prefill path — they all stay on the contiguous
    slot-stacked layout."""
    return cfg.family in ("dense", "moe", "hybrid")


def init_paged_cache(cfg, slots: int, num_blocks: int, block_size: int):
    """Engine-wide paged decode state: KV block pools (every layer indexed
    by the same block-id space) plus, for hybrid, slot-batched recurrent
    states."""
    if cfg.family == "hybrid":
        return hybrid.init_paged_cache(cfg, slots, num_blocks, block_size)
    return transformer.init_paged_cache(cfg, num_blocks, block_size)


def paged_decode_step(params, cfg, cache, tables, tokens, pos, *,
                      block_size: int, max_len: int,
                      backend: str = "reference"):
    """One token for every slot, attending through ``tables``
    [slots, max_len // block_size].  ``backend`` picks the attention
    implementation: ``"reference"`` gathers blocks in jnp, ``"pallas"``
    runs the paged kernel (interpret-mode off-TPU)."""
    return family_module(cfg).paged_decode_step(
        params, cfg, cache, tables, tokens, pos, block_size=block_size,
        max_len=max_len, backend=backend)


def paged_insert(cfg, state, rows, slot_idxs, write_ids, *, block_size: int):
    """Scatter a vmapped admission batch into the paged state: KV rows go
    to the pool blocks named by ``write_ids`` [n, max_len // block_size]
    (trash-block ids suppress writes for aliased prefix blocks), recurrent
    rows go to ``slot_idxs``."""
    if cfg.family == "hybrid":
        return hybrid.paged_insert(cfg, state, rows, slot_idxs, write_ids,
                                   block_size=block_size)
    return transformer.paged_insert(cfg, state, rows, write_ids,
                                    block_size=block_size)


def paged_seed(cfg, state, entry_state, write_ids, *, block_size: int):
    """Write a prefix-cache entry's KV into shared pool blocks so later
    admissions alias them through their block tables instead of copying."""
    return family_module(cfg).paged_seed(cfg, state, entry_state, write_ids,
                                         block_size=block_size)


# ---------------------------------------------------------------------------
# prefix-sharing prefill (serving: template-heavy OLAP prompts)
# ---------------------------------------------------------------------------

def supports_prefix(cfg) -> bool:
    """Whether the family can seed per-row state from a shared prefilled
    prompt prefix.  encdec needs encoder inputs and vlm splices image
    embeddings ahead of the text — both break the pure token-prefix
    contract, so they take the full-prefill path."""
    return cfg.family in ("dense", "moe", "rwkv", "hybrid")


def prefill_from(params, cfg, prefix_cache_entry, suffix_tokens, prefix_len,
                 *, max_len: int, lengths=None):
    """Continue a prefill from a stored prefix state: ``prefix_cache_entry``
    is the cache pytree returned by ``prefill`` on the shared prefix
    (batch=1 per engine row, absolute slots), ``suffix_tokens`` [B,S] are
    the per-row remainder, ``prefix_len`` (traced scalar ok) is the number
    of prefix tokens already resident.  Returns (suffix logits [B,S,V],
    fully-populated cache) matching ``prefill`` on the concatenation —
    attention families extend the KV at slots [prefix_len, prefix_len+S),
    recurrent families resume their O(1) state.  ``lengths`` [B] is the
    real (un-padded) suffix token count per row (recurrent families)."""
    if not supports_prefix(cfg):
        raise NotImplementedError(
            f"prefix-sharing prefill unsupported for family {cfg.family!r}")
    mod = family_module(cfg)
    kw: Dict[str, Any] = dict(max_len=max_len)
    if cfg.family in ("rwkv", "hybrid"):
        kw["lengths"] = lengths
    return mod.prefill_from(params, cfg, prefix_cache_entry, suffix_tokens,
                            prefix_len, **kw)


# ---------------------------------------------------------------------------
# step builders (what the dry-run lowers)
# ---------------------------------------------------------------------------

def build_train_step(cfg, optimizer, *, xent_chunk: int = 0,
                     grad_compress=None, donate: bool = True):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``optimizer`` from repro.training.optimizer; ``grad_compress`` an
    optional (compress, state) hook applied to grads pre-all-reduce.
    """
    def train_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, xent_chunk=xent_chunk))(params)
        if grad_compress is not None:
            grads = grad_compress(grads)
        params, opt_state = optimizer.update(params, grads, opt_state, step)
        gnorm = optimizer.global_norm(grads)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def build_prefill_step(cfg, shape_spec, *, compact_local: bool = True):
    max_len = shape_spec.seq_len
    def prefill_step(params, batch):
        logits, cache = prefill(params, cfg, batch, max_len=max_len,
                                compact_local=compact_local)
        # return only last-position logits: engine gathers per-row lengths
        return logits[:, -1:], cache
    return prefill_step


def build_serve_step(cfg, shape_spec):
    """Single-token decode against a seq_len-deep cache (the assigned
    ``decode_*``/``long_*`` cells lower THIS, not train_step)."""
    max_len = shape_spec.seq_len
    def serve_step(params, cache, tokens, pos):
        logits, cache = decode_step(params, cfg, cache, tokens, pos,
                                    max_len=max_len)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache
    return serve_step
