"""Shared neural-net layers for every architecture family.

Functional style: ``init_*`` builds param dicts, ``apply_*``/plain
functions are pure.  All linear projections go through
``repro.core.compressed.matmul`` so instance-optimized (quantized /
block-sparse) weights slot in transparently.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compressed
from repro.core.compressed import matmul

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def norm_init(d: int, dtype, norm_type: str = "rmsnorm"):
    if norm_type == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, p, offset: bool = False, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = p["w"].astype(jnp.float32)
    w = 1.0 + w if offset else w
    return (xf * w).astype(x.dtype)


def layernorm(x, p, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


def norm(x, p, cfg):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p)
    return rmsnorm(x, p, offset=cfg.rms_offset)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs        # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                      # broadcast heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    depth_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "wq": dense_init(k1, d, H * hd, dtype),
        "wk": dense_init(k2, d, K * hd, dtype),
        "wv": dense_init(k3, d, K * hd, dtype),
        "wo": dense_init(k4, H * hd, d, dtype, scale=depth_scale),
    }


def _qkv(p, x, cfg, positions, theta: float, use_rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    q = matmul(x, p["wq"]).reshape(B, S, H, hd)
    k = matmul(x, p["wk"]).reshape(B, S, K, hd)
    v = matmul(x, p["wv"]).reshape(B, S, K, hd)
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def _sdpa(q, k, v, mask, cap: float):
    """Grouped-query attention core.

    q: [B, S, K, G, D]; k, v: [B, T, K, D]; mask: broadcastable to
    [B, K, G, S, T] (True = attend).  f32 softmax.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def full_attention(q, k, v, *, causal: bool, cap: float = 0.0,
                   window: int = 0, q_offset: int = 0):
    """q: [B,S,H,D], k/v: [B,T,K,D].  Optional causal/window banding."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    out = _sdpa(qg, k, v, mask[None, None, None], cap)
    return out.reshape(B, S, H, D)


def local_block_attention(q, k, v, *, window: int, cap: float = 0.0):
    """Sliding-window causal attention in O(S*W) via W-sized blocks.

    Each query block attends to itself + the previous key block, which
    covers every key within ``window``.  Requires S % window == 0.
    Falls back to masked full attention when S <= window.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    W = window
    if S <= W:
        return full_attention(q, k, v, causal=True, cap=cap, window=W)
    assert S % W == 0, (S, W)
    nb = S // W
    G = H // K
    qb = q.reshape(B, nb, W, K, G, D)
    kb = k.reshape(B, nb, W, K, D)
    vb = v.reshape(B, nb, W, K, D)
    # previous block (zeros before the first)
    prev = lambda a: jnp.concatenate([jnp.zeros_like(a[:, :1]), a[:, :-1]], axis=1)
    k2 = jnp.concatenate([prev(kb), kb], axis=2)        # [B, nb, 2W, K, D]
    v2 = jnp.concatenate([prev(vb), vb], axis=2)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bnskgd,bntkd->bnkgst", qb, k2,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    qpos = jnp.arange(W)[:, None] + W                   # within the 2W frame
    kpos = jnp.arange(2 * W)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < W)
    first = jnp.arange(nb) == 0                          # no prev block
    valid = jnp.where(first[:, None, None], kpos >= W, True)  # [nb,1,2W]
    mask = mask[None, :, :] & valid                      # [nb, W, 2W]
    logits = jnp.where(mask[None, :, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnkgst,bntkd->bnskgd", probs.astype(v2.dtype), v2,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, D).astype(v.dtype)


def flash_attention_jnp(q, k, v, *, causal: bool = True, window: int = 0,
                        cap: float = 0.0, q_offset: int = 0,
                        bq: int = 1024, bkv: int = 1024,
                        unroll: bool = False):
    """Blocked online-softmax attention in pure XLA (the flash schedule).

    Peak memory is one [B, H, bq, bkv] logits tile instead of the full
    [B, H, S, T] matrix — this is what makes the 32k prefill cells fit
    HBM; the Pallas kernel (repro.kernels) is the TPU-native version and
    this is its jnp twin used under jit/SPMD.  ``unroll`` follows
    cfg.scan_unroll so the dry-run's cost analysis sees every tile.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq, bkv = min(bq, S), min(bkv, T)
    assert S % bq == 0 and T % bkv == 0, (S, T, bq, bkv)
    nq, nk = S // bq, T // bkv
    scale = 1.0 / math.sqrt(D)
    qb = jnp.moveaxis(q.reshape(B, nq, bq, K, G, D), 1, 0)   # [nq,B,bq,K,G,D]
    kb = jnp.moveaxis(k.reshape(B, nk, bkv, K, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bkv, K, D), 1, 0)

    def q_step(_, qi):
        qblk, i = qi                                   # [B,bq,K,G,D], scalar
        qpos = i * bq + jnp.arange(bq) + q_offset

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk, vblk, j = kj
            kpos = j * bkv + jnp.arange(bkv)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            mask = jnp.ones((bq, bkv), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m2, l2, acc2), None

        init = (jnp.full((B, K, G, bq), -1e30, jnp.float32),
                jnp.zeros((B, K, G, bq), jnp.float32),
                jnp.zeros((B, K, G, bq, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      (kb, vb, jnp.arange(nk)),
                                      unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)                  # [B,bq,K,G,D]
        return None, out.reshape(B, bq, H, D).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)),
                           unroll=unroll)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, D)


# threshold above which full [S, T] logits would dominate HBM
_FLASH_MIN_ELEMS = 1 << 26
_FLASH_MIN_ELEMS_OPT = 1 << 24    # §Perf flash_at_4k: flash from 4k up


def best_attention(q, k, v, *, kind: str, cfg, q_offset: int = 0,
                   causal: bool = True):
    """Dispatch: local-block for window layers, blocked-flash for long
    global sequences, plain masked attention otherwise."""
    S, T = q.shape[1], k.shape[1]
    if kind == "L" and S > cfg.window_size and causal:
        return local_block_attention(q, k, v, window=cfg.window_size,
                                     cap=cfg.attn_softcap)
    from repro.distributed.sharding import OPT
    thresh = _FLASH_MIN_ELEMS_OPT if OPT["flash_at_4k"] else _FLASH_MIN_ELEMS
    win = cfg.window_size if kind == "L" else 0
    if S * T >= thresh and S % 1024 == 0 and T % 1024 == 0:
        # analysis builds (scan_unroll) use 2x2 mega-tiles: total flops are
        # tile-size-invariant (every tile is computed then masked), so the
        # unrolled cost is faithful without a 32x32-tile compile blowup
        bq = max(1024, S // 2) if cfg.scan_unroll else 1024
        bkv = max(1024, T // 2) if cfg.scan_unroll else 1024
        return flash_attention_jnp(q, k, v, causal=causal, window=win,
                                   cap=cfg.attn_softcap, q_offset=q_offset,
                                   bq=bq, bkv=bkv, unroll=cfg.scan_unroll)
    return full_attention(q, k, v, causal=causal, cap=cfg.attn_softcap,
                          window=win, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, kv_len, *, cap: float = 0.0,
                     window: int = 0):
    """Single-step attention: q [B,1,H,D] vs cache [B,T,K,D], valid to kv_len.

    ``window``: restrict to the trailing ``window`` positions (local layers).
    """
    B, _, H, D = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, 1, K, G, D)
    pos = jnp.arange(T)
    kv = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    mask = pos[None, :] < kv[:, None]
    if window:
        mask &= pos[None, :] >= (kv[:, None] - window)
    out = _sdpa(qg, k_cache, v_cache, mask[:, None, None, None, :], cap)
    return out.reshape(B, 1, H, D)


def attention_block(p, x, cfg, *, kind: str, positions, theta: float,
                    use_flash: bool = False):
    """Full-sequence (train/prefill) attention incl. projections."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions, theta)
    if use_flash:
        from repro.kernels import ops as kops
        win = cfg.window_size if kind == "L" else 0
        out = kops.flash_attention(q, k, v, causal=True, window=win,
                                   softcap=cfg.attn_softcap)
    else:
        out = best_attention(q, k, v, kind=kind, cfg=cfg)
    return matmul(out.reshape(B, S, -1), p["wo"])


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    depth_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))
    p = {
        "wi": dense_init(k1, d, ff, dtype),
        "wo": dense_init(k3, ff, d, dtype, scale=depth_scale),
    }
    if cfg.mlp_gated:
        p["wg"] = dense_init(k2, d, ff, dtype)
    return p


def mlp_block(p, x):
    if "wg" in p:
        h = jax.nn.silu(matmul(x, p["wg"])) * matmul(x, p["wi"])
    else:
        h = jax.nn.gelu(matmul(x, p["wi"]))
    return matmul(h, p["wo"])


def init_moe(key, cfg, dtype):
    d, ffe, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    keys = jax.random.split(key, 4)
    depth_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))

    def expert_init(k, d_in, d_out, scale=1.0):
        std = scale / math.sqrt(d_in)
        return (jax.random.normal(k, (E, d_in, d_out), jnp.float32) * std).astype(dtype)

    return {
        "router": dense_init(keys[0], d, E, jnp.float32),
        "wi": expert_init(keys[1], d, ffe),
        "wg": expert_init(keys[2], d, ffe),
        "wo": expert_init(keys[3], ffe, d, scale=depth_scale),
    }


def moe_capacity(n_tokens: int, cfg, train: bool) -> int:
    # eval on small token counts (decode steps, interactive batches) is
    # dropless so prefill/decode agree bit-for-bit with the full forward;
    # large prefills fall back to capacity-bounded dispatch with
    # probability-ordered dropping (lowest-gate entries dropped first).
    from repro.distributed.sharding import OPT
    if not train and n_tokens <= 4096:
        if OPT["moe_decode_capacity"]:
            # §Perf: 4x mean expert load instead of dropless C = T
            cap = int(math.ceil(4.0 * n_tokens * cfg.top_k / cfg.n_experts))
            return max(8, min(-(-cap // 8) * 8, n_tokens))
        return n_tokens
    cf = cfg.capacity_factor if train else (
        1.25 if OPT["moe_eval_cf125"] else 2.0)
    cap = int(math.ceil(n_tokens * cfg.top_k * cf / cfg.n_experts))
    if OPT["moe_sharded_dispatch"]:
        cap = -(-cap // 256) * 256          # shardable token-axis multiple
    return max(8, min(cap, n_tokens))


def moe_block(p, x, cfg, *, train: bool) -> Tuple[jax.Array, jax.Array]:
    """Scatter/gather top-k MoE (EP-shardable; see distributed/README.md).

    x: [B, S, d] -> (out [B, S, d], aux load-balance loss scalar).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = matmul(xt, p["router"]).astype(jnp.float32)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                          # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    onehot_all = jax.nn.one_hot(eidx, E, dtype=jnp.float32).sum(1)  # [T, E]
    f = onehot_all.mean(0)
    pmean = probs.mean(0)
    aux = E * jnp.sum(f * pmean)

    C = moe_capacity(T, cfg, train)
    # position of each (token, choice) within its expert: ranks via cumsum.
    # When capacity can drop entries, rank in gate-probability order so the
    # lowest-confidence (token, choice) pairs are dropped first.
    flat_e = eidx.reshape(-1)                                      # [T*k]
    if C < T * k:
        # stop_gradient: routing order is not differentiated (and this
        # jaxlib rejects the batched-gather JVP a differentiable sort
        # would emit)
        order = jnp.argsort(jax.lax.stop_gradient(-gates.reshape(-1)))
        inv = jnp.argsort(order)
        onehot = jax.nn.one_hot(flat_e[order], E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        ppos_sorted = jnp.take_along_axis(
            pos, flat_e[order][:, None], axis=1)[:, 0]
        ppos = ppos_sorted[inv]                                    # [T*k]
    else:
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [T*k, E]
        pos = (jnp.cumsum(onehot, axis=0) - onehot)                # exclusive
        ppos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = ppos < C
    tok = jnp.repeat(jnp.arange(T), k)
    # scatter tokens into [E, C, d]
    buf = jnp.zeros((E, C, d), x.dtype)
    upd = jnp.where(keep[:, None], xt[tok], 0).astype(x.dtype)
    buf = buf.at[flat_e, jnp.where(keep, ppos, C - 1)].add(
        jnp.where(keep[:, None], upd, 0))
    from repro.distributed.sharding import constrain_moe
    buf = constrain_moe(buf)
    # calibration hooks (eager only): expert inputs + routing statistics
    ecounts = jnp.zeros((E,), jnp.int32).at[flat_e].add(keep.astype(jnp.int32))
    compressed.record(p["wg"], buf, ecounts)
    compressed.record(p["wi"], buf, ecounts)
    compressed.record_routing(p["router"], ecounts, pmean)
    # expert FFN on [E, C, d] (dispatches on quantized expert stacks)
    h = jax.nn.silu(compressed.expert_matmul(buf, p["wg"]))
    h = h * compressed.expert_matmul(buf, p["wi"])
    from repro.distributed.sharding import constrain_moe as _cm
    h = _cm(h)
    compressed.record(p["wo"], h, ecounts)
    yb = compressed.expert_matmul(h, p["wo"])
    # gather back and weight by gates
    gath = yb[flat_e, jnp.where(keep, ppos, 0)]                    # [T*k, d]
    gath = jnp.where(keep[:, None], gath, 0)
    gflat = gates.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok].add(gath * gflat)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(key, cfg, dtype):
    p = {"embed": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                     jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def embed(params, cfg, tokens):
    t = params["embed"]
    x = t.lookup(tokens) if isinstance(t, compressed.QEmbed) else t[tokens]
    if cfg.emb_scale:
        x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
    return x


def unembed(params, cfg, x):
    if cfg.tie_embeddings:
        t = params["embed"]
        if isinstance(t, compressed.QEmbed):
            logits = t.logits(x)
        else:
            logits = jnp.einsum("...d,vd->...v", x, t,
                                preferred_element_type=jnp.float32)
    else:
        logits = matmul(x, params["unembed"]).astype(jnp.float32)
    return softcap(logits, cfg.final_softcap)
