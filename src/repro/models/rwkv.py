"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay.

WKV6 recurrence per head (state S in R^{N x N}, N = head dim):

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    o_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)

Prefill/train use a *chunked* parallel form: within a chunk the pairwise
decay factor exp(lb_i - la_j) is computed directly in log space (stable
for arbitrarily strong decays — the factored matmul form overflows when
per-channel decay is strong; see tests/test_rwkv.py), while chunk-to-chunk
state is carried through ``lax.scan``.  Decode carries S exactly, giving
O(1) state — this is why rwkv6 runs the ``long_500k`` cell natively.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.layers import matmul

Params = Dict[str, Any]

_LORA = 64  # decay LoRA bottleneck


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    depth_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    return {
        "ln1": L.norm_init(d, dtype, cfg.norm_type),
        "ln2": L.norm_init(d, dtype, cfg.norm_type),
        "tm": {
            # static lerp mixes for r,k,v,g + decay base mix
            "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
            "wr": L.dense_init(ks[1], d, d, dtype),
            "wk": L.dense_init(ks[2], d, d, dtype),
            "wv": L.dense_init(ks[3], d, d, dtype),
            "wg": L.dense_init(ks[4], d, d, dtype),
            "wo": L.dense_init(ks[5], d, d, dtype, scale=depth_scale),
            # data-dependent decay: w = exp(-exp(w0 + tanh(x A1) A2))
            "w0": (jax.random.normal(ks[6], (d,)) * 0.5 - 0.6).astype(jnp.float32),
            "wa1": L.dense_init(ks[7], d, _LORA, dtype),
            "wa2": L.dense_init(ks[8], _LORA, d, dtype, scale=0.1),
            "u": (jax.random.normal(ks[9], (d,)) * 0.3).astype(jnp.float32),
            "gn": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        },
        "cm": {
            "mu": (jax.random.uniform(ks[10], (2, d)) * 0.5 + 0.25).astype(dtype),
            "wk": L.dense_init(ks[11], d, cfg.d_ff, dtype),
            "wv": L.dense_init(jax.random.fold_in(key, 20), cfg.d_ff, d, dtype,
                               scale=depth_scale),
            "wr": L.dense_init(jax.random.fold_in(key, 21), d, d, dtype),
        },
    }


def init_params(key, cfg) -> Params:
    dtype = cfg.dtype
    k_emb, k_blocks = jax.random.split(key)
    params = L.init_embed(k_emb, cfg, dtype)
    params["blocks"] = [jax.vmap(lambda k: init_layer(k, cfg, dtype))(
        jax.random.split(k_blocks, cfg.n_layers))]
    params["tail"] = []
    params["ln_f"] = L.norm_init(cfg.d_model, dtype, cfg.norm_type)
    return params


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------

def wkv6_sequential(r, k, v, w, u, S0):
    """Oracle: token-by-token recurrence.

    r,k,v,w: [B,T,H,N]; u: [H,N]; S0: [B,H,N,N] -> (out [B,T,H,N], S_T).
    """
    def step(S, xs):
        rt, kt, vt, wt = xs                                    # [B,H,N]
        kv = kt[..., :, None] * vt[..., None, :]               # [B,H,N,N]
        out = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0).astype(jnp.float32) for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1), S


def wkv6_chunked(r, k, v, w, u, S0, chunk: int = 32):
    """Chunked parallel WKV6.  Same signature/semantics as sequential."""
    B, T, H, N = r.shape
    C = min(chunk, T)
    while T % C:
        C -= 1
    nc = T // C
    f32 = jnp.float32
    rs, ks, vs, ws = (jnp.moveaxis(a.reshape(B, nc, C, H, N), 1, 0).astype(f32)
                      for a in (r, k, v, w))

    def chunk_step(S, xs):
        rc, kc, vc, wc = xs                                    # [B,C,H,N]
        # 1e-38 is subnormal and may flush to zero on some backends; clamp
        # the log itself (decays below e^-60 per token are numerically dead)
        logw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-30)), -60.0)
        la = jnp.cumsum(logw, axis=1)                          # inclusive [B,C,H,N]
        lb = la - logw                                         # exclusive
        # inter-chunk: r_i decayed to chunk start, applied to carried state
        out = jnp.einsum("bchn,bhnm->bchm", rc * jnp.exp(lb), S)
        # intra-chunk: per-pair log-space decay (stable for strong decay)
        E = lb[:, :, None] - la[:, None, :]                    # [B,C,C,H,N]
        A = jnp.einsum("bihn,bjhn,bijhn->bhij", rc, kc,
                       jnp.exp(jnp.minimum(E, 0.0)))
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        A = jnp.where(mask[None, None], A, 0.0)
        diag = jnp.einsum("bchn,bchn,hn->bch", rc, kc, u)
        out = out + jnp.einsum("bhij,bjhn->bihn", A, vc) \
            + diag[..., None] * vc
        # state to next chunk
        decay_to_end = jnp.exp(la[:, -1][:, None] - la)        # [B,C,H,N]
        S = jnp.exp(la[:, -1])[..., None] * S \
            + jnp.einsum("bchn,bchm->bhnm", kc * decay_to_end, vc)
        return S, out

    S, outs = jax.lax.scan(chunk_step, S0.astype(f32), (rs, ks, vs, ws))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, H, N)
    return out, S


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _token_shift(x, prev):
    """prev: [B,d] carry of last token (zeros initially)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _tm_inputs(p, x, xx):
    mu = p["mu"].astype(jnp.float32)
    xf, xxf = x.astype(jnp.float32), xx.astype(jnp.float32)
    mix = lambda i: (xf + (xxf - xf) * mu[i]).astype(x.dtype)
    return mix(0), mix(1), mix(2), mix(3), mix(4)   # r,k,v,g,w inputs


def _last_real(x, lengths):
    """x [B,T,d], lengths [B] -> x at each row's last REAL position."""
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def time_mix(p, x, cfg, *, shift_prev, S0, chunk: int = 32, mask=None,
             lengths=None):
    """x: [B,T,d] (post-ln).  Returns (out, S_final, new_shift).

    ``mask``/``lengths`` make right-padding a state no-op: pad positions
    get decay w=1 and key k=0 (so S carries through unchanged) and the
    token-shift carry is taken at the last real position — the state a
    decode step resumes from is exactly the unpadded prompt's state.
    """
    B, T, d = x.shape
    H, N = cfg.n_heads, cfg.rwkv_head_dim
    xx = _token_shift(x, shift_prev)
    xr, xk, xv, xg, xw = _tm_inputs(p, x, xx)
    r = matmul(xr, p["wr"]).reshape(B, T, H, N)
    k = matmul(xk, p["wk"]).reshape(B, T, H, N)
    v = matmul(xv, p["wv"]).reshape(B, T, H, N)
    g = jax.nn.silu(matmul(xg, p["wg"]))
    dd = jnp.tanh(matmul(xw, p["wa1"]))
    dd = matmul(dd, p["wa2"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + dd)).reshape(B, T, H, N)
    if mask is not None:
        mm = mask[:, :, None, None]
        w = jnp.where(mm, w, 1.0)
        k = jnp.where(mm, k, 0.0)
    u = p["u"].astype(jnp.float32).reshape(H, N)
    if T == 1:
        out, S = wkv6_sequential(r, k, v, w, u, S0)
    else:
        out, S = wkv6_chunked(r, k, v, w, u, S0, chunk=chunk)
    out = out.reshape(B, T, d)
    # per-head groupnorm
    oh = out.reshape(B, T, H, N)
    mu_ = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    oh = (oh - mu_) * jax.lax.rsqrt(var + 64e-5)
    out = oh.reshape(B, T, d) * p["gn"]["w"].astype(jnp.float32) \
        + p["gn"]["b"].astype(jnp.float32)
    out = (out * g.astype(jnp.float32)).astype(x.dtype)
    carry = x[:, -1] if lengths is None else _last_real(x, lengths)
    return matmul(out, p["wo"]), S, carry


def channel_mix(p, x, *, shift_prev, lengths=None):
    xx = _token_shift(x, shift_prev)
    mu = p["mu"].astype(jnp.float32)
    xf, xxf = x.astype(jnp.float32), xx.astype(jnp.float32)
    xk = (xf + (xxf - xf) * mu[0]).astype(x.dtype)
    xr = (xf + (xxf - xf) * mu[1]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(matmul(xk, p["wk"])))
    out = jax.nn.sigmoid(matmul(xr, p["wr"])) * matmul(kk, p["wv"])
    carry = x[:, -1] if lengths is None else _last_real(x, lengths)
    return out, carry


def block_apply(p, x, cfg, *, state=None, chunk: int = 32, lengths=None):
    """One RWKV layer.  state: {"S","tm_x","cm_x"} or None (zeros).
    ``lengths`` [B]: real (un-padded) token count per row — pad positions
    leave the carried state untouched (see time_mix)."""
    B, T, d = x.shape
    H, N = cfg.n_heads, cfg.rwkv_head_dim
    if state is None:
        state = init_layer_state(cfg, B, x.dtype)
    mask = (None if lengths is None
            else jnp.arange(T, dtype=jnp.int32)[None, :] < lengths[:, None])
    h = L.norm(x, p["ln1"], cfg)
    a, S, tm_x = time_mix(p["tm"], h, cfg, shift_prev=state["tm_x"].astype(h.dtype),
                          S0=state["S"], chunk=chunk, mask=mask,
                          lengths=lengths)
    x = x + a
    h = L.norm(x, p["ln2"], cfg)
    m, cm_x = channel_mix(p["cm"], h, shift_prev=state["cm_x"].astype(h.dtype),
                          lengths=lengths)
    x = x + m
    return x, {"S": S, "tm_x": tm_x, "cm_x": cm_x}


def init_layer_state(cfg, batch: int, dtype=jnp.float32):
    H, N, d = cfg.n_heads, cfg.rwkv_head_dim, cfg.d_model
    return {"S": jnp.zeros((batch, H, N, N), jnp.float32),
            "tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype)}


# ---------------------------------------------------------------------------
# model-level API (mirrors transformer.py)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg, tokens, *, train: bool = False,
            remat: bool = True, capture: bool = False, **_):
    x = L.embed(params, cfg, tokens)

    def body(xc, p):
        cap = (xc,) if capture else ()
        xc, _ = block_apply(p, xc, cfg)
        xc = constrain(xc)
        return xc, (jnp.zeros((), jnp.float32), cap)

    sb = jax.checkpoint(body) if (remat and not capture) else body
    x, (auxs, caps) = jax.lax.scan(sb, x, params["blocks"][0],
                                   unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    aux = {"moe_aux": jnp.zeros((), jnp.float32)}
    if capture:
        aux["captures"] = {"blocks": [caps[0]], "tail": []}
        aux["final_hidden"] = x
    return logits, aux


def init_cache(cfg, batch: int, max_len: int, **_):
    """Recurrent state per layer, stacked along the scan axis."""
    one = init_layer_state(cfg, batch)
    return {"blocks": [jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)],
        "tail": []}


def decode_step(params: Params, cfg, cache, tokens, pos, *, max_len: int = 0):
    x = L.embed(params, cfg, tokens)          # [B,1,d]

    def body(xc, xs):
        p, st = xs
        xc, st2 = block_apply(p, xc, cfg, state=st)
        return xc, st2

    x, states = jax.lax.scan(body, x,
                             (params["blocks"][0], cache["blocks"][0]),
                             unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": [states], "tail": []}


def prefill(params: Params, cfg, tokens, *, max_len: int = 0, lengths=None,
            **_):
    x = L.embed(params, cfg, tokens)

    def body(xc, p):
        xc, st = block_apply(p, xc, cfg, lengths=lengths)
        xc = constrain(xc)
        return xc, st

    x, states = jax.lax.scan(jax.checkpoint(body), x, params["blocks"][0],
                             unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": [states], "tail": []}


def prefill_from(params: Params, cfg, cache, tokens, start, *,
                 max_len: int = 0, lengths=None):
    """Prefill the suffix ``tokens`` starting from the recurrent state in
    ``cache`` (a prefilled template prefix).  The WKV state is O(1) and
    position-free, so seeding is exact by construction: ``start`` is
    unused beyond the shared signature."""
    del start
    x = L.embed(params, cfg, tokens)

    def body(xc, xs):
        p, st = xs
        xc, st2 = block_apply(p, xc, cfg, state=st, lengths=lengths)
        xc = constrain(xc)
        return xc, st2

    x, states = jax.lax.scan(jax.checkpoint(body), x,
                             (params["blocks"][0], cache["blocks"][0]),
                             unroll=cfg.scan_unroll)
    x = L.norm(x, params["ln_f"], cfg)
    logits = L.unembed(params, cfg, x)
    return logits, {"blocks": [states], "tail": []}
