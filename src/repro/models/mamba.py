"""Mamba2 (SSD) block — scalar-per-head decay state-space layer.

    h_t = a_t h_{t-1} + dt_t * x_t (x) B_t        a_t = exp(-dt_t e^{A_h})
    y_t = C_t . h_t + D_h x_t

Chunked parallel form: with scalar per-head decay the pairwise factor
exp(la_i - la_j) <= 1 is a [C, C] matrix per head — exactly computable
and MXU-friendly (matmul with B/C/x), unlike RWKV6's per-channel case.
Decode carries h exactly (O(1) state).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import matmul

Params = Dict[str, Any]


def dims(cfg):
    d_inner = cfg.expand * cfg.d_model
    H = d_inner // cfg.ssd_head_dim
    return d_inner, H, cfg.ssd_head_dim, cfg.d_state


def init_layer(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 6)
    depth_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "ln": L.norm_init(d, dtype, cfg.norm_type),
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_ch))
                   * (1.0 / math.sqrt(cfg.conv_kernel))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(jnp.float32),
        "norm_y": {"w": jnp.ones((d_inner,), dtype)},
        "out_proj": L.dense_init(ks[3], d_inner, d, dtype, scale=depth_scale),
    }


def init_layer_state(cfg, batch: int, dtype):
    d_inner, H, P, N = dims(cfg)
    conv_ch = d_inner + 2 * N
    return {"h": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype)}


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_sequential(x, dt, a, Bm, Cm, D, h0):
    """Oracle.  x: [B,T,H,P]; dt,a: [B,T,H]; Bm,Cm: [B,T,N]; D: [H];
    h0: [B,H,P,N] -> (y [B,T,H,P], h_T)."""
    def step(h, xs):
        xt, dtt, at, bt, ct = xs
        upd = (dtt[..., None, None] * xt[..., None]) * bt[:, None, None, :]
        h = at[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct) + D[None, :, None] * xt
        return h, y

    xs = tuple(jnp.moveaxis(v, 1, 0).astype(jnp.float32)
               for v in (x, dt, a, Bm, Cm))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h


def ssd_chunked(x, dt, a, Bm, Cm, D, h0, chunk: int = 64):
    """Chunked parallel SSD (same semantics as ssd_sequential)."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    C = min(chunk, T)
    while T % C:
        C -= 1
    nc = T // C
    f32 = jnp.float32
    mv = lambda v: jnp.moveaxis(v.reshape(B, nc, C, *v.shape[2:]), 1, 0).astype(f32)
    xs_, dts, as_, bs, cs = mv(x), mv(dt), mv(a), mv(Bm), mv(Cm)

    def chunk_step(h, xs):
        xc, dtc, ac, bc, cc = xs                      # [B,C,H,P] / [B,C,H] / [B,C,N]
        la = jnp.cumsum(jnp.maximum(jnp.log(jnp.maximum(ac, 1e-30)), -60.0),
                        axis=1)                                     # [B,C,H]
        # inter: state from previous chunks
        y = jnp.einsum("bcn,bhpn->bchp", cc, h) * jnp.exp(la)[..., None]
        # intra: causal pairwise within chunk (j <= i)
        scores = jnp.einsum("bin,bjn->bij", cc, bc)                # [B,C,C]
        ladiff = la[:, :, None] - la[:, None, :]                   # [B,C,C,H]
        mask = jnp.tril(jnp.ones((C, C), bool))
        A = scores[..., None] * jnp.exp(jnp.minimum(ladiff, 0.0)) \
            * dtc[:, None, :, :]
        A = jnp.where(mask[None, :, :, None], A, 0.0)              # [B,C,C,H]
        y = y + jnp.einsum("bijh,bjhp->bihp", A, xc)
        y = y + D[None, None, :, None] * xc
        # state update
        dec = jnp.exp(la[:, -1][:, None] - la)                     # [B,C,H]
        upd = jnp.einsum("bchp,bcn->bhpn", xc * (dtc * dec)[..., None], bc)
        h = jnp.exp(la[:, -1])[..., None, None] * h + upd
        return h, y

    h, ys = jax.lax.scan(chunk_step, h0.astype(f32), (xs_, dts, as_, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    return y, h


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def _conv1d(x, w, b, conv_state, lengths=None):
    """Causal depthwise conv.  x: [B,T,ch]; w: [K,ch]; conv_state: [B,K-1,ch].

    ``lengths`` [B]: with right-padded rows the carried conv window must
    hold the last K-1 REAL inputs (possibly reaching back into the
    incoming ``conv_state``), not the padding tail.
    """
    K = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    if lengths is None:
        new_state = xp[:, xp.shape[1] - (K - 1):]
    else:
        # real inputs occupy xp[:, K-1 : K-1+len); the window of the
        # last K-1 real inputs starts at index len
        idx = lengths[:, None] + jnp.arange(K - 1, dtype=jnp.int32)[None]
        new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return out + b[None, None], new_state


def stack_apply(stacked_params, states, x, cfg, *, chunk: int = 64,
                lengths=None):
    """Apply K layer-stacked mamba blocks (param/state leaves carry a
    leading [K] axis) sequentially from ``states``, returning the output
    and the re-stacked new states.  This is the cache-seeding primitive:
    callers (hybrid prefill/decode, prefix-cache continued prefill) hand
    in carried states instead of zeros and the recurrence resumes
    exactly where the stored prefix left off."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    new_states = []
    for u in range(K):
        p = jax.tree.map(lambda a: a[u], stacked_params)
        st = jax.tree.map(lambda a: a[u], states)
        x, st2 = block_apply(p, x, cfg, state=st, chunk=chunk,
                             lengths=lengths)
        new_states.append(st2)
    return x, jax.tree.map(lambda *a: jnp.stack(a), *new_states)


def block_apply(p, x, cfg, *, state=None, chunk: int = 64, lengths=None):
    """One Mamba2 block with residual.  x: [B,T,d].  ``lengths`` [B]
    makes right-padding a state no-op: pad positions get dt=0 (so the
    decay a=exp(-dt·e^A)=1 freezes h) and the conv window carries the
    last real inputs — decode resumes from the unpadded prompt's state."""
    B, T, d = x.shape
    d_inner, H, P, N = dims(cfg)
    if state is None:
        state = init_layer_state(cfg, B, x.dtype)
    h_in = L.norm(x, p["ln"], cfg)
    proj = matmul(h_in, p["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    xbc, conv_state = _conv1d(xbc, p["conv_w"], p["conv_b"], state["conv"],
                              lengths=lengths)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    if lengths is not None:
        mask = jnp.arange(T, dtype=jnp.int32)[None, :] < lengths[:, None]
        dt = dt * mask[:, :, None]
    a = jnp.exp(-dt * jnp.exp(p["A_log"])[None, None])
    xh = xs.reshape(B, T, H, P)
    if T == 1:
        y, h_new = ssd_sequential(xh, dt, a, Bm, Cm, p["D"], state["h"])
    else:
        y, h_new = ssd_chunked(xh, dt, a, Bm, Cm, p["D"], state["h"], chunk=chunk)
    y = y.reshape(B, T, d_inner)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = L.rmsnorm(y, p["norm_y"])
    out = matmul(y, p["out_proj"])
    return x + out, {"h": h_new, "conv": conv_state}
