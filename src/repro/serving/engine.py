"""Serving engine: asynchronous continuous batching over fixed decode slots.

TPU-adapted vLLM-style serving (see README.md in this package): XLA
wants static shapes, so the engine keeps a **fixed set of decode
slots**.  Families that support it (``api.supports_paged``) store KV in
a **paged layout**: one global pool of fixed-size blocks shared by all
slots plus a per-slot block table, so admission scatters per-row
prefill KV into table-addressed blocks and a shared template prefix is
seeded once and *aliased* by every row's table instead of copied —
decode attends through the table (reference gather or the paged Pallas
kernel, per the engine's ``KernelBackend``).  Other families — and
sharded/mesh engines — keep the contiguous layout: stacked per-row
state with a leading slot axis, decode as ``vmap`` of the model's
single-row decode.  Either way slot admission is ONE jitted batched
scatter for the whole admission batch, compiled once per admission
width, and both layouts produce byte-identical greedy outputs
(tests/test_paged_cache.py).

The engine is an async core with three entry points:

  ``submit(text)``  enqueue a request; duplicate prompts attach as
                    followers to an in-flight leader (queued OR already
                    decoding) and never touch a slot; finished prompts
                    short-circuit through the result cache.
  ``step()``        one engine tick: admit a batch into free slots
                    (one bucketed prefill + one batched insert), run one
                    vmapped decode step for all slots, retire rows that
                    hit EOS / max_new.  Returns requests finished this
                    tick — callers may keep ``submit()``-ing between
                    ticks while decode is in flight.
  ``drain()``       tick until queue and slots are empty.

``step()`` is internally split into ``step_begin()`` (admit + launch
the tick's decode, without blocking on its result) and
``step_finish()`` (block, retire).  A multi-device scheduler uses the
split directly: it calls ``step_begin()`` on every engine first —
XLA dispatch is asynchronous, so decode steps of engines **placed on
distinct devices** execute concurrently — and only then collects with
``step_finish()``.  ``step() == step_finish(step_begin())``, so the
serial path is unchanged.

Placement: ``Engine(..., device=d)`` commits the params (and all slot
state) to one jax device, so a ``ModelPool`` can spread its resident
fleet over ``jax.devices()``.  ``Engine(..., mesh=m)`` instead shards
the params with the DP/TP rules of ``distributed/sharding.py``
(``param_shardings=``/``cache_shardings=`` override them) — the
tensor-parallel path for a model too big for one device.  Both default
to ``None`` ≡ the historical single-implicit-device behavior.

``generate(texts)`` is the synchronous convenience wrapper
(submit-all + drain) used by the benchmarks.

Sampling is part of the jitted decode step: a static ``SamplingConfig``
(greedy / temperature / top-k, see sampler.py) is closed over at
compile time and a PRNG key derived from ``fold_in(base, step_counter)``
is threaded through, so ``temperature=0`` lowers to exactly the old
``jnp.argmax`` decode.

The result cache (cache.py) short-circuits duplicate rows before they
ever reach a slot, and the instance-optimized (compressed) model drops
in transparently because every linear goes through compressed.matmul.

Template-heavy OLAP prompts additionally share one prefilled prompt
prefix: ``submit(text, prefix=template)`` splits the prompt at the
template boundary, a ``PrefixCache`` stores the template's prefilled
state once per (template, model version), and admission seeds every
row's slot state from it so per-row prefill processes only the row
suffix (see README.md §Prefix-sharing KV cache).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressed import kernel_backend
from repro.kernels.backend import resolve_backend
from repro.models import api
from repro.serving.batcher import Batcher, Request, bucket_len
from repro.serving.cache import PrefixCache, ResultCache
from repro.serving.paged import BlockTableAllocator
from repro.serving.sampler import SamplingConfig, sample, token_confidence
from repro.training.data import ByteTokenizer

# Default bound on un-finished requests resident during generate_stream;
# the single source for the streaming chunk (olap operators import it).
DEFAULT_CHUNK = 64


@dataclass
class EngineStats:
    rows: int = 0
    tokens_out: int = 0
    prefills: int = 0
    decode_steps: int = 0
    cache_hits: int = 0
    truncated: int = 0           # prompts clipped to the top bucket
    peak_inflight: int = 0       # max queued+active requests ever resident
    busy_slot_steps: int = 0     # slot-steps that decoded a live row
    total_slot_steps: int = 0    # slot-steps executed (busy + idle)
    prefix_hits: int = 0         # rows seeded from a shared prefix state
    prefill_tokens: int = 0      # padded prompt tokens actually prefilled
    prefill_tokens_saved: int = 0  # prefix tokens NOT re-prefilled per row
    backend: str = ""            # resolved KernelBackend ("reference"/"pallas")
    kv_blocks_in_use: int = 0    # peak KV blocks reachable (paged layout)
    kv_blocks_shared: int = 0    # peak blocks aliased by >1 slot (paged)
    confidence_sum: float = 0.0  # sum of per-row min answer-token prob
    confidence_rows: int = 0     # rows with a finite confidence signal
    wall_s: float = 0.0

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.wall_s if self.wall_s else 0.0

    @property
    def mean_confidence(self) -> float:
        """Mean per-row cascade confidence (min answer-token probability
        over the row's emitted tokens) across finished rows."""
        return (self.confidence_sum / self.confidence_rows
                if self.confidence_rows else 0.0)

    @property
    def slot_utilization(self) -> float:
        """Fraction of decode-step slot work spent on live rows."""
        return (self.busy_slot_steps / self.total_slot_steps
                if self.total_slot_steps else 0.0)


class StepPending(NamedTuple):
    """Handle between ``step_begin`` and ``step_finish``: the requests
    already finished at admission, plus the launched decode's output
    arrays — a ``(tokens, confidences)`` pair straight out of the jitted
    step — or ``None`` when this tick dispatched no decode (empty
    slots), so schedulers can tell real in-flight work from a no-op."""
    finished: List["Request"]
    nxt: Any


class Engine:
    def __init__(self, params, cfg, *, tokenizer: Optional[ByteTokenizer] = None,
                 slots: int = 8, max_len: int = 256,
                 buckets: Sequence[int] = (32, 64, 128),
                 use_result_cache: bool = True, version: str = "base",
                 use_prefix_cache: bool = True,
                 prefix_cache: Optional[PrefixCache] = None,
                 extra_inputs: Optional[Dict] = None,
                 sampling: Optional[SamplingConfig] = None,
                 device=None, mesh=None,
                 param_shardings=None, cache_shardings=None,
                 backend: str = "auto", kv_layout: str = "auto",
                 kv_block_size: int = 32):
        if device is not None and mesh is not None:
            raise ValueError("pass device= (single-device placement) OR "
                             "mesh= (sharded), not both")
        if kv_layout not in ("auto", "paged", "contiguous"):
            raise ValueError(f"kv_layout must be auto/paged/contiguous, "
                             f"got {kv_layout!r}")
        # KernelBackend is resolved once per engine ("auto" -> pallas on
        # TPU, reference elsewhere) and scoped around every jit trace
        # site via kernel_backend() — no process-global flag.
        self.backend = resolve_backend(backend)
        self.device = device
        self.mesh = mesh
        self._cache_shardings = cache_shardings
        if mesh is not None:
            from repro.distributed import sharding as SH
            if param_shardings is None:
                param_shardings = SH.param_shardings(cfg, params, mesh)
            params = jax.device_put(params, param_shardings)
            # distinct placements must never share prefilled state: a
            # re-admitted model on a different device would hand jit
            # operands committed to two devices.  The tag keys the
            # prefix cache per placement (same-placement re-admission
            # still reuses entries).
            self._placement_tag = ("@mesh" + "x".join(
                str(s) for s in mesh.devices.shape) + ":" + ",".join(
                str(d.id) for d in mesh.devices.flat))
        elif device is not None:
            params = jax.device_put(params, device)
            self._placement_tag = f"@{device.platform}:{device.id}"
        else:
            self._placement_tag = ""
        self.params = params
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer(max(cfg.vocab_size, 260))
        self.slots = slots
        self.max_len = max_len
        # Bucket ladder invariants: non-empty, strictly below max_len (a
        # prompt filling the whole cache leaves no room to decode), sorted,
        # deduplicated.  Out-of-range user buckets clamp instead of vanish.
        cap = max(1, max_len - 1)
        ladder = sorted({min(int(b), cap) for b in buckets if int(b) > 0})
        self.buckets = tuple(ladder) or (cap,)
        self.result_cache = ResultCache() if use_result_cache else None
        self.version = version
        # prefix sharing needs a family that can seed per-row state from a
        # stored prompt prefix, and no extra per-row inputs (img/enc) that
        # would sit ahead of the text tokens.  ``prefix_cache`` lets a
        # ModelPool share ONE cache across its resident engines — entries
        # stay isolated per model because every key includes the engine's
        # version (scheduler.py; leak-tested in tests/test_scheduler.py).
        self.prefix_cache = (
            (prefix_cache if prefix_cache is not None else PrefixCache())
            if use_prefix_cache and api.supports_prefix(cfg)
            and not (extra_inputs or {}) else None)
        self._prefix_ids_memo: Dict[str, tuple] = {}
        self.batcher = Batcher(self.buckets)
        self.stats = EngineStats()
        self.stats.backend = self.backend
        self.sampling = sampling or SamplingConfig()
        self._rid = 0
        self.extra_inputs = extra_inputs or {}

        # --- KV layout: paged (block pool + per-slot table) vs contiguous ---
        # Paged needs a family with positional KV in the standard layout
        # and an unsharded cache (mesh/cache_shardings keep the stacked
        # layout — block gathers would defeat the sharding rules).  The
        # block size is the largest power of two <= kv_block_size that
        # divides max_len; "auto" falls back to contiguous when that
        # degenerates below 8 positions per block.
        bs = 1
        while bs * 2 <= kv_block_size and max_len % (bs * 2) == 0:
            bs *= 2
        want_paged = (kv_layout != "contiguous" and api.supports_paged(cfg)
                      and mesh is None and cache_shardings is None
                      and not (kv_layout == "auto" and bs < 8))
        self._paged = want_paged
        self._block_size = bs if want_paged else 0
        self._seed = None
        self._alloc = None
        self._tables_dev = None
        self._tables_dirty = True
        if self._paged:
            self._alloc = BlockTableAllocator(slots, max_len // bs)
            if self.prefix_cache is not None:
                self.prefix_cache.add_evict_listener(self._on_prefix_evict)

        # async serving state -------------------------------------------
        self._active: Dict[int, Request] = {}           # slot -> request
        self._leaders: Dict[tuple, Request] = {}        # in-flight dedup
        self._followers: Dict[tuple, List[Request]] = {}
        self._cur_tok = np.zeros((self.slots,), np.int32)
        self._cur_pos = np.zeros((self.slots,), np.int32)
        self._key = jax.random.PRNGKey(self.sampling.seed)
        # PRNG stream positions are private state, NOT stats: resetting
        # engine.stats must never replay sampled tokens
        self._admit_ctr = 0
        self._decode_ctr = 0

        # --- jit'd single-row prefill, vmapped over the admission batch ---
        # ln is the row's REAL token count: recurrent families must not
        # absorb the bucket's right-padding into their carried state
        def row_prefill(params, toks, ln):
            batch = {"tokens": toks[None]}
            batch.update({k: v[None] for k, v in self.extra_inputs.items()})
            logits, cache = api.prefill(params, cfg, batch,
                                        max_len=max_len, compact_local=False,
                                        lengths=ln[None])
            return logits[0], cache

        self._prefill = {}
        for b in self.buckets:
            self._prefill[b] = jax.jit(
                jax.vmap(row_prefill, in_axes=(None, 0, 0)))

        # --- suffix-only prefill seeded from a shared prefix state ---
        # prefix_state is the batch=1 cache pytree of the prefilled
        # template prefix, broadcast (in_axes=None) to every admitted
        # row; each row processes only its suffix tokens and returns a
        # fully-populated per-row state for the batched slot insert.
        def row_prefill_from(params, prefix_state, toks, plen, ln):
            logits, cache = api.prefill_from(params, cfg, prefix_state,
                                             toks[None], plen,
                                             max_len=max_len,
                                             lengths=ln[None])
            return logits[0], cache

        self._prefill_from = {}
        if self.prefix_cache is not None:
            for b in self.buckets:
                self._prefill_from[b] = jax.jit(
                    jax.vmap(row_prefill_from,
                             in_axes=(None, None, 0, None, 0)))

        sampling_cfg = self.sampling  # static: closed over at trace time

        if self._paged:
            # --- paged admission scatter + prefix seeding + decode ---
            # write_ids [n, max_len // bs] name the destination block per
            # KV chunk (trash ids suppress chunks covered by aliased
            # prefix blocks); recurrent rows scatter at slot_idxs.
            blk = self._block_size

            def insert(slot_state, row_states, slot_idxs, write_ids):
                return api.paged_insert(cfg, slot_state, row_states,
                                        slot_idxs, write_ids, block_size=blk)

            self._insert = jax.jit(insert, donate_argnums=(0,))

            def seed(slot_state, entry_state, write_ids):
                return api.paged_seed(cfg, slot_state, entry_state,
                                      write_ids, block_size=blk)

            self._seed = jax.jit(seed, donate_argnums=(0,))

            # decode runs batched over ALL slots (the block pool is
            # shared, so the per-row vmap of the contiguous path does
            # not apply) and attends through the block tables
            def step(params, slot_state, tables, toks, pos, ctr):
                logits, state = api.paged_decode_step(
                    params, cfg, slot_state, tables, toks[:, None], pos,
                    block_size=blk, max_len=max_len, backend=self.backend)
                key = jax.random.fold_in(self._key, ctr)
                nxt = sample(logits[:, -1], key,
                             temperature=sampling_cfg.temperature,
                             top_k=sampling_cfg.top_k)
                # cascade confidence, from arrays already live in the
                # jitted step — no host callback (jit_audit JIT001)
                conf = token_confidence(logits[:, -1], nxt)
                return nxt, conf, state

            self._decode = jax.jit(step, donate_argnums=(1,))
        else:
            # --- batched slot-state scatter (uniform leading axis) ---
            # row_states carry the vmapped admission axis in front; one
            # call scatters the whole admission batch into its free slots.
            def insert(slot_state, row_states, slot_idxs):
                return jax.tree.map(
                    lambda s, r: s.at[slot_idxs].set(r.astype(s.dtype)),
                    slot_state, row_states)

            self._insert = jax.jit(insert, donate_argnums=(0,))

            # --- vmapped decode step over slots, sampling fused in ---
            def row_decode(params, cache, tok, pos):
                logits, cache = api.decode_step(params, cfg, cache,
                                                tok[None, None], pos[None],
                                                max_len=max_len)
                return logits[0, -1], cache

            def step(params, slot_state, toks, pos, ctr):
                logits, state = jax.vmap(
                    row_decode, in_axes=(None, 0, 0, 0))(params, slot_state,
                                                         toks, pos)
                key = jax.random.fold_in(self._key, ctr)
                nxt = sample(logits, key,
                             temperature=sampling_cfg.temperature,
                             top_k=sampling_cfg.top_k)
                # cascade confidence, from arrays already live in the
                # jitted step — no host callback (jit_audit JIT001)
                conf = token_confidence(logits, nxt)
                return nxt, conf, state

            self._decode = jax.jit(step, donate_argnums=(1,))
        self._slot_state = None

    # ------------------------------------------------------------------
    def _init_slots(self):
        if self._paged:
            state = api.init_paged_cache(self.cfg, self.slots,
                                         self._alloc.num_blocks,
                                         self._block_size)
            if self.device is not None:
                state = jax.device_put(state, self.device)
            self._slot_state = state
            return
        one = api.init_cache(self.cfg, 1, self.max_len, compact_local=False)
        state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.slots,) + a.shape).copy(),
            one)
        if self.mesh is not None:
            if self._cache_shardings is None:
                from repro.distributed import sharding as SH
                shapes = jax.eval_shape(lambda: state)
                self._cache_shardings = SH.cache_shardings(
                    self.cfg, shapes, self.mesh)
            state = jax.device_put(state, self._cache_shardings)
        elif self.device is not None:
            state = jax.device_put(state, self.device)
        self._slot_state = state

    # -- paged block-table plumbing -------------------------------------
    def _tables(self):
        """Device mirror of the allocator's block tables, refreshed only
        when host-side bookkeeping changed since the last decode."""
        if self._tables_dirty or self._tables_dev is None:
            t = jnp.asarray(self._alloc.tables)
            if self.device is not None:
                t = jax.device_put(t, self.device)
            self._tables_dev = t
            self._tables_dirty = False
        return self._tables_dev

    def _on_prefix_evict(self, key, entry) -> None:
        """PrefixCache eviction: release the cache's reference on the
        entry's shared blocks (slots still aliasing them keep them
        pinned until they retire)."""
        self._alloc.drop_prefix(key)

    def _release_slot(self, s: int) -> None:
        if self._paged:
            self._alloc.release(s)
            self._tables_dirty = True

    def _paged_admit_ids(self, slot_idxs, pk, plen, entry):
        """Block-table bookkeeping for one admission wave.

        Seeds the prefix's FULL blocks into shared storage on first
        sight (partial tail blocks stay private — the per-row prefill
        state covers them), points every admitted row's table at the
        shared prefix + its private remainder, and returns the
        [n, nblk] write-id matrix for the jitted KV scatter, with
        aliased chunks aimed at the trash block."""
        A = self._alloc
        shared = None
        if pk is not None:
            n_full = plen // self._block_size
            shared = A.lookup(pk)
            if shared is None and n_full:
                shared = A.seed_blocks(pk, n_full)
                if shared is not None:
                    w = np.full((1, A.nblk), A.trash, np.int32)
                    w[0, :n_full] = shared
                    self._slot_state = self._seed(
                        self._slot_state, entry.state, jnp.asarray(w))
        w_ids = np.empty((len(slot_idxs), A.nblk), np.int32)
        for i, s in enumerate(slot_idxs):
            s = int(s)
            w_ids[i] = A.private(s)
            if shared is not None and len(shared):
                A.alias(s, pk)
                w_ids[i, :len(shared)] = A.trash
            else:
                A.occupy(s)
        self._tables_dirty = True
        return w_ids

    # -- async API ------------------------------------------------------
    def _encode_prefix(self, prefix: str):
        """Memoized template encode: the prefix is identical across an
        operator's whole row stream, so the per-row hot path must not
        re-encode it (or rebuild its cache-key tuple) per submit."""
        hit = self._prefix_ids_memo.get(prefix)
        if hit is None:
            p_ids = self.tok.encode(prefix, bos=True)
            hit = (p_ids, self.prefix_cache.key(
                p_ids, self.version + self._placement_tag))
            self._prefix_ids_memo[prefix] = hit
        return hit

    def _split_prefix(self, text: str, prefix: Optional[str]):
        """(prefix_ids, suffix_ids, prefix_key) when the shared-template
        split is usable, else (None, full_ids, None).  The byte
        tokenizer concatenates (enc(a+b) == enc(a)+enc(b)), so splitting
        at the template boundary preserves the exact token stream; the
        split is refused whenever the full prompt would have been
        clipped to the top bucket (truncation semantics — and outputs —
        stay byte-identical to the full-prompt path) or the stacked
        prefix+suffix bucket would not leave a decode slot below
        max_len."""
        if (prefix is not None and self.prefix_cache is not None
                and len(text) > len(prefix) and text.startswith(prefix)):
            p_ids, pkey = self._encode_prefix(prefix)
            s_ids = self.tok.encode(text[len(prefix):]) + [self.tok.SEP]
            if len(p_ids) + len(s_ids) <= self.buckets[-1] \
                    and len(p_ids) + bucket_len(len(s_ids), self.buckets) \
                    <= self.max_len - 1:
                return p_ids, s_ids, pkey
            # token stream of the refused split == the full encode
            return None, p_ids + s_ids, None
        return None, self.tok.encode(text, bos=True) + [self.tok.SEP], None

    def submit(self, text: str, *, max_new: int = 32,
               prefix: Optional[str] = None) -> Request:
        """Enqueue one request; resolves immediately on a cache hit and
        attaches as a follower when its prompt is already in flight.
        ``prefix`` marks the shared template prefix of ``text`` (operators
        pass their prompt template): rows sharing it are prefilled from
        one cached prefix state and bucketed on their suffix only."""
        prefix_ids, ids, pkey = self._split_prefix(text, prefix)
        req = Request(rid=self._rid, prompt_ids=ids, max_new=max_new,
                      src=text)
        if prefix_ids is not None:
            req.prefix_ids = prefix_ids
            req.prefix_key = pkey
        self._rid += 1
        if self.result_cache is not None:
            req.cache_key = self.result_cache.key(text, max_new, self.version)
            hit = self.result_cache.peek(req.cache_key)
            if hit is not None:
                # cache values are (text, confidence) pairs so cascade
                # acceptance survives the dedup short-circuit
                text, conf = hit
                self.result_cache.record_hit(req.cache_key)
                self.stats.cache_hits += 1
                req.out_ids = self.tok.encode(text)
                req.confidence = conf
                self._finalize(req, text)
                return req
            if req.cache_key in self._leaders:
                # duplicate of a queued OR actively decoding request:
                # ride on the leader, never touch a slot.  Exactly one
                # cache accounting event (a hit) for this lookup.
                self.result_cache.record_hit(req.cache_key)
                self.stats.cache_hits += 1
                req.follower = True
                self._followers.setdefault(req.cache_key, []).append(req)
                req.prompt_ids = []
                return req
            self.result_cache.record_miss()
            self._leaders[req.cache_key] = req
        self.batcher.add(req)
        inflight = len(self.batcher) + len(self._active)
        self.stats.peak_inflight = max(self.stats.peak_inflight, inflight)
        return req

    def step(self) -> List[Request]:
        """One engine tick (admit -> decode -> retire); returns the
        requests that finished during this tick."""
        return self.step_finish(self.step_begin())

    def step_begin(self):
        """First half of a tick: admit a batch and LAUNCH the decode
        step, without blocking on its result (XLA dispatch is async —
        the returned handle's arrays are still being computed).  Pair
        each call with exactly one ``step_finish(handle)`` before the
        next ``step_begin``; the multi-device scheduler dispatches
        ``step_begin`` on every engine (distinct devices then compute
        concurrently) before collecting any of them."""
        # every jit trace under this tick dispatches compressed matmuls
        # (and paged attention) on THIS engine's backend
        with kernel_backend(self.backend):
            return self._step_begin()

    def _step_begin(self):
        if self._slot_state is None:
            self._init_slots()
        finished: List[Request] = []
        free = [s for s in range(self.slots) if s not in self._active]
        # --- admit: one bucketed prefill + ONE batched slot insert ---
        if free and len(self.batcher):
            take = self.batcher.take(len(free))
            if take:
                top = self.buckets[-1]
                for r in take:
                    if len(r.prompt_ids) > top:
                        r.truncated = True
                        self.stats.truncated += 1
                b = bucket_len(max(len(r.prompt_ids) for r in take),
                               self.buckets)
                toks = np.zeros((len(take), b), np.int32)
                for i, r in enumerate(take):
                    ids = r.prompt_ids[-b:]
                    toks[i, :len(ids)] = ids
                lens = np.array([min(len(r.prompt_ids), b) for r in take])
                pk = take[0].prefix_key     # uniform across the batch
                if pk is not None:
                    # seed every row from the shared prefilled prefix and
                    # prefill only the suffixes.  A fresh entry costs one
                    # prefix-length prefill; every other row in this and
                    # all later admissions skips it entirely.
                    entry = self.prefix_cache.get(pk)
                    fresh = entry is None
                    if fresh:
                        entry = self._build_prefix_entry(
                            pk, take[0].prefix_ids)
                    plen = entry.prefix_len
                    logits, rows = self._prefill_from[b](
                        self.params, entry.state, jnp.asarray(toks),
                        jnp.int32(plen), jnp.asarray(lens, jnp.int32))
                    seeded = len(take) - (1 if fresh else 0)
                    entry.hits += seeded
                    self.stats.prefix_hits += seeded
                    self.stats.prefill_tokens_saved += plen * seeded
                else:
                    plen = 0
                    entry = None
                    logits, rows = self._prefill[b](
                        self.params, jnp.asarray(toks),
                        jnp.asarray(lens, jnp.int32))
                self.stats.prefills += 1
                self.stats.prefill_tokens += len(take) * b
                # rows are right-padded: gather each row's logits at its
                # last REAL position, not at the padding tail
                last_logits = jnp.take_along_axis(
                    logits, jnp.asarray(lens - 1)[:, None, None],
                    axis=1)[:, 0]
                # per-wave key: fold in a counter that advances every
                # admission so successive waves draw independent samples
                # (mirrors the decode path's per-step fold_in)
                self._admit_ctr += 1
                admit_key = (jax.random.fold_in(self._key,
                                                self._admit_ctr + (1 << 30))
                             if self.sampling.temperature > 0 else None)
                first_dev = sample(
                    last_logits, admit_key,
                    temperature=self.sampling.temperature,
                    top_k=self.sampling.top_k)
                first = np.asarray(first_dev).astype(np.int32)
                # first token is sampled off the prefill logits (outside
                # the decode loop), so its confidence is computed here too
                first_conf = np.asarray(
                    token_confidence(last_logits, first_dev), np.float64)
                slot_idxs = np.asarray(free[:len(take)], np.int32)
                if self._paged:
                    w_ids = self._paged_admit_ids(slot_idxs, pk, plen, entry)
                    self._slot_state = self._insert(
                        self._slot_state, rows, jnp.asarray(slot_idxs),
                        jnp.asarray(w_ids))
                else:
                    self._slot_state = self._insert(
                        self._slot_state, rows, jnp.asarray(slot_idxs))
                for i, r in enumerate(take):
                    s = int(slot_idxs[i])
                    t0 = int(first[i])
                    r.out_ids.append(t0)
                    r.confidence = min(r.confidence, float(first_conf[i]))
                    if t0 == self.tok.EOS or len(r.out_ids) >= r.max_new:
                        # prefill token already ends the row (EOS) or
                        # exhausts the budget: retire without ever
                        # occupying a decode slot
                        self._release_slot(s)
                        finished.extend(self._retire(r))
                        continue
                    self._active[s] = r
                    self._cur_tok[s] = t0
                    self._cur_pos[s] = plen + int(lens[i])
        if not self._active:
            return StepPending(finished, None)
        # --- decode one token for every active slot (launch only) ---
        if self._paged:
            used, sh = self._alloc.stats()
            self.stats.kv_blocks_in_use = max(self.stats.kv_blocks_in_use,
                                              used)
            self.stats.kv_blocks_shared = max(self.stats.kv_blocks_shared, sh)
            nxt, conf, self._slot_state = self._decode(
                self.params, self._slot_state, self._tables(),
                jnp.asarray(self._cur_tok), jnp.asarray(self._cur_pos),
                jnp.int32(self._decode_ctr))
        else:
            nxt, conf, self._slot_state = self._decode(
                self.params, self._slot_state, jnp.asarray(self._cur_tok),
                jnp.asarray(self._cur_pos), jnp.int32(self._decode_ctr))
        self._decode_ctr += 1
        self.stats.decode_steps += 1
        self.stats.busy_slot_steps += len(self._active)
        self.stats.total_slot_steps += self.slots
        return StepPending(finished, (nxt, conf))

    def step_finish(self, pending: StepPending) -> List[Request]:
        """Second half of a tick: block on the launched decode, then
        retire/advance every active slot.  Returns all requests that
        finished during the whole tick (admission-retired + decoded)."""
        finished, nxt = pending
        if nxt is None:
            return finished
        nxt, conf = nxt
        nxt = np.asarray(nxt)
        conf = np.asarray(conf)
        # --- retire / advance ---
        for s in list(self._active):
            r = self._active[s]
            t = int(nxt[s])
            r.out_ids.append(t)
            r.confidence = min(r.confidence, float(conf[s]))
            self._cur_tok[s] = t
            self._cur_pos[s] += 1
            if t == self.tok.EOS or len(r.out_ids) >= r.max_new \
                    or self._cur_pos[s] >= self.max_len - 1:
                del self._active[s]
                self._release_slot(s)
                finished.extend(self._retire(r))
        return finished

    def has_work(self) -> bool:
        """True while any request is queued or actively decoding — the
        scheduler's cheap should-I-tick-this-engine probe (a bare
        ``step()`` on an idle engine would still allocate slot state)."""
        return bool(len(self.batcher) or self._active)

    def drain(self) -> List[Request]:
        """Tick until every queued and active request has finished."""
        finished: List[Request] = []
        while self.has_work():
            finished.extend(self.step())
        return finished

    # -- introspection --------------------------------------------------
    def jit_targets(self) -> Dict[str, object]:
        """Every jitted callable on the tick hot path, by stable name —
        the surface the static auditor (analysis/jit_audit.py) wraps
        and the jit-cache accounting in tests keys on.  Bucket-laddered
        targets are suffixed ``[bucket]``."""
        out: Dict[str, object] = {"_insert": self._insert,
                                  "_decode": self._decode}
        if self._seed is not None:
            out["_seed"] = self._seed
        for b, fn in self._prefill.items():
            out[f"_prefill[{b}]"] = fn
        for b, fn in self._prefill_from.items():
            out[f"_prefill_from[{b}]"] = fn
        return out

    # -- prefix sharing -------------------------------------------------
    def _build_prefix_entry(self, key, prefix_ids):
        """One-time prefill of a template prefix (batch=1, absolute
        slots); the stored state seeds every row that shares it.  Runs
        eagerly: once per (template, version), off the jit hot path."""
        toks = jnp.asarray(np.asarray(prefix_ids, np.int32)[None])
        _, cache = api.prefill(self.params, self.cfg, {"tokens": toks},
                               max_len=self.max_len, compact_local=False)
        self.stats.prefills += 1
        self.stats.prefill_tokens += len(prefix_ids)
        return self.prefix_cache.put(key, cache, len(prefix_ids))

    # -- completion plumbing -------------------------------------------
    def _retire(self, req: Request) -> List[Request]:
        """Finalize a decoded leader plus any followers riding on it;
        returns every request completed by this retirement."""
        text = self.tok.decode([t for t in req.out_ids if t != self.tok.EOS])
        done = [req]
        if self.result_cache is not None and req.cache_key is not None:
            self.result_cache.put(req.cache_key, (text, req.confidence))
            self._leaders.pop(req.cache_key, None)
            for f in self._followers.pop(req.cache_key, []):
                f.out_ids = list(req.out_ids)
                f.confidence = req.confidence
                self._finalize(f, text)
                done.append(f)
        self._finalize(req, text)
        return done

    def _finalize(self, req: Request, text: str) -> None:
        req.text = text
        req.done = True
        req.prompt_ids = []      # drop prompt residency as soon as possible
        self.stats.rows += 1
        self.stats.tokens_out += len(req.out_ids)
        if np.isfinite(req.confidence):
            self.stats.confidence_sum += req.confidence
            self.stats.confidence_rows += 1

    # -- synchronous convenience wrappers ------------------------------
    def generate(self, texts: Sequence[str], *, max_new: int = 32,
                 prefix: Optional[str] = None) -> List[str]:
        """Continuous-batching run over all texts; returns decoded rows."""
        t0 = time.time()
        reqs = [self.submit(t, max_new=max_new, prefix=prefix)
                for t in texts]
        self.drain()
        self.stats.wall_s += time.time() - t0
        return [r.text for r in reqs]

    def generate_stream(self, prompts, *, max_new: int = 32,
                        chunk: int = DEFAULT_CHUNK,
                        prefix: Optional[str] = None,
                        return_requests: bool = False):
        """The streaming operator contract: consume ``prompts`` (any
        iterable) lazily, keeping at most ``chunk`` of THIS call's
        requests un-finished at a time — decode ticks overlap with
        prompt construction, and peak prompt residency is bounded by
        ``chunk + slots`` instead of the prompt count.  Requests
        submitted outside this call are ignored by the throttle (their
        completions don't loosen the bound).  Returns decoded rows in
        prompt order; ``return_requests=True`` returns the finished
        ``Request`` objects instead so the cascade path can read the
        per-row confidence next to the text."""
        t0 = time.time()
        reqs: List[Request] = []
        inflight = set()                  # queued/active rids owned here
        for p in prompts:
            r = self.submit(p, max_new=max_new, prefix=prefix)
            reqs.append(r)
            # followers hold no prompt and no slot, so they don't count
            # against the residency bound the throttle enforces
            if not r.done and not r.follower:
                inflight.add(r.rid)
            while len(inflight) >= max(1, chunk):
                for f in self.step():
                    inflight.discard(f.rid)
        self.drain()
        self.stats.wall_s += time.time() - t0
        if return_requests:
            return reqs
        return [r.text for r in reqs]
