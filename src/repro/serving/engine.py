"""Serving engine: continuous batching over fixed decode slots.

TPU-adapted vLLM-style serving (DESIGN.md §3): XLA wants static shapes,
so instead of paged KV blocks the engine keeps a **fixed pool of decode
slots** — the KV cache is stacked per-row state with a leading slot
axis, and the decode step is ``vmap`` of the model's single-row decode
over that axis.  That makes slot admission a uniform ``leaf.at[slot]
.set(row_state)`` for EVERY architecture family (attention KV, rwkv
state, mamba state, whisper cross-KV ... all have a leading slot axis by
construction), compiled exactly once.

Flow per engine tick:
  1. admit: take up to (free slots) queued requests, prefill them as one
     length-bucketed batch, scatter their row states into free slots;
  2. decode: one vmapped step for all slots (inactive slots masked);
  3. retire: rows hitting EOS / max_new leave; their slots free up.

The result cache (cache.py) short-circuits duplicate rows before they
ever reach a slot, and the instance-optimized (compressed) model drops
in transparently because every linear goes through compressed.matmul.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serving.batcher import Batcher, Request, bucket_len
from repro.serving.cache import ResultCache
from repro.training.data import ByteTokenizer


@dataclass
class EngineStats:
    rows: int = 0
    tokens_out: int = 0
    prefills: int = 0
    decode_steps: int = 0
    cache_hits: int = 0
    wall_s: float = 0.0

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.wall_s if self.wall_s else 0.0


class Engine:
    def __init__(self, params, cfg, *, tokenizer: Optional[ByteTokenizer] = None,
                 slots: int = 8, max_len: int = 256,
                 buckets: Sequence[int] = (32, 64, 128),
                 use_result_cache: bool = True, version: str = "base",
                 extra_inputs: Optional[Dict] = None):
        self.params = params
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer(max(cfg.vocab_size, 260))
        self.slots = slots
        self.max_len = max_len
        self.buckets = tuple(b for b in buckets if b < max_len)
        self.result_cache = ResultCache() if use_result_cache else None
        self.version = version
        self.batcher = Batcher(self.buckets)
        self.stats = EngineStats()
        self._rid = 0
        self.extra_inputs = extra_inputs or {}

        # --- jit'd single-row prefill, vmapped over the admission batch ---
        def row_prefill(params, toks):
            batch = {"tokens": toks[None]}
            batch.update({k: v[None] for k, v in self.extra_inputs.items()})
            logits, cache = api.prefill(params, cfg, batch,
                                        max_len=max_len, compact_local=False)
            return logits[0], cache  # leaves without leading batch axis? no:

        self._prefill = {}
        for b in self.buckets:
            self._prefill[b] = jax.jit(
                jax.vmap(row_prefill, in_axes=(None, 0)))

        # --- slot-state scatter (uniform leading axis) ---
        def insert(slot_state, row_state, slot_idx):
            return jax.tree.map(
                lambda s, r: s.at[slot_idx].set(r.astype(s.dtype)),
                slot_state, row_state)

        self._insert = jax.jit(insert, donate_argnums=(0,))

        # --- vmapped decode step over slots ---
        def row_decode(params, cache, tok, pos):
            logits, cache = api.decode_step(params, cfg, cache,
                                            tok[None, None], pos[None],
                                            max_len=max_len)
            return logits[0, -1], cache

        def step(params, slot_state, toks, pos):
            logits, state = jax.vmap(
                row_decode, in_axes=(None, 0, 0, 0))(params, slot_state,
                                                     toks, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, state

        self._decode = jax.jit(step, donate_argnums=(1,))
        self._slot_state = None

    # ------------------------------------------------------------------
    def _init_slots(self):
        one = api.init_cache(self.cfg, 1, self.max_len, compact_local=False)
        self._slot_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.slots,) + a.shape).copy(),
            one)

    def submit(self, text: str, *, max_new: int = 32) -> Request:
        ids = self.tok.encode(text, bos=True) + [self.tok.SEP]
        req = Request(rid=self._rid, prompt_ids=ids, max_new=max_new)
        self._rid += 1
        if self.result_cache is not None:
            req.cache_key = self.result_cache.key(text, max_new, self.version)
        self.batcher.add(req)
        return req

    def generate(self, texts: Sequence[str], *, max_new: int = 32,
                 progress: bool = False) -> List[str]:
        """Continuous-batching run over all texts; returns decoded rows."""
        t0 = time.time()
        reqs = [self.submit(t, max_new=max_new) for t in texts]
        followers: Dict[tuple, List[Request]] = {}
        leaders: Dict[tuple, Request] = {}
        for r in list(self.batcher.queue):
            if self.result_cache is None:
                continue
            hit = self.result_cache.get(r.cache_key)
            if hit is not None:
                r.out_ids = self.tok.encode(hit)
                r.done = True
                self.stats.cache_hits += 1
                self.batcher.queue.remove(r)
            elif r.cache_key in leaders:
                # duplicate row within this query: ride on the leader
                followers.setdefault(r.cache_key, []).append(r)
                self.stats.cache_hits += 1
                self.result_cache.hits += 1
                self.batcher.queue.remove(r)
            else:
                leaders[r.cache_key] = r
        if self._slot_state is None:
            self._init_slots()

        active: Dict[int, Request] = {}          # slot -> request
        cur_tok = np.zeros((self.slots,), np.int32)
        cur_pos = np.zeros((self.slots,), np.int32)

        while len(self.batcher) or active:
            free = [s for s in range(self.slots) if s not in active]
            # --- admit ---
            if free and len(self.batcher):
                take = self.batcher.take(len(free))
                if take:
                    b = bucket_len(max(len(r.prompt_ids) for r in take),
                                   self.buckets)
                    toks = np.zeros((len(take), b), np.int32)
                    for i, r in enumerate(take):
                        ids = r.prompt_ids[-b:]
                        toks[i, :len(ids)] = ids
                    logits, rows = self._prefill[b](self.params,
                                                    jnp.asarray(toks))
                    self.stats.prefills += 1
                    # rows are right-padded: gather each row's logits at
                    # its last REAL position, not at the padding tail
                    lens = np.array([min(len(r.prompt_ids), b)
                                     for r in take])
                    last_logits = jnp.take_along_axis(
                        logits, jnp.asarray(lens - 1)[:, None, None],
                        axis=1)[:, 0]
                    last = np.asarray(jnp.argmax(last_logits,
                                                 axis=-1)).astype(np.int32)
                    for i, r in enumerate(take):
                        s = free[i]
                        row = jax.tree.map(lambda a, i=i: a[i], rows)
                        self._slot_state = self._insert(
                            self._slot_state, row, jnp.asarray(s))
                        active[s] = r
                        n = int(lens[i])
                        r.out_ids.append(int(last[i]))
                        cur_tok[s] = last[i]
                        cur_pos[s] = n
            if not active:
                continue
            # --- decode one token for every active slot ---
            nxt, self._slot_state = self._decode(
                self.params, self._slot_state, jnp.asarray(cur_tok),
                jnp.asarray(cur_pos))
            self.stats.decode_steps += 1
            nxt = np.asarray(nxt)
            # --- retire / advance ---
            for s in list(active):
                r = active[s]
                t = int(nxt[s])
                r.out_ids.append(t)
                cur_tok[s] = t
                cur_pos[s] += 1
                if t == self.tok.EOS or len(r.out_ids) >= r.max_new \
                        or cur_pos[s] >= self.max_len - 1:
                    r.done = True
                    del active[s]

        for key, flw in followers.items():
            for r in flw:
                r.out_ids = list(leaders[key].out_ids)
                r.done = True
        outs = []
        for r in reqs:
            ids = [t for t in r.out_ids if t != self.tok.EOS]
            text = self.tok.decode(ids)
            if self.result_cache is not None and r.cache_key is not None:
                self.result_cache.put(r.cache_key, text)
            outs.append(text)
        self.stats.rows += len(reqs)
        self.stats.tokens_out += sum(len(r.out_ids) for r in reqs)
        self.stats.wall_s += time.time() - t0
        return outs
