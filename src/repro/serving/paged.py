"""Host-side block-table allocator for the paged KV cache.

The engine's device state holds one global pool of KV blocks per layer
(`models/api.py: init_paged_cache`); this class owns the *mapping* —
which pool block backs which logical position of which slot — as plain
numpy, mirrored to the device as the ``[slots, blocks_per_slot]`` int32
table the decode step and the paged attention kernel index through.

Block-id space (``num_blocks`` total):

- **private**: ids ``[s * nblk, (s+1) * nblk)`` are permanently owned by
  slot ``s`` — a slot can always be admitted without allocation, and a
  retired slot's table resets to its private row so stale table entries
  can never read (or pin) shared state.
- **shared**: ids ``[slots * nblk, slots * nblk + extra)`` form a free
  list used to seed full prefix blocks once per template; admissions
  alias them by table reference.  Refcounted: the prefix cache holds one
  reference while its entry lives, each aliasing slot holds one more; a
  block returns to the free list at zero.
- **trash**: the last id.  Admission scatter writes *every* chunk of a
  row's prefill KV somewhere; chunks covered by aliased prefix blocks
  are pointed at the trash block, which no table ever references.

Partial tail blocks of a prefix are never shared — only ``plen // bs``
full blocks — so the boundary block is written privately from the row's
own (complete) prefill state and per-row suffix tokens never touch
shared storage.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class BlockTableAllocator:
    def __init__(self, slots: int, blocks_per_slot: int, *,
                 extra_blocks: Optional[int] = None):
        nblk = int(blocks_per_slot)
        self.slots = int(slots)
        self.nblk = nblk
        self.extra = int(2 * nblk if extra_blocks is None else extra_blocks)
        self.num_blocks = self.slots * nblk + self.extra + 1
        self.trash = self.num_blocks - 1
        self.tables = np.stack([self.private(s) for s in range(self.slots)])
        self._free: List[int] = list(
            range(self.slots * nblk, self.slots * nblk + self.extra))
        self._ref: Dict[int, int] = {}
        self._entries: Dict[object, np.ndarray] = {}
        self._occupied: set = set()

    def private(self, s: int) -> np.ndarray:
        return np.arange(s * self.nblk, (s + 1) * self.nblk, dtype=np.int32)

    # -- shared prefix blocks -------------------------------------------------

    def lookup(self, key) -> Optional[np.ndarray]:
        """Shared block ids seeded for ``key`` (None if never seeded /
        dropped)."""
        return self._entries.get(key)

    def seed_blocks(self, key, n_full: int) -> Optional[np.ndarray]:
        """Allocate ``n_full`` shared blocks for a prefix.  Returns None
        when the free list can't cover it (admissions then fall back to
        fully-private writes — correctness never depends on aliasing)."""
        if key in self._entries:
            return self._entries[key]
        if n_full > len(self._free):
            return None
        ids = np.asarray([self._free.pop(0) for _ in range(n_full)], np.int32)
        for b in ids:
            self._ref[int(b)] = 1            # the prefix-cache's reference
        self._entries[key] = ids
        return ids

    def drop_prefix(self, key) -> None:
        """Release the prefix cache's reference (entry evicted).  Blocks
        still aliased by live slots stay allocated until those retire."""
        ids = self._entries.pop(key, None)
        if ids is None:
            return
        for b in ids:
            self._decref(int(b))

    def _decref(self, b: int) -> None:
        self._ref[b] -= 1
        if self._ref[b] == 0:
            del self._ref[b]
            self._free.append(b)

    # -- slot lifecycle -------------------------------------------------------

    def occupy(self, s: int) -> None:
        """Admit into slot ``s`` with no shared prefix: fully private row."""
        self.tables[s] = self.private(s)
        self._occupied.add(s)

    def alias(self, s: int, key) -> int:
        """Admit into slot ``s`` aliasing the prefix seeded under ``key``;
        returns the number of aliased blocks."""
        ids = self._entries[key]
        n = len(ids)
        row = self.private(s)
        row[:n] = ids
        self.tables[s] = row
        for b in ids:
            self._ref[int(b)] += 1
        self._occupied.add(s)
        return n

    def release(self, s: int) -> None:
        """Retire slot ``s``: drop its shared references and reset the
        table row to the private blocks."""
        if s not in self._occupied:
            return
        lo = self.slots * self.nblk
        for b in self.tables[s]:
            if lo <= int(b) < self.trash:
                self._decref(int(b))
        self.tables[s] = self.private(s)
        self._occupied.discard(s)

    # -- accounting -----------------------------------------------------------

    def stats(self):
        """(kv_blocks_in_use, kv_blocks_shared): distinct blocks reachable
        from occupied slots or live prefix entries, and blocks aliased by
        more than one occupied slot."""
        rows = [self.tables[s] for s in self._occupied]
        slot_ids = (np.concatenate(rows) if rows
                    else np.empty(0, np.int32))
        uniq, counts = np.unique(slot_ids, return_counts=True)
        entry_ids = {int(b) for ids in self._entries.values() for b in ids}
        in_use = len(set(uniq.tolist()) | entry_ids)
        shared = int((counts > 1).sum())
        return in_use, shared
