"""Streaming per-tenant latency metrics for the serving layer.

A long-running service cannot keep every row latency in memory, yet the
SLO numbers operators actually watch are tail percentiles.  ``Reservoir``
is a classic Algorithm-R reservoir sampler (Vitter 1985) over a latency
stream: exact below ``capacity`` observations (it simply stores them
all), an unbiased uniform sample beyond it, with exact count / sum /
min / max tracked on the side.  Percentiles are read off the sorted
sample with the same linear interpolation as
``statistics.quantiles(..., method="inclusive")``, so for streams that
fit the reservoir the estimator IS the exact quantile (property-tested
in tests/test_service.py against ``statistics.quantiles``).

Determinism: the sampler draws from a private ``random.Random(seed)``,
never the global RNG — two services fed the same stream report the same
percentiles, and tests can assert on estimates for streams longer than
the capacity.

``TenantStats`` bundles the two histograms the scheduler maintains per
tenant — queue wait (submit -> first activation) and per-row latency
(engine submit -> row completion) — plus row/degradation counters.
``render_stats`` turns a stats dict (SchedulerStats.as_dict + service
counters) into the EXPLAIN-style text block served by ``/stats?format=
text``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PERCENTILES = (0.50, 0.95, 0.99)


class Reservoir:
    """Algorithm-R reservoir percentile estimator (pure Python).

    Exact for streams up to ``capacity`` (every observation is kept);
    beyond that each observation is retained with probability
    ``capacity / n`` — a uniform sample of the whole stream.  count,
    sum, min and max are always exact.
    """

    def __init__(self, capacity: int = 512, seed: int = 0xA5):
        if capacity < 1:
            raise ValueError("reservoir capacity must be >= 1")
        self.capacity = int(capacity)
        self._rng = random.Random(seed)
        self.sample: List[float] = []
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        self.vmin = x if self.vmin is None else min(self.vmin, x)
        self.vmax = x if self.vmax is None else max(self.vmax, x)
        if len(self.sample) < self.capacity:
            self.sample.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self.sample[j] = x

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> Optional[float]:
        """Linear interpolation over the sorted sample at position
        ``q * (n - 1)`` — the "inclusive" quantile method, so a full
        (un-overflowed) reservoir matches ``statistics.quantiles(data,
        method="inclusive")`` exactly.  None before any observation."""
        if not self.sample:
            return None
        s = sorted(self.sample)
        pos = q * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        frac = pos - lo
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def as_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {"count": self.count, "mean": self.mean,
                                "min": self.vmin, "max": self.vmax}
        for q in PERCENTILES:
            d[f"p{int(q * 100)}"] = self.quantile(q)
        return d


@dataclass
class TenantStats:
    """Per-tenant serving record inside ``SchedulerStats``."""
    rows: int = 0
    degradations: int = 0
    queue_wait: Reservoir = field(default_factory=Reservoir)
    latency: Reservoir = field(default_factory=Reservoir)

    def as_dict(self) -> Dict[str, object]:
        return {"rows": self.rows, "degradations": self.degradations,
                "queue_wait": self.queue_wait.as_dict(),
                "latency": self.latency.as_dict()}


def _fmt(v: object) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v * 1e3:.1f}ms"
    return str(v)


def render_stats(stats: Dict[str, object]) -> str:
    """EXPLAIN-style text rendering of a service stats dict (the JSON
    shape built by ``SemanticQueryService.stats_dict``; scheduler-only
    dicts from ``SchedulerStats.as_dict`` render too)."""
    sched = stats.get("scheduler", stats)
    lines = ["SERVICE STATS"]
    svc = stats.get("service")
    if svc:
        lines.append(
            f"  service: uptime={svc.get('uptime_s', 0.0):.1f}s "
            f"queries={svc.get('queries', 0)} "
            f"shed={svc.get('shed', 0)} errors={svc.get('errors', 0)}")
    lines.append(
        f"  scheduler: ticks={sched.get('ticks', 0)} "
        f"rows={sched.get('rows', 0)} "
        f"rows/s={sched.get('rows_per_s', 0.0):.1f} "
        f"degradations={sched.get('degradations', 0)}")
    tenants = sched.get("tenants", {})
    if tenants:
        lines.append("  tenants:")
        for i, (name, ts) in enumerate(sorted(tenants.items()), 1):
            lat, qw = ts.get("latency", {}), ts.get("queue_wait", {})
            lines.append(
                f"    {i}. {name}: rows={ts.get('rows', 0)}"
                + (f" degradations={ts['degradations']}"
                   if ts.get("degradations") else ""))
            lines.append(
                "       latency p50=" + _fmt(lat.get("p50"))
                + " p95=" + _fmt(lat.get("p95"))
                + " p99=" + _fmt(lat.get("p99"))
                + " | queue_wait p50=" + _fmt(qw.get("p50"))
                + " p95=" + _fmt(qw.get("p95"))
                + " p99=" + _fmt(qw.get("p99")))
    events = sched.get("events", [])
    if events:
        lines.append("  degradation events:")
        for e in events[-8:]:
            lines.append(
                f"    tick {e.get('tick')}: tenant={e.get('tenant')} "
                f"engine={e.get('engine')} action={e.get('action')} "
                f"({e.get('error')})")
    pool = stats.get("pool")
    if pool:
        lines.append(
            f"  pool: resident={pool.get('resident_models', 0)} "
            f"hits={pool.get('hits', 0)} misses={pool.get('misses', 0)} "
            f"evictions={pool.get('evictions', 0)}")
    adm = stats.get("admission")
    if adm:
        lines.append("  admission:")
        for name, a in sorted(adm.items()):
            lines.append(
                f"    {name}: admitted={a.get('admitted', 0)} "
                f"shed={a.get('shed', 0)} "
                f"inflight_rows={a.get('inflight_rows', 0)}")
    return "\n".join(lines)
