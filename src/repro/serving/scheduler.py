"""Multi-tenant serving: byte-budgeted model residency + fair scheduling.

The paper's headline systems claim is that instance-optimization
"enables higher parallelism on existing hardware": a compressed
per-query model is small enough that *many* specialized instances
co-reside in the memory where one base model fit, so concurrent OLAP
queries from different tenants run simultaneously instead of queueing
behind a single engine.  This module supplies the two pieces that turn
the single-model async engine (engine.py) into that fleet:

``ModelPool``
    Byte-budgeted residency of per-query compressed models.  An entry
    is one resident ``Engine`` (model params + its decode-slot state);
    ``engine_for(qsig, probe)`` returns the resident engine for the
    query's optimized model, re-running the instance-optimization
    workflow through the owning ``IOLMSession`` on a miss (the
    session's ``ModelCache`` makes an evicted-but-remembered model
    cheap to re-admit: only the engine is rebuilt, not the compression
    search).  Residency is LRU with pin counts — engines with live
    scheduler work are never evicted — and the byte budget is a hard
    invariant: an admission evicts least-recently-used unpinned
    entries first and fails rather than overshoot.  All resident
    engines share one ``PrefixCache`` keyed by (template tokens, model
    version), so tenants on different compressed models can never
    collide on prefilled state while tenants on the *same* model share
    it.

``Scheduler``
    Fair-share round-robin interleaving of ``Engine.step()`` across
    the pool's resident engines.  A ``Submission`` is one tenant's
    prompt stream bound for one model; every scheduler tick tops each
    active submission up to ``share`` in-flight rows (round-robin, so
    no tenant starves at admission) and then runs one decode tick on
    every engine that has work.  Tenants whose prompts and model
    version coincide dedup through the shared engine's result cache
    and leader/follower path — identical work is decoded once across
    the whole fleet.  Greedy outputs are byte-identical to running
    each submission alone on a private engine: per-slot decode state
    is independent, so interleaving changes only the schedule, never
    the tokens (property-tested in tests/test_property.py).

``Scheduler.run_queries`` drives whole OLAP query *plans* (not just
prompt streams) concurrently: each ``Query`` exposes its plan as a
coroutine of operator submissions, and the scheduler interleaves the
operators of all tenants' queries while respecting each plan's own
sequential dependencies.

Device-parallel serving (the paper's "higher parallelism on existing
hardware" read literally): constructed with ``devices=`` (a list of
jax devices) or ``mesh=`` (a ``jax.sharding.Mesh``), the pool tracks a
**per-device** byte budget, places each admitted engine's params on
one device (``jax.device_put`` inside ``Engine``) under a least-loaded
or affinity placement policy, and — with a mesh — admits a model too
big for any single device as ONE tensor-parallel engine sharded by
``distributed/sharding.py``'s rules, coexisting with the single-device
replicas.  The scheduler's tick then *fans out*: it dispatches
``Engine.step_begin()`` on every engine with work before collecting
any ``step_finish()``, so engines pinned to distinct devices run their
decode steps concurrently while outputs stay byte-identical to the
serial executor (dispatch order is deterministic and per-engine
sequencing is unchanged).  ``devices=None, mesh=None`` is exactly the
historical single-device pool.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Set, Tuple)

import jax
import numpy as np

from repro.core.compressed import param_bytes
from repro.models import api
from repro.serving.batcher import Request
from repro.serving.cache import PrefixCache
from repro.serving.engine import Engine
from repro.serving.metrics import TenantStats


def slot_state_bytes(cfg, max_len: int) -> int:
    """Per-decode-slot state bytes (KV cache / recurrent state, batch=1),
    computed from shapes only — no allocation."""
    cache = jax.eval_shape(lambda: api.init_cache(cfg, 1, max_len,
                                                  compact_local=False))
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


class PoolBudgetError(RuntimeError):
    """Raised when an admission cannot fit inside the byte budget.

    ``retryable`` distinguishes "blocked by pinned residents, wait for
    a pin to release" (the scheduler queues the submission) from "the
    model alone exceeds the budget, it can never fit" (always raised
    through to the caller).
    """

    def __init__(self, msg: str, *, retryable: bool):
        super().__init__(msg)
        self.retryable = retryable


@dataclass
class _BaseModel:
    """Duck-typed OptimizedModel for the un-optimized (base) path."""
    params: Any
    cfg: Any
    version: str = "base"


@dataclass
class PoolEntry:
    engine: Engine
    nbytes: int
    hits: int = 0
    # device-aware pools: indices into pool.devices this entry occupies
    # (one for a placed replica, all of them for a sharded TP entry) and
    # the bytes charged against EACH of those devices' budgets.
    devices: Tuple[int, ...] = ()
    dev_bytes: int = 0

    @property
    def sharded(self) -> bool:
        return len(self.devices) > 1


@dataclass
class PoolStats:
    hits: int = 0            # engine_for served by a resident engine
    misses: int = 0          # engine (re)built — optimize and/or admit
    evictions: int = 0
    peak_resident_models: int = 0
    peak_resident_bytes: int = 0
    sharded_admissions: int = 0   # models admitted tensor-parallel


class ModelPool:
    """Byte-budgeted LRU residency of per-query (compressed) engines.

    ``session`` is duck-typed: the pool needs ``session._optimize(qsig,
    probe) -> model`` (with ``.params/.cfg/.version``), ``session.params``
    / ``session.cfg`` for the base path, and ``session.tok``.
    ``engine_factory`` / ``entry_bytes`` are injection points for tests
    and alternate backends; the defaults build a real ``Engine`` and
    charge it ``param_bytes(model) + slots * slot_state_bytes(cfg)``.

    Device-aware mode — pass ``devices=`` (list of jax devices) or
    ``mesh=`` (its devices, plus a tensor-parallel admission path for
    models too big for one device):

    * ``byte_budget`` becomes **per-device**; total fleet capacity is
      ``byte_budget * len(devices)``.
    * Each admitted engine is pinned to one device (its params are
      ``jax.device_put`` there by ``Engine``); ``placement`` picks it:
      ``"least_loaded"`` (fewest resident bytes, lowest index on ties —
      deterministic) or ``"affinity"`` (re-admit an evicted version to
      its previous home while it fits, so same-placement prefix-cache
      entries and warm state stay reusable; falls back to
      least-loaded).
    * A model with ``entry_bytes > byte_budget`` is admitted as ONE
      sharded engine over ``mesh`` (when given), charging
      ``ceil(bytes/n_devices)`` to every device — the tensor-parallel
      base model coexisting with single-device compressed replicas.
    * The budget stays a hard per-device invariant: admission evicts
      LRU unpinned entries *on the chosen device(s)* and refuses
      rather than overshoot.

    ``devices=None, mesh=None`` (the default) is the historical
    single-implicit-device pool: ``byte_budget`` is the total budget
    and engines are built without placement.
    """

    def __init__(self, session, byte_budget: int, *,
                 engine_kw: Optional[Dict] = None,
                 prefix_capacity: int = 32,
                 engine_factory: Optional[Callable] = None,
                 entry_bytes: Optional[Callable] = None,
                 devices: Optional[List] = None,
                 mesh=None,
                 placement: str = "least_loaded"):
        self.session = session
        self.byte_budget = int(byte_budget)
        self.engine_kw = dict(engine_kw or {})
        self.prefix_cache = PrefixCache(capacity=prefix_capacity)
        self._engine_factory = engine_factory or self._default_factory
        self._entry_bytes = entry_bytes or self._default_bytes
        self._entries: "OrderedDict[str, PoolEntry]" = OrderedDict()
        self._pins: Dict[str, int] = {}
        self.stats = PoolStats()
        self.eviction_log: List[str] = []
        if placement not in ("least_loaded", "affinity"):
            raise ValueError(f"unknown placement policy {placement!r}")
        self.placement = placement
        self.mesh = mesh
        if mesh is not None:
            if devices is not None:
                raise ValueError("pass devices= or mesh=, not both")
            self.devices = list(mesh.devices.flat)
        else:
            self.devices = list(devices) if devices is not None else None
        self._homes: Dict[str, int] = {}   # version -> last device index

    @property
    def device_aware(self) -> bool:
        return self.devices is not None

    # -- defaults -------------------------------------------------------
    def _default_factory(self, model, *, device=None, mesh=None) -> Engine:
        return Engine(model.params, model.cfg, tokenizer=self.session.tok,
                      version=model.version, prefix_cache=self.prefix_cache,
                      device=device, mesh=mesh, **self.engine_kw)

    def _default_bytes(self, model) -> int:
        slots = self.engine_kw.get("slots", 8)
        max_len = self.engine_kw.get("max_len", 256)
        return (param_bytes(model.params)
                + slots * slot_state_bytes(model.cfg, max_len))

    # -- residency ------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    @property
    def resident_versions(self) -> List[str]:
        return list(self._entries)

    def device_bytes(self, i: int) -> int:
        """Bytes charged against device ``i``'s budget (device-aware)."""
        return sum(e.dev_bytes for e in self._entries.values()
                   if i in e.devices)

    def _pinned_device_bytes(self, i: int) -> int:
        return sum(e.dev_bytes for v, e in self._entries.items()
                   if i in e.devices and self.pinned(v))

    def placement_of(self, version: str) -> Tuple[int, ...]:
        """Device indices a resident version occupies (``()`` when not
        resident or the pool is not device-aware)."""
        e = self._entries.get(version)
        return e.devices if e is not None else ()

    def __len__(self) -> int:
        return len(self._entries)

    def pin(self, version: str) -> None:
        self._pins[version] = self._pins.get(version, 0) + 1

    def unpin(self, version: str) -> None:
        n = self._pins.get(version, 0) - 1
        if n <= 0:
            self._pins.pop(version, None)
        else:
            self._pins[version] = n

    def pinned(self, version: str) -> bool:
        return self._pins.get(version, 0) > 0

    def discard(self, version: str, *, engine=None) -> bool:
        """Forcibly drop a resident entry (fault quarantine).  Unlike
        LRU eviction this removes the entry even when pinned — the pins
        belong to the submissions being quarantined off the faulty
        engine, and the scheduler clears them by discarding here — so
        the replacement admission has room.  ``engine`` (when given)
        guards against discarding an innocent rebuild that re-used the
        same version string after the fault."""
        e = self._entries.get(version)
        if e is None or (engine is not None and e.engine is not engine):
            return False
        del self._entries[version]
        self._pins.pop(version, None)
        self.stats.evictions += 1
        self.eviction_log.append(version)
        return True

    def resolve(self, qsig: str, probe: Iterable[str] = (), *,
                optimize: bool = True):
        """The query's model (optimizing on first sight), WITHOUT
        admitting an engine — callers that may need to retry admission
        (budget pinned full) resolve once and re-``admit`` the memoized
        model instead of re-running the optimization lookup per try."""
        return (self.session._optimize(qsig, list(probe)) if optimize
                else _BaseModel(self.session.params, self.session.cfg))

    def admit(self, model) -> Engine:
        """Resident engine for ``model``, building one on miss.  Raises
        PoolBudgetError instead of exceeding the budget; a *retryable*
        refusal (pinned residents block the room) evicts nothing — warm
        engines are only sacrificed for admissions that will succeed."""
        entry = self._entries.get(model.version)
        if entry is not None:
            self._entries.move_to_end(model.version)
            entry.hits += 1
            self.stats.hits += 1
            return entry.engine
        need = int(self._entry_bytes(model))
        if self.device_aware:
            entry = self._admit_placed(model, need)
        else:
            entry = self._admit_legacy(model, need)
        self._entries[model.version] = entry
        self.stats.misses += 1
        self.stats.peak_resident_models = max(self.stats.peak_resident_models,
                                              len(self._entries))
        self.stats.peak_resident_bytes = max(self.stats.peak_resident_bytes,
                                             self.resident_bytes)
        return entry.engine

    def _admit_legacy(self, model, need: int) -> PoolEntry:
        """Single-implicit-device admission (the historical behavior)."""
        if need > self.byte_budget:
            raise PoolBudgetError(
                f"model {model.version!r} needs {need} bytes but the pool "
                f"budget is {self.byte_budget}", retryable=False)
        pinned_bytes = sum(e.nbytes for v, e in self._entries.items()
                           if self.pinned(v))
        if pinned_bytes + need > self.byte_budget:
            raise PoolBudgetError(
                f"cannot admit {model.version!r} ({need} bytes): "
                f"{pinned_bytes} bytes pinned by live submissions",
                retryable=True)
        self._evict_until(self.byte_budget - need)
        return PoolEntry(engine=self._engine_factory(model), nbytes=need)

    # -- device-aware admission ----------------------------------------
    def _pick_device(self, version: str, need: int) -> Optional[int]:
        """Placement policy: the device this admission should land on,
        or None when every device is blocked by pins (retryable).
        Deterministic: least-loaded by resident bytes with lowest index
        winning ties; ``affinity`` first tries the version's previous
        home so re-admissions reuse same-placement state."""
        cand = [i for i in range(len(self.devices))
                if self._pinned_device_bytes(i) + need <= self.byte_budget]
        if not cand:
            return None
        if self.placement == "affinity":
            home = self._homes.get(version)
            if home in cand:
                return home
        return min(cand, key=lambda i: (self.device_bytes(i), i))

    def _admit_placed(self, model, need: int) -> PoolEntry:
        """Per-device-budget admission: place on one device, or shard
        over the whole mesh when the model cannot fit any single one."""
        ndev = len(self.devices)
        if need <= self.byte_budget:
            dev = self._pick_device(model.version, need)
            if dev is None:
                raise PoolBudgetError(
                    f"cannot admit {model.version!r} ({need} bytes): every "
                    f"device's budget is pinned by live submissions",
                    retryable=True)
            self._evict_device_until(dev, self.byte_budget - need)
            engine = self._engine_factory(model, device=self.devices[dev])
            self._homes[model.version] = dev
            return PoolEntry(engine=engine, nbytes=need,
                             devices=(dev,), dev_bytes=need)
        per = -(-need // ndev)          # ceil: bytes charged per device
        if self.mesh is not None and per <= self.byte_budget:
            if any(self._pinned_device_bytes(i) + per > self.byte_budget
                   for i in range(ndev)):
                raise PoolBudgetError(
                    f"cannot admit sharded {model.version!r} ({per} "
                    f"bytes/device): pinned residents block the room",
                    retryable=True)
            for i in range(ndev):
                self._evict_device_until(i, self.byte_budget - per)
            engine = self._engine_factory(model, mesh=self.mesh)
            self.stats.sharded_admissions += 1
            return PoolEntry(engine=engine, nbytes=need,
                             devices=tuple(range(ndev)), dev_bytes=per)
        raise PoolBudgetError(
            f"model {model.version!r} needs {need} bytes but the "
            f"per-device budget is {self.byte_budget}"
            + ("" if self.mesh is not None
               else " (no mesh: sharded admission unavailable)"),
            retryable=False)

    def engine_for(self, qsig: str, probe: Iterable[str] = (), *,
                   optimize: bool = True) -> Engine:
        """``resolve`` + ``admit`` in one call (the no-retry path)."""
        return self.admit(self.resolve(qsig, probe, optimize=optimize))

    def _evict_lru(self, over_budget: Callable[[], bool],
                   occupies: Callable[[PoolEntry], bool]) -> None:
        """The one eviction loop both pools share: pop the least-
        recently-used unpinned entry satisfying ``occupies`` until
        ``over_budget()`` clears (or only pinned residents remain);
        deterministic (global LRU order)."""
        while over_budget():
            victim = next((v for v, e in self._entries.items()
                           if occupies(e) and not self.pinned(v)), None)
            if victim is None:
                return
            del self._entries[victim]
            self.stats.evictions += 1
            self.eviction_log.append(victim)

    def _evict_until(self, budget: int) -> None:
        """Legacy pool: evict until total resident bytes fit."""
        self._evict_lru(lambda: self.resident_bytes > budget,
                        lambda e: True)

    def _evict_device_until(self, dev: int, budget: int) -> None:
        """Device-aware pool: evict entries occupying device ``dev``
        until its charged bytes fit (a sharded entry is evictable from
        any of its devices and frees its charge on all of them)."""
        self._evict_lru(lambda: self.device_bytes(dev) > budget,
                        lambda e: dev in e.devices)


# ---------------------------------------------------------------------------
# fair-share scheduling
# ---------------------------------------------------------------------------

_EXHAUSTED = object()
_WHOLE_STEP = object()      # engine lacks the step_begin/step_finish split


@dataclass
class Submission:
    """One tenant's prompt stream bound for one model."""
    tenant: str
    prompts: Iterator[str]
    qsig: str
    probe: List[str]
    max_new: int
    prefix: Optional[str]
    optimize: bool
    engine: Optional[Engine] = None
    model: Any = None            # resolved once; re-admitted on retries
    error: Optional[BaseException] = None   # terminal admission failure
    reqs: List = field(default_factory=list)
    inflight: Set[int] = field(default_factory=set)
    exhausted: bool = False
    peak_inflight: int = 0
    first_done_tick: Optional[int] = None
    last_done_tick: Optional[int] = None
    # per-submission in-flight cap (a tenant SLO): effective share is
    # min(scheduler share, this) when set
    share: Optional[int] = None
    # fault quarantine: how many engines this submission has been
    # evacuated from (bounded by Scheduler.max_retries)
    retries: int = 0
    # latency instrumentation (metrics.py reservoirs)
    submit_t: float = 0.0
    activated_t: Optional[float] = None

    @property
    def active(self) -> bool:
        return self.engine is not None

    @property
    def done(self) -> bool:
        if self.error is not None:
            return True
        return self.active and self.exhausted and not self.inflight

    def results(self) -> List[str]:
        """Decoded rows in prompt order; re-raises this submission's
        terminal error (e.g. its model can never fit the pool budget)
        at the consumer instead of aborting unrelated tenants' work."""
        if self.error is not None:
            raise self.error
        return [r.text for r in self.reqs]


@dataclass
class SchedulerStats:
    ticks: int = 0
    rows: int = 0
    wall_s: float = 0.0
    # device fan-out: how many distinct devices had an in-flight decode
    # step dispatched in the same tick (1 on a single-device pool)
    peak_concurrent_devices: int = 1
    # graceful degradation: submissions quarantined off a faulted
    # engine (each retried on the pooled base engine until
    # ``max_retries`` is spent), with one event record apiece
    degradations: int = 0
    events: List[Dict[str, Any]] = field(default_factory=list)
    # per-tenant streaming histograms (serving/metrics.py): queue-wait
    # and per-row latency reservoirs + row/degradation counters
    tenants: Dict[str, TenantStats] = field(default_factory=dict)

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.wall_s if self.wall_s else 0.0

    def tenant(self, name: str) -> TenantStats:
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats()
        return ts

    def as_dict(self) -> Dict[str, Any]:
        """JSON-ready snapshot (the ``/stats`` endpoint's scheduler
        section; p50/p95/p99 come from the per-tenant reservoirs)."""
        return {"ticks": self.ticks, "rows": self.rows,
                "wall_s": self.wall_s, "rows_per_s": self.rows_per_s,
                "peak_concurrent_devices": self.peak_concurrent_devices,
                "degradations": self.degradations,
                "events": list(self.events),
                "tenants": {t: ts.as_dict()
                            for t, ts in self.tenants.items()}}


class Scheduler:
    """Interleaves ``Engine.step()`` across the pool's engines.

    ``share`` bounds each submission's un-finished rows: every tick
    tops every active submission up to ``share`` (round-robin rotation
    so admission order is fair), then runs one decode tick per engine
    with work.  Submissions whose model cannot become resident yet
    (budget full of pinned engines) wait in FIFO order and activate as
    pins release — head-of-line activation, so waiting is starvation-
    free too.
    """

    def __init__(self, pool: ModelPool, *, share: int = 8,
                 max_retries: int = 2):
        self.pool = pool
        self.share = max(1, share)
        # fault quarantine: how many engine evacuations one submission
        # may survive before its error turns terminal
        self.max_retries = max(0, max_retries)
        self.pending: "deque[Submission]" = deque()
        self.active: List[Submission] = []
        self.finished: List[Submission] = []
        self.stats = SchedulerStats()
        self.trace: List[Tuple[int, str]] = []   # (tick, tenant) per row
        self._owners: Dict[Tuple[int, int], Submission] = {}
        self._t0: Dict[Tuple[int, int], float] = {}   # row submit times
        self._rr = 0

    # -- submission -----------------------------------------------------
    def submit(self, tenant: str, prompts: Iterable[str], *, qsig: str,
               probe: Optional[Iterable[str]] = None, max_new: int = 16,
               prefix: Optional[str] = None,
               optimize: bool = True,
               share: Optional[int] = None) -> Submission:
        """Enqueue one tenant's prompt stream; prompts are consumed
        lazily as the scheduler admits them.  ``share`` (when set) caps
        THIS submission's in-flight rows below the scheduler-wide
        share — the per-tenant max-in-flight SLO knob."""
        sub = Submission(tenant=tenant, prompts=iter(prompts), qsig=qsig,
                         probe=list(probe or []), max_new=max_new,
                         prefix=prefix, optimize=optimize, share=share,
                         submit_t=time.time())
        self.pending.append(sub)
        self._activate()
        return sub

    def _activate(self) -> None:
        """FIFO head-of-line activation of pending submissions."""
        while self.pending:
            sub = self.pending[0]
            try:
                if sub.model is None:       # optimize exactly once
                    sub.model = self.pool.resolve(sub.qsig, sub.probe,
                                                  optimize=sub.optimize)
                engine = self.pool.admit(sub.model)
            except PoolBudgetError as e:
                if not e.retryable:
                    # this submission can NEVER fit: fail it alone (the
                    # error surfaces from its results()) and keep
                    # scheduling everyone else
                    self.pending.popleft()
                    sub.error = e
                    self.finished.append(sub)
                    continue
                return          # budget full of pinned engines: wait
            self.pool.pin(engine.version)
            sub.engine = engine
            self.active.append(sub)
            self.pending.popleft()
            if sub.activated_t is None:
                sub.activated_t = time.time()
                self.stats.tenant(sub.tenant).queue_wait.add(
                    sub.activated_t - sub.submit_t)
            # a quarantined submission re-activating on its replacement
            # engine re-submits its unfinished rows (finished rows keep
            # their outputs — only pending work is replayed)
            if any(not r.done for r in sub.reqs):
                self._resubmit_unfinished(sub)

    # -- the tick -------------------------------------------------------
    def _top_up(self, sub: Submission) -> None:
        cap = (self.share if sub.share is None
               else max(1, min(self.share, sub.share)))
        while len(sub.inflight) < cap and not sub.exhausted:
            p = next(sub.prompts, _EXHAUSTED)
            if p is _EXHAUSTED:
                sub.exhausted = True
                break
            try:
                r = sub.engine.submit(p, max_new=sub.max_new,
                                      prefix=sub.prefix)
            except Exception as e:
                # the consumed prompt must not be lost: park it as an
                # unfinished placeholder so the replacement engine
                # replays it with the rest of the quarantined rows
                ph = Request(rid=-1, prompt_ids=[], max_new=sub.max_new,
                             src=p)
                sub.reqs.append(ph)
                self._quarantine_engine(sub.engine, e)
                return
            if r.src is None:
                r.src = p
            sub.reqs.append(r)
            if r.done:          # result-cache hit: resolved instantly
                self._record_done(sub)
            else:
                sub.inflight.add(r.rid)
                self._owners[(id(sub.engine), r.rid)] = sub
                self._t0[(id(sub.engine), r.rid)] = time.time()
        sub.peak_inflight = max(sub.peak_inflight, len(sub.inflight))

    def _record_done(self, sub: Submission, latency: float = 0.0) -> None:
        self.stats.rows += 1
        self.trace.append((self.stats.ticks, sub.tenant))
        ts = self.stats.tenant(sub.tenant)
        ts.rows += 1
        ts.latency.add(latency)
        if sub.first_done_tick is None:
            sub.first_done_tick = self.stats.ticks
        sub.last_done_tick = self.stats.ticks

    # -- graceful degradation -------------------------------------------
    def _quarantine_engine(self, engine, exc: BaseException) -> None:
        """An engine raising mid-tick poisons ONLY the submissions bound
        to it: the entry is discarded from the pool (pins cleared), each
        affected submission's unfinished rows are kept for replay
        (``Request.src`` holds the prompt text) and the submission
        re-enters the pending queue with ``optimize=False`` — the retry
        runs on the pooled base engine, trading the compressed recipe
        for availability.  The event lands in ``stats.events`` instead
        of killing the tick; a submission that keeps faulting past
        ``max_retries`` gets a terminal error (surfaced from its
        ``results()``, like an unretryable admission failure)."""
        eid = id(engine)
        version = getattr(engine, "version", "?")
        self.pool.discard(version, engine=engine)
        victims = [s for s in self.active if s.engine is engine]
        for sub in victims:
            self.active.remove(sub)
            sub.retries += 1
            for rid in list(sub.inflight):
                self._owners.pop((eid, rid), None)
                self._t0.pop((eid, rid), None)
            sub.inflight.clear()
            sub.engine = None
            self.stats.degradations += 1
            self.stats.tenant(sub.tenant).degradations += 1
            terminal = sub.retries > self.max_retries
            self.stats.events.append({
                "tick": self.stats.ticks, "tenant": sub.tenant,
                "engine": version,
                "error": f"{type(exc).__name__}: {exc}",
                "action": "failed" if terminal else "retry_base"})
            if terminal:
                sub.error = exc
                self.finished.append(sub)
                continue
            sub.optimize = False
            sub.model = None
            self.pending.appendleft(sub)

    def _resubmit_unfinished(self, sub: Submission) -> None:
        """Replay a quarantined submission's unfinished rows on its
        replacement engine, splicing the new requests over the old ones
        so row order (and every already-finished output) is
        preserved."""
        eid = id(sub.engine)
        for i, r in enumerate(list(sub.reqs)):
            if r.done:
                continue
            try:
                nr = sub.engine.submit(r.src or "", max_new=sub.max_new,
                                       prefix=sub.prefix)
            except Exception as e:
                self._quarantine_engine(sub.engine, e)
                return
            if nr.src is None:
                nr.src = r.src
            sub.reqs[i] = nr
            if nr.done:
                self._record_done(sub)
            else:
                sub.inflight.add(nr.rid)
                self._owners[(eid, nr.rid)] = sub
                self._t0[(eid, nr.rid)] = time.time()
        sub.peak_inflight = max(sub.peak_inflight, len(sub.inflight))

    def _retire_done(self) -> None:
        still = []
        for sub in self.active:
            if sub.done:
                self.pool.unpin(sub.engine.version)
                self.finished.append(sub)
            else:
                still.append(sub)
        self.active[:] = still

    def step(self) -> bool:
        """One fair-share tick; returns True while work remains."""
        self._activate()
        self.stats.ticks += 1
        order = list(self.active)   # snapshot: quarantine may mutate
        n = len(order)
        for i in range(n):          # rotating round-robin admission
            sub = order[(self._rr + i) % n]
            if sub.engine is not None:   # skip mid-tick quarantined
                self._top_up(sub)
        if n:
            self._rr = (self._rr + 1) % n
        # one decode tick per distinct engine with work, in activation
        # order (deterministic).  Fan-out: DISPATCH every engine's tick
        # (step_begin launches the decode asynchronously) before
        # COLLECTING any of them — engines placed on distinct devices
        # overlap their decode steps instead of serializing.  Ordering
        # and per-engine sequencing are unchanged, so outputs stay
        # byte-identical to stepping each engine to completion in turn.
        engines: "OrderedDict[int, Engine]" = OrderedDict()
        for sub in self.active:
            engines.setdefault(id(sub.engine), sub.engine)
        pending: List[Tuple[int, Engine, Any]] = []
        devs: Set[Any] = set()
        for eid, eng in engines.items():
            if not eng.has_work():
                continue
            if hasattr(eng, "step_begin"):
                try:
                    handle = eng.step_begin()
                except Exception as e:
                    self._quarantine_engine(eng, e)
                    continue
                pending.append((eid, eng, handle))
                # count only placements with a decode genuinely in
                # flight: a tick whose rows all retired at admission
                # (handle.nxt is None) overlapped nothing, and split-
                # less fallback engines run serially at collect time.
                # A mesh-sharded engine's decode occupies EVERY mesh
                # device, so each one counts.
                if handle.nxt is not None:
                    mesh = getattr(eng, "mesh", None)
                    if mesh is not None:
                        devs.update(mesh.devices.flat)
                    else:
                        devs.add(getattr(eng, "device", None))
            else:            # fakes / remote backends without the split
                pending.append((eid, eng, _WHOLE_STEP))
        self.stats.peak_concurrent_devices = max(
            self.stats.peak_concurrent_devices, len(devs))
        for eid, eng, handle in pending:
            try:
                reqs = (eng.step() if handle is _WHOLE_STEP
                        else eng.step_finish(handle))
            except Exception as e:
                self._quarantine_engine(eng, e)
                continue
            now = time.time()
            for req in reqs:
                owner = self._owners.pop((eid, req.rid), None)
                if owner is not None:
                    owner.inflight.discard(req.rid)
                    t0 = self._t0.pop((eid, req.rid), None)
                    self._record_done(owner,
                                      now - t0 if t0 is not None else 0.0)
        self._retire_done()
        self._activate()            # released pins may admit waiters
        return bool(self.active or self.pending)

    def run(self) -> List[Submission]:
        """Tick until every submission completes; returns them all."""
        t0 = time.time()
        while self.step():
            pass
        self.stats.wall_s += time.time() - t0
        return self.finished

    # -- whole-query concurrency ---------------------------------------
    def run_queries(self, queries: Dict[str, Any]) -> Dict[str, Any]:
        """Drive OLAP query *plans* concurrently: ``queries`` maps
        tenant -> ``Query``; each plan's LLM operators run in order,
        but operators of different tenants interleave tick-by-tick.
        Each plan is wrapped in a ``QueryDriver`` (the re-entrant
        per-query state machine below, shared with the long-running
        service); a tenant's plan failure is captured per driver and
        re-raised here after the fleet drains, so one bad plan never
        aborts the other tenants' queries mid-flight.  Returns
        tenant -> result Table."""
        drivers = {t: QueryDriver(self, t, q) for t, q in queries.items()}
        t0 = time.time()
        for d in drivers.values():
            d.start()
        while any(d.sub is not None for d in drivers.values()):
            self.step()
            for d in drivers.values():
                d.poll()
        self.stats.wall_s += time.time() - t0
        for d in drivers.values():
            if d.error is not None:
                raise d.error
        return {t: d.result for t, d in drivers.items()}


class QueryDriver:
    """Drives ONE OLAP query plan through a ``Scheduler``, operator by
    operator — the re-entrant core of ``Scheduler.run_queries``, reused
    by the always-on service (repro/service/core.py) where query jobs
    arrive dynamically instead of as one batch.

    Each ``Query._ops()`` generator yields optimizer-lowered
    ``ExecutableOp``s (olap/physical.py) carrying the per-op engine
    choice (base vs instance-optimized recipe vs cascade), probe
    sample, prefix template, and the dedup-wrapped prompt stream.  A
    cascade op runs as TWO submissions: every row through the pooled
    proxy engine first, then the rows whose confidence fell below the
    fitted threshold re-enter the scheduler as a base-engine
    submission (proxy and base coexist under the one pool budget);
    accepted and escalated outputs splice back in row order before the
    plan advances.

    Lifecycle: ``start()`` submits the plan's first LLM op; the owner
    ticks the scheduler and calls ``poll()`` until ``finished`` — each
    poll collects a completed submission, advances the plan coroutine
    and submits the next op.  Failures (a plan error or a submission's
    terminal error) land in ``error`` instead of raising, so one
    tenant's failure never unwinds another tenant's scheduling loop.
    ``share`` forwards a per-tenant in-flight-row cap to every
    submission; ``on_op_done(driver, op, outs)`` fires as each operator
    completes (the service streams operator progress from it).
    """

    def __init__(self, sched: Scheduler, tenant: str, query, *,
                 share: Optional[int] = None,
                 on_op_done: Optional[Callable] = None):
        self.sched = sched
        self.tenant = tenant
        self.query = query
        self.share = share
        self.on_op_done = on_op_done
        self.gen = query._ops()
        self.sub: Optional[Submission] = None
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.ops_done = 0
        self._op = None                      # ExecutableOp in flight
        self._cascade: Optional[Dict[str, Any]] = None

    @property
    def finished(self) -> bool:
        return self.result is not None or self.error is not None

    def start(self) -> None:
        self._advance(None)

    def poll(self) -> bool:
        """Collect a finished submission and advance the plan; returns
        ``finished``.  Cheap while the current submission is still in
        flight."""
        if self.finished or self.sub is None or not self.sub.done:
            return self.finished
        sub, self.sub = self.sub, None
        try:
            outs = self._collect(sub)
        except Exception as e:
            self.error = e
            return True
        if outs is not None:
            op, self._op = self._op, None
            self.ops_done += 1
            if self.on_op_done is not None:
                self.on_op_done(self, op, outs)
            self._advance(outs)
        return self.finished

    # -- plan coroutine plumbing ---------------------------------------
    def _submit(self, prompts, op, *, optimize: bool) -> Submission:
        return self.sched.submit(
            self.tenant, prompts, qsig=op.qsig, probe=op.probe,
            max_new=op.spec.max_new, prefix=op.spec.prefix,
            optimize=optimize, share=self.share)

    def _advance(self, send_val) -> None:
        try:
            op = self.gen.send(send_val)
        except StopIteration as stop:
            self.result = stop.value
            return
        except Exception as e:       # plan/table failure: capture
            self.error = e
            return
        self._op = op
        if op.op.engine == "cascade":
            budget = op.op.accuracy_budget or 0.0
            cal = self.sched.pool.session._cascade(
                op.qsig, op.probe, budget, max_new=op.spec.max_new)
            prompts = list(op.spec.prompts)
            if not np.isfinite(cal.threshold):
                # unsatisfiable budget: base-only, no proxy pass —
                # the exactness contract for accuracy_budget=0
                self.sub = self._submit(iter(prompts), op, optimize=False)
                return
            self._cascade = {"cal": cal, "prompts": prompts}
            self.sub = self._submit(iter(prompts), op, optimize=True)
            return
        self.sub = self._submit(op.spec.prompts, op, optimize=op.optimize)

    def _collect(self, sub: Submission):
        """Finished-submission hand-off: the op's output rows, or None
        when a cascade just queued its escalation phase."""
        state = self._cascade
        if state is None:
            return sub.results()
        if "rejects" not in state:      # proxy phase finished
            outs = sub.results()
            thr = state["cal"].threshold
            rejects = [i for i, r in enumerate(sub.reqs)
                       if r.confidence < thr]
            if not rejects:
                self._cascade = None
                return outs
            state["outs"] = outs
            state["rejects"] = rejects
            self.sub = self._submit(
                iter([state["prompts"][i] for i in rejects]), self._op,
                optimize=False)
            return None
        outs, rejects = state["outs"], state["rejects"]
        for i, o in zip(rejects, sub.results()):
            outs[i] = o
        self._cascade = None
        return outs
