"""Multi-tenant serving: byte-budgeted model residency + fair scheduling.

The paper's headline systems claim is that instance-optimization
"enables higher parallelism on existing hardware": a compressed
per-query model is small enough that *many* specialized instances
co-reside in the memory where one base model fit, so concurrent OLAP
queries from different tenants run simultaneously instead of queueing
behind a single engine.  This module supplies the two pieces that turn
the single-model async engine (engine.py) into that fleet:

``ModelPool``
    Byte-budgeted residency of per-query compressed models.  An entry
    is one resident ``Engine`` (model params + its decode-slot state);
    ``engine_for(qsig, probe)`` returns the resident engine for the
    query's optimized model, re-running the instance-optimization
    workflow through the owning ``IOLMSession`` on a miss (the
    session's ``ModelCache`` makes an evicted-but-remembered model
    cheap to re-admit: only the engine is rebuilt, not the compression
    search).  Residency is LRU with pin counts — engines with live
    scheduler work are never evicted — and the byte budget is a hard
    invariant: an admission evicts least-recently-used unpinned
    entries first and fails rather than overshoot.  All resident
    engines share one ``PrefixCache`` keyed by (template tokens, model
    version), so tenants on different compressed models can never
    collide on prefilled state while tenants on the *same* model share
    it.

``Scheduler``
    Fair-share round-robin interleaving of ``Engine.step()`` across
    the pool's resident engines.  A ``Submission`` is one tenant's
    prompt stream bound for one model; every scheduler tick tops each
    active submission up to ``share`` in-flight rows (round-robin, so
    no tenant starves at admission) and then runs one decode tick on
    every engine that has work.  Tenants whose prompts and model
    version coincide dedup through the shared engine's result cache
    and leader/follower path — identical work is decoded once across
    the whole fleet.  Greedy outputs are byte-identical to running
    each submission alone on a private engine: per-slot decode state
    is independent, so interleaving changes only the schedule, never
    the tokens (property-tested in tests/test_property.py).

``Scheduler.run_queries`` drives whole OLAP query *plans* (not just
prompt streams) concurrently: each ``Query`` exposes its plan as a
coroutine of operator submissions, and the scheduler interleaves the
operators of all tenants' queries while respecting each plan's own
sequential dependencies.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Set, Tuple)

import jax
import numpy as np

from repro.core.compressed import param_bytes
from repro.models import api
from repro.serving.cache import PrefixCache
from repro.serving.engine import Engine


def slot_state_bytes(cfg, max_len: int) -> int:
    """Per-decode-slot state bytes (KV cache / recurrent state, batch=1),
    computed from shapes only — no allocation."""
    cache = jax.eval_shape(lambda: api.init_cache(cfg, 1, max_len,
                                                  compact_local=False))
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


class PoolBudgetError(RuntimeError):
    """Raised when an admission cannot fit inside the byte budget.

    ``retryable`` distinguishes "blocked by pinned residents, wait for
    a pin to release" (the scheduler queues the submission) from "the
    model alone exceeds the budget, it can never fit" (always raised
    through to the caller).
    """

    def __init__(self, msg: str, *, retryable: bool):
        super().__init__(msg)
        self.retryable = retryable


@dataclass
class _BaseModel:
    """Duck-typed OptimizedModel for the un-optimized (base) path."""
    params: Any
    cfg: Any
    version: str = "base"


@dataclass
class PoolEntry:
    engine: Engine
    nbytes: int
    hits: int = 0


@dataclass
class PoolStats:
    hits: int = 0            # engine_for served by a resident engine
    misses: int = 0          # engine (re)built — optimize and/or admit
    evictions: int = 0
    peak_resident_models: int = 0
    peak_resident_bytes: int = 0


class ModelPool:
    """Byte-budgeted LRU residency of per-query (compressed) engines.

    ``session`` is duck-typed: the pool needs ``session._optimize(qsig,
    probe) -> model`` (with ``.params/.cfg/.version``), ``session.params``
    / ``session.cfg`` for the base path, and ``session.tok``.
    ``engine_factory`` / ``entry_bytes`` are injection points for tests
    and alternate backends; the defaults build a real ``Engine`` and
    charge it ``param_bytes(model) + slots * slot_state_bytes(cfg)``.
    """

    def __init__(self, session, byte_budget: int, *,
                 engine_kw: Optional[Dict] = None,
                 prefix_capacity: int = 32,
                 engine_factory: Optional[Callable] = None,
                 entry_bytes: Optional[Callable] = None):
        self.session = session
        self.byte_budget = int(byte_budget)
        self.engine_kw = dict(engine_kw or {})
        self.prefix_cache = PrefixCache(capacity=prefix_capacity)
        self._engine_factory = engine_factory or self._default_factory
        self._entry_bytes = entry_bytes or self._default_bytes
        self._entries: "OrderedDict[str, PoolEntry]" = OrderedDict()
        self._pins: Dict[str, int] = {}
        self.stats = PoolStats()
        self.eviction_log: List[str] = []

    # -- defaults -------------------------------------------------------
    def _default_factory(self, model) -> Engine:
        return Engine(model.params, model.cfg, tokenizer=self.session.tok,
                      version=model.version, prefix_cache=self.prefix_cache,
                      **self.engine_kw)

    def _default_bytes(self, model) -> int:
        slots = self.engine_kw.get("slots", 8)
        max_len = self.engine_kw.get("max_len", 256)
        return (param_bytes(model.params)
                + slots * slot_state_bytes(model.cfg, max_len))

    # -- residency ------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    @property
    def resident_versions(self) -> List[str]:
        return list(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def pin(self, version: str) -> None:
        self._pins[version] = self._pins.get(version, 0) + 1

    def unpin(self, version: str) -> None:
        n = self._pins.get(version, 0) - 1
        if n <= 0:
            self._pins.pop(version, None)
        else:
            self._pins[version] = n

    def pinned(self, version: str) -> bool:
        return self._pins.get(version, 0) > 0

    def resolve(self, qsig: str, probe: Iterable[str] = (), *,
                optimize: bool = True):
        """The query's model (optimizing on first sight), WITHOUT
        admitting an engine — callers that may need to retry admission
        (budget pinned full) resolve once and re-``admit`` the memoized
        model instead of re-running the optimization lookup per try."""
        return (self.session._optimize(qsig, list(probe)) if optimize
                else _BaseModel(self.session.params, self.session.cfg))

    def admit(self, model) -> Engine:
        """Resident engine for ``model``, building one on miss.  Raises
        PoolBudgetError instead of exceeding the budget; a *retryable*
        refusal (pinned residents block the room) evicts nothing — warm
        engines are only sacrificed for admissions that will succeed."""
        entry = self._entries.get(model.version)
        if entry is not None:
            self._entries.move_to_end(model.version)
            entry.hits += 1
            self.stats.hits += 1
            return entry.engine
        need = int(self._entry_bytes(model))
        if need > self.byte_budget:
            raise PoolBudgetError(
                f"model {model.version!r} needs {need} bytes but the pool "
                f"budget is {self.byte_budget}", retryable=False)
        pinned_bytes = sum(e.nbytes for v, e in self._entries.items()
                           if self.pinned(v))
        if pinned_bytes + need > self.byte_budget:
            raise PoolBudgetError(
                f"cannot admit {model.version!r} ({need} bytes): "
                f"{pinned_bytes} bytes pinned by live submissions",
                retryable=True)
        self._evict_until(self.byte_budget - need)
        engine = self._engine_factory(model)
        self._entries[model.version] = PoolEntry(engine=engine, nbytes=need)
        self.stats.misses += 1
        self.stats.peak_resident_models = max(self.stats.peak_resident_models,
                                              len(self._entries))
        self.stats.peak_resident_bytes = max(self.stats.peak_resident_bytes,
                                             self.resident_bytes)
        return engine

    def engine_for(self, qsig: str, probe: Iterable[str] = (), *,
                   optimize: bool = True) -> Engine:
        """``resolve`` + ``admit`` in one call (the no-retry path)."""
        return self.admit(self.resolve(qsig, probe, optimize=optimize))

    def _evict_until(self, budget: int) -> None:
        """Evict least-recently-used unpinned entries until resident
        bytes fit in ``budget``; deterministic (LRU order)."""
        while self.resident_bytes > budget:
            victim = next((v for v in self._entries if not self.pinned(v)),
                          None)
            if victim is None:
                return
            del self._entries[victim]
            self.stats.evictions += 1
            self.eviction_log.append(victim)


# ---------------------------------------------------------------------------
# fair-share scheduling
# ---------------------------------------------------------------------------

_EXHAUSTED = object()


@dataclass
class Submission:
    """One tenant's prompt stream bound for one model."""
    tenant: str
    prompts: Iterator[str]
    qsig: str
    probe: List[str]
    max_new: int
    prefix: Optional[str]
    optimize: bool
    engine: Optional[Engine] = None
    model: Any = None            # resolved once; re-admitted on retries
    error: Optional[BaseException] = None   # terminal admission failure
    reqs: List = field(default_factory=list)
    inflight: Set[int] = field(default_factory=set)
    exhausted: bool = False
    peak_inflight: int = 0
    first_done_tick: Optional[int] = None
    last_done_tick: Optional[int] = None

    @property
    def active(self) -> bool:
        return self.engine is not None

    @property
    def done(self) -> bool:
        if self.error is not None:
            return True
        return self.active and self.exhausted and not self.inflight

    def results(self) -> List[str]:
        """Decoded rows in prompt order; re-raises this submission's
        terminal error (e.g. its model can never fit the pool budget)
        at the consumer instead of aborting unrelated tenants' work."""
        if self.error is not None:
            raise self.error
        return [r.text for r in self.reqs]


@dataclass
class SchedulerStats:
    ticks: int = 0
    rows: int = 0
    wall_s: float = 0.0

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.wall_s if self.wall_s else 0.0


class Scheduler:
    """Interleaves ``Engine.step()`` across the pool's engines.

    ``share`` bounds each submission's un-finished rows: every tick
    tops every active submission up to ``share`` (round-robin rotation
    so admission order is fair), then runs one decode tick per engine
    with work.  Submissions whose model cannot become resident yet
    (budget full of pinned engines) wait in FIFO order and activate as
    pins release — head-of-line activation, so waiting is starvation-
    free too.
    """

    def __init__(self, pool: ModelPool, *, share: int = 8):
        self.pool = pool
        self.share = max(1, share)
        self.pending: "deque[Submission]" = deque()
        self.active: List[Submission] = []
        self.finished: List[Submission] = []
        self.stats = SchedulerStats()
        self.trace: List[Tuple[int, str]] = []   # (tick, tenant) per row
        self._owners: Dict[Tuple[int, int], Submission] = {}
        self._rr = 0

    # -- submission -----------------------------------------------------
    def submit(self, tenant: str, prompts: Iterable[str], *, qsig: str,
               probe: Optional[Iterable[str]] = None, max_new: int = 16,
               prefix: Optional[str] = None,
               optimize: bool = True) -> Submission:
        """Enqueue one tenant's prompt stream; prompts are consumed
        lazily as the scheduler admits them."""
        sub = Submission(tenant=tenant, prompts=iter(prompts), qsig=qsig,
                         probe=list(probe or []), max_new=max_new,
                         prefix=prefix, optimize=optimize)
        self.pending.append(sub)
        self._activate()
        return sub

    def _activate(self) -> None:
        """FIFO head-of-line activation of pending submissions."""
        while self.pending:
            sub = self.pending[0]
            try:
                if sub.model is None:       # optimize exactly once
                    sub.model = self.pool.resolve(sub.qsig, sub.probe,
                                                  optimize=sub.optimize)
                engine = self.pool.admit(sub.model)
            except PoolBudgetError as e:
                if not e.retryable:
                    # this submission can NEVER fit: fail it alone (the
                    # error surfaces from its results()) and keep
                    # scheduling everyone else
                    self.pending.popleft()
                    sub.error = e
                    self.finished.append(sub)
                    continue
                return          # budget full of pinned engines: wait
            self.pool.pin(engine.version)
            sub.engine = engine
            self.active.append(sub)
            self.pending.popleft()

    # -- the tick -------------------------------------------------------
    def _top_up(self, sub: Submission) -> None:
        while len(sub.inflight) < self.share and not sub.exhausted:
            p = next(sub.prompts, _EXHAUSTED)
            if p is _EXHAUSTED:
                sub.exhausted = True
                break
            r = sub.engine.submit(p, max_new=sub.max_new, prefix=sub.prefix)
            sub.reqs.append(r)
            if r.done:          # result-cache hit: resolved instantly
                self._record_done(sub)
            else:
                sub.inflight.add(r.rid)
                self._owners[(id(sub.engine), r.rid)] = sub
        sub.peak_inflight = max(sub.peak_inflight, len(sub.inflight))

    def _record_done(self, sub: Submission) -> None:
        self.stats.rows += 1
        self.trace.append((self.stats.ticks, sub.tenant))
        if sub.first_done_tick is None:
            sub.first_done_tick = self.stats.ticks
        sub.last_done_tick = self.stats.ticks

    def _retire_done(self) -> None:
        still = []
        for sub in self.active:
            if sub.done:
                self.pool.unpin(sub.engine.version)
                self.finished.append(sub)
            else:
                still.append(sub)
        self.active[:] = still

    def step(self) -> bool:
        """One fair-share tick; returns True while work remains."""
        self._activate()
        self.stats.ticks += 1
        n = len(self.active)
        for i in range(n):          # rotating round-robin admission
            self._top_up(self.active[(self._rr + i) % n])
        if n:
            self._rr = (self._rr + 1) % n
        # one decode tick per distinct engine with work, in activation
        # order (deterministic)
        engines: "OrderedDict[int, Engine]" = OrderedDict()
        for sub in self.active:
            engines.setdefault(id(sub.engine), sub.engine)
        for eid, eng in engines.items():
            if not eng.has_work():
                continue
            for req in eng.step():
                owner = self._owners.pop((eid, req.rid), None)
                if owner is not None:
                    owner.inflight.discard(req.rid)
                    self._record_done(owner)
        self._retire_done()
        self._activate()            # released pins may admit waiters
        return bool(self.active or self.pending)

    def run(self) -> List[Submission]:
        """Tick until every submission completes; returns them all."""
        t0 = time.time()
        while self.step():
            pass
        self.stats.wall_s += time.time() - t0
        return self.finished

    # -- whole-query concurrency ---------------------------------------
    def run_queries(self, queries: Dict[str, Any]) -> Dict[str, Any]:
        """Drive OLAP query *plans* concurrently: ``queries`` maps
        tenant -> ``Query``; each plan's LLM operators run in order,
        but operators of different tenants interleave tick-by-tick.
        Returns tenant -> result Table."""
        gens = {t: q._ops() for t, q in queries.items()}
        optimize = {t: q.optimize for t, q in queries.items()}
        results: Dict[str, Any] = {}
        current: Dict[str, Submission] = {}

        def advance(tenant: str, send_val) -> None:
            try:
                qsig, probe, spec = gens[tenant].send(send_val)
            except StopIteration as stop:
                results[tenant] = stop.value
                return
            current[tenant] = self.submit(
                tenant, spec.prompts, qsig=qsig, probe=probe,
                max_new=spec.max_new, prefix=spec.prefix,
                optimize=optimize[tenant])

        t0 = time.time()
        for tenant in queries:
            advance(tenant, None)
        while current:
            self.step()
            for tenant in list(current):
                sub = current[tenant]
                if sub.done:
                    del current[tenant]
                    advance(tenant, sub.results())
        self.stats.wall_s += time.time() - t0
        return results
