"""Result cache (paper §3.3): exact-match memoization of LLM outputs.

OLAP columns are full of duplicates (categories, enums, repeated
entities); identical (prompt, params-version) pairs short-circuit the
model entirely.  LRU with hit accounting — the cache-hit rate is one of
the Table-1-adjacent numbers benchmarks report.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple


class ResultCache:
    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def key(self, prompt: str, max_new: int, version: str = "") -> Tuple:
        return (prompt, max_new, version)

    def get(self, key) -> Optional[str]:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def peek(self, key) -> Optional[str]:
        """Lookup without touching hit/miss accounting or LRU order.

        The engine separates *lookup* from *accounting*: a prompt whose
        twin is still decoding counts as a hit (it never reaches the
        model) even though the value isn't stored yet, so the engine
        peeks first and then records exactly one hit or miss per
        request via record_hit / record_miss.
        """
        return self._d.get(key)

    def record_hit(self, key=None) -> None:
        self.hits += 1
        if key is not None and key in self._d:
            self._d.move_to_end(key)

    def record_miss(self) -> None:
        self.misses += 1

    def put(self, key, value: str) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0
