"""Serving caches (paper §3.3): result memoization + prefix KV sharing.

``ResultCache``: OLAP columns are full of duplicates (categories,
enums, repeated entities); identical (prompt, params-version) pairs
short-circuit the model entirely.  LRU with hit accounting — the
cache-hit rate is one of the Table-1-adjacent numbers benchmarks
report.

``PrefixCache``: template-heavy operators render every row through a
fixed prompt template, so the template's token prefix is prefilled
once per (template, model version) and its KV/recurrent state is
reused to seed every row's per-slot state — per-row prefill then
processes only the row suffix (Liu et al., "Optimizing LLM Queries in
Relational Workloads").  ``version`` in the key invalidates entries
when a query swaps in a recompressed instance-optimized model.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple


class ResultCache:
    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def key(self, prompt: str, max_new: int, version: str = "") -> Tuple:
        return (prompt, max_new, version)

    def get(self, key) -> Optional[str]:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def peek(self, key) -> Optional[str]:
        """Lookup without touching hit/miss accounting or LRU order.

        The engine separates *lookup* from *accounting*: a prompt whose
        twin is still decoding counts as a hit (it never reaches the
        model) even though the value isn't stored yet, so the engine
        peeks first and then records exactly one hit or miss per
        request via record_hit / record_miss.
        """
        return self._d.get(key)

    def record_hit(self, key=None) -> None:
        self.hits += 1
        if key is not None and key in self._d:
            self._d.move_to_end(key)

    def record_miss(self) -> None:
        self.misses += 1

    def put(self, key, value: str) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0


# ---------------------------------------------------------------------------
# prefix KV sharing
# ---------------------------------------------------------------------------

@dataclass
class PrefixEntry:
    """One prefilled template prefix: the family cache pytree (batch=1,
    full ``max_len`` slots for attention families; O(1) recurrent state
    for rwkv/hybrid) plus the prefix token count."""
    state: Any
    prefix_len: int
    hits: int = 0            # rows seeded from this entry


class PrefixCache:
    """LRU of prefilled template prefixes.

    Keyed on ``(prefix token tuple, model version)``: the token prefix
    identifies the rendered template, the version ties the stored
    KV/state to the exact parameter set that produced it — an
    instance-optimized (recompressed) model gets fresh entries instead
    of decoding against stale activations.  Capacity is small: entries
    hold device arrays sized like one decode slot.
    """

    def __init__(self, capacity: int = 8):
        self.capacity = capacity
        self._d: "OrderedDict[Tuple, PrefixEntry]" = OrderedDict()
        self.hits = 0            # entry-level lookup hits
        self.misses = 0
        # fn(key, entry) called on LRU eviction — paged engines subscribe
        # so their block allocators can release the entry's shared blocks
        # (a pool-shared cache holds entries from many engines; each
        # subscriber ignores keys it never seeded)
        self._evict_listeners: list = []

    def add_evict_listener(self, fn) -> None:
        if fn not in self._evict_listeners:
            self._evict_listeners.append(fn)

    def key(self, prefix_ids: Sequence[int], version: str = "") -> Tuple:
        return (tuple(prefix_ids), version)

    def get(self, key) -> Optional[PrefixEntry]:
        e = self._d.get(key)
        if e is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return e

    def put(self, key, state, prefix_len: int) -> PrefixEntry:
        e = PrefixEntry(state=state, prefix_len=prefix_len)
        self._d[key] = e
        self._d.move_to_end(key)
        if len(self._d) > self.capacity:
            old_key, old_entry = self._d.popitem(last=False)
            for fn in self._evict_listeners:
                fn(old_key, old_entry)
        return e

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0
