"""Token sampling: greedy / temperature / top-k (f32 logits).

``SamplingConfig`` is the static half (closed over when the engine
traces its decode step — temperature/top_k pick the lowered program,
seed roots the PRNG stream); the per-step key is derived inside the jit
via ``fold_in(base_key, step_counter)`` so decode stays replayable and
``temperature=0`` lowers to exactly the greedy ``argmax`` program.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key, *, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """Sample next tokens from ``logits`` ([..., vocab]).  ``key`` may be
    None when ``temperature <= 0`` (greedy needs no randomness)."""
    if temperature <= 0.0:
        return greedy(logits)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        kth = vals[..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def token_confidence(logits: jax.Array, tok: jax.Array) -> jax.Array:
    """Answer-token probability of the emitted token under the raw
    (untempered) softmax: ``p = exp(logit[tok] - logsumexp(logits))``.

    This is the cascade's acceptance signal (olap/README.md §Cascades):
    it is computed from arrays already live inside the jitted decode
    step — pure device math, no host callback — and calibrated against
    an accuracy budget by ``core.calibrate.fit_confidence_threshold``.
    ``logits`` is [..., vocab], ``tok`` the matching [...] int tokens;
    returns f32 probabilities in [0, 1].
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    chosen = jnp.take_along_axis(lf, tok[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
    return jnp.exp(chosen - lse)
