"""Token sampling: greedy / temperature / top-k (f32 logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key, *, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    if temperature <= 0.0:
        return greedy(logits)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        kth = vals[..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
