"""Request batching (paper §3.3): group rows to amortize invocation cost.

Buckets prompts by padded length (powers of two between min and max) so
the jit cache holds one prefill executable per bucket instead of one per
distinct length.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence


@dataclass
class Request:
    rid: int
    prompt_ids: List[int]            # full prompt, or row suffix when split
    max_new: int
    # filled during serving
    out_ids: List[int] = field(default_factory=list)
    done: bool = False
    cache_key: Optional[tuple] = None
    text: Optional[str] = None       # decoded output, set on completion
    truncated: bool = False          # prompt clipped to the top bucket
    follower: bool = False           # riding on an in-flight duplicate
    # cascade acceptance signal: min answer-token probability over every
    # emitted token (sampler.token_confidence), updated as the jitted
    # decode step's confidence output lands.  inf until the first token
    # (an empty output is "never doubted"); followers and result-cache
    # hits inherit their leader's value.
    confidence: float = float("inf")
    # prefix sharing: template token prefix split off at submit()
    prefix_ids: Optional[List[int]] = None
    prefix_key: Optional[tuple] = None   # PrefixCache key (ids, version)
    # original prompt text, kept so a scheduler can re-submit the row to
    # a replacement engine after a mid-tick engine fault (quarantine)
    src: Optional[str] = None


def bucket_len(n: int, buckets: Sequence[int]) -> int:
    if not buckets:
        return n
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class Batcher:
    """FIFO admission with length-bucketing."""

    def __init__(self, buckets: Sequence[int] = (32, 64, 128, 256, 512)):
        self.buckets = tuple(sorted(buckets))
        self.queue: List[Request] = []

    def add(self, req: Request) -> None:
        self.queue.append(req)

    def take(self, n: int) -> List[Request]:
        """Up to n requests sharing one length bucket AND one prefix
        entry (FIFO head defines both so no request starves).  Prefix
        uniformity matters because admission seeds every row of the
        batch from a single shared prefix state; requests are bucketed
        on their *suffix* when a prefix was split off."""
        if not self.queue or n <= 0:
            return []
        head = self.queue[0]
        head_b = bucket_len(len(head.prompt_ids), self.buckets)
        out, rest = [], []
        for r in self.queue:
            if len(out) < n and r.prefix_key == head.prefix_key \
                    and bucket_len(len(r.prompt_ids),
                                   self.buckets) == head_b:
                out.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return out

    def __len__(self) -> int:
        return len(self.queue)
