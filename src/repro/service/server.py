"""Stdlib HTTP front-end for the semantic query service.

``http.server.ThreadingHTTPServer`` + ``BaseHTTPRequestHandler`` — no
new dependencies, per the service's design constraint.  Handler threads
never touch an engine: they parse the request, run admission, enqueue
the job, and then *stream* the job's event queue back as NDJSON
(one JSON object per line, flushed per event) so the client sees rows
as the pump emits them.  Responses are close-delimited (HTTP/1.0
framing): no Content-Length is needed for a stream whose end is the
connection close, and every stdlib client can read it.

Endpoints:

  GET  /healthz            -> {"ok": true, "uptime_s": ...}
  GET  /stats              -> full stats JSON (core.stats_dict)
  GET  /stats?format=text  -> EXPLAIN-style text (serving/metrics.py)
  POST /query              -> body {"tenant": ..., "spec": ...};
                              200 + NDJSON event stream, or
                              429 + Retry-After on SLO shed, or
                              400 on a malformed spec
  POST /checkpoint         -> body {"dir": ...}; warm-state save
  POST /shutdown           -> acknowledge, then stop serving

A 429 body carries the machine-readable shed verdict
(reason / retry_after_s / detail) so clients can back off precisely.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple
from urllib.parse import parse_qs, urlparse

from repro.serving.metrics import render_stats
from repro.service.checkpoint import save_warm_state
from repro.service.core import SemanticQueryService
from repro.service.slo import Shed


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.0"      # close-delimited streaming
    server_version = "iolm-service/1"

    # quiet by default; the CI smoke job flips this on
    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    @property
    def svc(self) -> SemanticQueryService:
        return self.server.service

    def _send_json(self, code: int, obj, *, headers=()) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in headers:
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self):
        n = int(self.headers.get("Content-Length", 0))
        return json.loads(self.rfile.read(n) or b"{}")

    def do_GET(self) -> None:
        url = urlparse(self.path)
        if url.path == "/healthz":
            self._send_json(200, {"ok": True,
                                  "uptime_s": self.svc.stats_dict()
                                  ["service"]["uptime_s"]})
            return
        if url.path == "/stats":
            stats = self.svc.stats_dict()
            fmt = parse_qs(url.query).get("format", ["json"])[0]
            if fmt == "text":
                body = render_stats(stats).encode()
                self.send_response(200)
                self.send_header("Content-Type", "text/plain")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self._send_json(200, stats)
            return
        self._send_json(404, {"error": f"no route {url.path}"})

    def do_POST(self) -> None:
        url = urlparse(self.path)
        if url.path == "/query":
            self._handle_query()
            return
        if url.path == "/checkpoint":
            body = self._read_body()
            path = save_warm_state(self.svc.session, body["dir"])
            self._send_json(200, {"ok": True, "dir": path})
            return
        if url.path == "/shutdown":
            self._send_json(200, {"ok": True})
            # shut down from another thread: shutdown() blocks until
            # serve_forever returns, which can't happen on this thread
            threading.Thread(target=self.server.shutdown,
                             daemon=True).start()
            return
        self._send_json(404, {"error": f"no route {url.path}"})

    def _handle_query(self) -> None:
        try:
            body = self._read_body()
            tenant = body["tenant"]
            res = self.svc.submit_spec(tenant, body["spec"])
        except (KeyError, ValueError, TypeError) as e:
            self._send_json(400, {"error": str(e),
                                  "kind": type(e).__name__})
            return
        if isinstance(res, Shed):
            self._send_json(
                429,
                {"error": "shed", "reason": res.reason,
                 "retry_after_s": res.retry_after_s,
                 "detail": res.detail},
                headers=(("Retry-After", f"{res.retry_after_s:.3f}"),))
            return
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.end_headers()
        try:
            for ev in res.stream():
                self.wfile.write(json.dumps(ev).encode() + b"\n")
                self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            pass        # client went away; the pump finishes the job


class ServiceHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr, service: SemanticQueryService, *,
                 verbose: bool = False):
        self.service = service
        self.verbose = verbose
        super().__init__(addr, _Handler)


def serve(service: SemanticQueryService, *, host: str = "127.0.0.1",
          port: int = 0, block: bool = True,
          verbose: bool = False) -> Tuple[ServiceHTTPServer,
                                          Optional[threading.Thread]]:
    """Bind and serve.  ``port=0`` picks a free port (read it back from
    ``server.server_address``).  ``block=False`` serves on a background
    thread and returns immediately — the test-suite/CI mode; callers
    stop it with ``server.shutdown()`` then ``service.stop()``."""
    service.start()
    server = ServiceHTTPServer((host, port), service, verbose=verbose)
    if block:
        try:
            server.serve_forever()
        finally:
            server.server_close()
            service.stop()
        return server, None
    t = threading.Thread(target=server.serve_forever,
                         name="service-http", daemon=True)
    t.start()
    return server, t
