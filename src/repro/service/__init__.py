"""Always-on semantic query service over the IOLM-DB serving spine.

The paper's "millions of users" framing (PAPER.md §1) only holds for a
long-running service, not a script that drives the library once.  This
package is that service: a stdlib-only HTTP front-end
(:mod:`repro.service.server`) over a single pump thread
(:mod:`repro.service.core`) that drives the fair-share ``Scheduler``
tick loop, per-tenant SLO admission control with 429-style shedding
(:mod:`repro.service.slo`), a retrying client
(:mod:`repro.service.client`), and warm restart of the session's
instance-optimization state (:mod:`repro.service.checkpoint`).

See src/repro/service/README.md for the architecture walk-through.
"""
from repro.service.client import ServiceClient
from repro.service.core import SemanticQueryService
from repro.service.checkpoint import restore_warm_state, save_warm_state
from repro.service.server import serve
from repro.service.slo import AdmissionController, TenantSLO

__all__ = [
    "AdmissionController",
    "SemanticQueryService",
    "ServiceClient",
    "TenantSLO",
    "restore_warm_state",
    "save_warm_state",
    "serve",
]
