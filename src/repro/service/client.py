"""Stdlib client for the semantic query service.

``http.client`` only — the client mirrors the server's no-new-deps
constraint so tests and the CI smoke job can drive a real socket
round-trip anywhere Python runs.  ``query()`` POSTs a plan spec and
parses the NDJSON event stream; on a 429 it honours the server's
``Retry-After`` hint (bounded exponential backoff on top, so a
mis-behaving server cannot park the client forever) and retries within
``max_retries``.
"""
from __future__ import annotations

import http.client
import json
import time
from typing import Any, Dict, Iterator, List, Optional


class ShedError(RuntimeError):
    """Raised when the retry budget is exhausted on 429s."""

    def __init__(self, verdict: Dict[str, Any]):
        super().__init__(f"query shed after retries: {verdict}")
        self.verdict = verdict


class QueryError(RuntimeError):
    """Terminal server-side query failure (the stream's error event)."""


class ServiceClient:
    def __init__(self, host: str, port: int, *, timeout: float = 120.0,
                 max_retries: int = 5, backoff_s: float = 0.05,
                 max_backoff_s: float = 2.0):
        self.host = host
        self.port = port
        self.timeout = timeout
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s

    def _conn(self) -> http.client.HTTPConnection:
        return http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)

    def _get_json(self, path: str) -> Dict[str, Any]:
        c = self._conn()
        try:
            c.request("GET", path)
            r = c.getresponse()
            return json.loads(r.read())
        finally:
            c.close()

    def _post_json(self, path: str, body: Dict[str, Any]):
        c = self._conn()
        try:
            c.request("POST", path, body=json.dumps(body),
                      headers={"Content-Type": "application/json"})
            r = c.getresponse()
            return r.status, json.loads(r.read())
        finally:
            c.close()

    # -- queries --------------------------------------------------------
    def iter_query(self, tenant: str,
                   spec: Dict[str, Any]) -> Iterator[Dict[str, Any]]:
        """POST the spec and yield the event stream; retries 429s with
        Retry-After-honouring bounded backoff before giving up."""
        verdict: Optional[Dict[str, Any]] = None
        for attempt in range(self.max_retries + 1):
            c = self._conn()
            try:
                c.request("POST", "/query",
                          body=json.dumps({"tenant": tenant,
                                           "spec": spec}),
                          headers={"Content-Type": "application/json"})
                r = c.getresponse()
                if r.status == 429:
                    verdict = json.loads(r.read())
                    c.close()
                    if attempt == self.max_retries:
                        break
                    hint = float(r.headers.get(
                        "Retry-After",
                        verdict.get("retry_after_s", self.backoff_s)))
                    wait = min(self.max_backoff_s,
                               max(hint, self.backoff_s * 2 ** attempt))
                    time.sleep(wait)
                    continue
                if r.status != 200:
                    err = json.loads(r.read())
                    c.close()
                    raise QueryError(f"HTTP {r.status}: {err}")
                for line in r:
                    line = line.strip()
                    if line:
                        yield json.loads(line)
                return
            finally:
                c.close()
        raise ShedError(verdict or {"reason": "unknown"})

    def query(self, tenant: str,
              spec: Dict[str, Any]) -> List[Dict[str, Any]]:
        """The collected result rows, in index order; raises
        ``QueryError`` on a server-side failure event."""
        rows: List[Dict[str, Any]] = []
        for ev in self.iter_query(tenant, spec):
            if ev.get("event") == "row":
                rows.append(ev["row"])
            elif ev.get("event") == "error":
                raise QueryError(f"{ev.get('kind')}: {ev.get('error')}")
        return rows

    # -- control plane --------------------------------------------------
    def healthz(self) -> Dict[str, Any]:
        return self._get_json("/healthz")

    def stats(self) -> Dict[str, Any]:
        return self._get_json("/stats")

    def stats_text(self) -> str:
        c = self._conn()
        try:
            c.request("GET", "/stats?format=text")
            return c.getresponse().read().decode()
        finally:
            c.close()

    def checkpoint(self, ckpt_dir: str) -> Dict[str, Any]:
        status, body = self._post_json("/checkpoint", {"dir": ckpt_dir})
        if status != 200:
            raise QueryError(f"checkpoint failed: HTTP {status} {body}")
        return body

    def shutdown(self) -> None:
        status, _ = self._post_json("/shutdown", {})
        if status != 200:
            raise QueryError(f"shutdown refused: HTTP {status}")
