"""Warm restart: checkpoint/restore of a session's optimization state.

The expensive part of IOLM-DB is not serving — it is the per-(qsig,
dsig) instance-optimization search (calibration + recipe search) and
the cascade threshold fits.  A service restart that loses them pays
the whole bill again on the first query.  ``save_warm_state`` persists
the three pieces that make a restart *warm*:

  1. the **ModelCache**: every compressed model's params (via
     ``training/checkpoint.py``'s atomic array writer — one
     self-validating checkpoint per model under ``models/m<i>/``),
     its ``ModelConfig`` and winning ``Recipe``;
  2. the **cascade_cache**: fitted acceptance thresholds per
     (qsig, dsig, budget) — plain JSON (``inf`` thresholds round-trip
     through Python json's ``Infinity`` literal);
  3. the **pool-residency manifest**: which model versions were
     engine-resident at save time, so a restart can rebuild the same
     working set eagerly instead of on first request.

The top-level ``service_state.json`` manifest is written LAST with
``atomic_write_json``, so a crash mid-save leaves the previous state
readable: restore only trusts models the manifest lists.

``restore_warm_state`` rebuilds the caches in a fresh process — array
state through ``restore_tree`` (no pytree template needed: this
process never built these models) — and pre-admits previously
resident engines.  The contract (regression-tested in
tests/test_service.py): a restored session answers a previously seen
(qsig, dsig) query with ``session.recalibrations == 0`` and
``session.cascade_fits == 0``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict

from repro.core.calibrate import CascadeCalibration
from repro.core.pipeline import Recipe
from repro.configs.base import ModelConfig
from repro.olap.query import IOLMSession, OptimizedModel
from repro.training import checkpoint as CKPT

MANIFEST = "service_state.json"


def save_warm_state(session: IOLMSession, ckpt_dir: str) -> str:
    """Persist model cache + cascade thresholds + pool residency."""
    os.makedirs(ckpt_dir, exist_ok=True)
    models = []
    for i, ((qsig, dsig), m) in enumerate(session.model_cache._d.items()):
        entry: Dict[str, Any] = {
            "qsig": qsig, "dsig": dsig, "version": m.version,
            "recipe": dataclasses.asdict(m.recipe),
            # identity picks (nothing survived the search) carry the
            # session's own base params — never re-serialized
            "identity": m.params is session.params,
        }
        if not entry["identity"]:
            mdir = os.path.join("models", f"m{i}")
            CKPT.save(os.path.join(ckpt_dir, mdir), 0, m.params,
                      extra={"cfg": dataclasses.asdict(m.cfg)}, keep=1)
            entry["dir"] = mdir
        models.append(entry)
    cascades = [{"qsig": q, "dsig": d, "budget": b,
                 "cal": cal.to_dict()}
                for (q, d, b), cal in session.cascade_cache.items()]
    residency = (session.pool.resident_versions
                 if session.pool is not None else [])
    CKPT.atomic_write_json(
        os.path.join(ckpt_dir, MANIFEST),
        {"version": 1, "models": models, "cascades": cascades,
         "residency": residency})
    return ckpt_dir


def _recipe_from_dict(d: Dict[str, Any]) -> Recipe:
    d = dict(d)
    d["nm"] = tuple(d.get("nm", (0, 0)))
    return Recipe(**d)


def restore_warm_state(session: IOLMSession, ckpt_dir: str, *,
                       prewarm: bool = True) -> Dict[str, Any]:
    """Load warm state into ``session``; returns the manifest.

    ``prewarm=True`` additionally re-admits engines for the model
    versions that were pool-resident at save time (best effort: a
    smaller pool budget on the restarted host simply ends up with a
    smaller working set, never an error)."""
    with open(os.path.join(ckpt_dir, MANIFEST)) as f:
        manifest = json.load(f)
    if manifest.get("version") != 1:
        raise ValueError(
            f"unsupported warm-state version {manifest.get('version')!r}")
    by_version: Dict[str, OptimizedModel] = {}
    for entry in manifest["models"]:
        if entry["identity"]:
            m = OptimizedModel(session.params, session.cfg, None,
                               _recipe_from_dict(entry["recipe"]),
                               entry["version"])
        else:
            params, _, extra = CKPT.restore_tree(
                os.path.join(ckpt_dir, entry["dir"]))
            m = OptimizedModel(params, ModelConfig(**extra["cfg"]), None,
                               _recipe_from_dict(entry["recipe"]),
                               entry["version"])
        session.model_cache.put(entry["qsig"], entry["dsig"], m)
        by_version[m.version] = m
    for c in manifest["cascades"]:
        session.cascade_cache[(c["qsig"], c["dsig"],
                               float(c["budget"]))] = \
            CascadeCalibration.from_dict(c["cal"])
    if prewarm and session.pool is not None:
        for version in manifest["residency"]:
            try:
                if version == "base":
                    session.pool.engine_for("base", optimize=False)
                elif version in by_version:
                    session.pool.admit(by_version[version])
            except Exception:
                # best effort: budget/device mismatches on the new
                # host shrink the prewarmed set, nothing more
                session.log.append(
                    f"[warm] could not pre-admit {version}")
    session.log.append(
        f"[warm] restored {len(manifest['models'])} models, "
        f"{len(manifest['cascades'])} cascade fits from {ckpt_dir}")
    return manifest
