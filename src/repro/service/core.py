"""The service core: one pump thread driving the scheduler tick loop.

Threading model — the part worth reading twice: ALL engine/jax work
happens on ONE thread (the pump).  HTTP handler threads (server.py)
only parse specs, run admission, and enqueue ``QueryJob``s on the
inbox; the pump thread starts a ``QueryDriver`` per job, ticks the
shared ``Scheduler`` while any driver is live, polls each driver, and
emits progress events onto the job's private event queue — which the
handler thread drains back to the client as NDJSON.  Single-threaded
engine access means the service inherits the scheduler's byte-identical
determinism contract for free: the HTTP path and a direct
``Scheduler.run_queries`` call produce identical rows
(tests/test_service.py asserts this), and no jax computation ever runs
concurrently with itself.

Event stream per query (in order):

  {"event": "op",    "index": i, "kind": ..., "qsig": ..., "rows": n}
  {"event": "row",   "index": i, "row": {col: value, ...}}   (per row)
  {"event": "done",  "rows": n, "ops": k}
  {"event": "error", "error": "...", "kind": "ExcType"}      (terminal)

Rows stream strictly in index order — result order is part of the
byte-identity contract, not a best-effort property.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

from repro.olap.query import IOLMSession, Query, query_from_spec
from repro.olap.table import Table
from repro.serving.scheduler import QueryDriver, Scheduler
from repro.service.slo import AdmissionController, Shed, TenantSLO


def table_rows(table: Table) -> List[Dict[str, Any]]:
    """A Table as an ordered list of row dicts (the wire row form)."""
    cols = list(table.columns)
    return [dict(zip(cols, vals))
            for vals in zip(*(table.columns[c] for c in cols))] \
        if cols else []


class QueryJob:
    """One admitted query: the spec-built plan plus its event queue."""

    def __init__(self, jid: int, tenant: str, query: Query, *,
                 est_rows: int, est_tokens: float,
                 share: Optional[int] = None):
        self.jid = jid
        self.tenant = tenant
        self.query = query
        self.est_rows = est_rows
        self.est_tokens = est_tokens
        self.share = share
        self.events: "queue.Queue[Dict[str, Any]]" = queue.Queue()
        self.driver: Optional[QueryDriver] = None

    def stream(self, timeout: float = 120.0) -> Iterator[Dict[str, Any]]:
        """Drain events until the terminal done/error event (incl.)."""
        while True:
            ev = self.events.get(timeout=timeout)
            yield ev
            if ev.get("event") in ("done", "error"):
                return

    def rows(self, timeout: float = 120.0) -> List[Dict[str, Any]]:
        """Block for the result rows; raises on a query error."""
        out: List[Dict[str, Any]] = []
        for ev in self.stream(timeout=timeout):
            if ev["event"] == "row":
                out.append(ev["row"])
            elif ev["event"] == "error":
                raise RuntimeError(
                    f"query failed ({ev.get('kind')}): {ev['error']}")
        return out


class SemanticQueryService:
    """Always-on front half of the stack: admission + pump + stats.

    Wraps one ``IOLMSession`` (which must carry a ``ModelPool``) and
    one ``Scheduler``; jobs admitted by the ``AdmissionController``
    flow through ``QueryDriver``s interleaved tick-by-tick exactly as
    ``Scheduler.run_queries`` would interleave them — the service IS
    run_queries unrolled over an unbounded, dynamically arriving job
    stream.
    """

    def __init__(self, session: IOLMSession, *,
                 slos: Optional[Dict[str, TenantSLO]] = None,
                 default_slo: Optional[TenantSLO] = None,
                 share: int = 8, max_retries: int = 2,
                 idle_wait_s: float = 0.02):
        if session.pool is None:
            raise ValueError("SemanticQueryService needs a pooled session "
                             "(pass pool_budget= to IOLMSession)")
        self.session = session
        self.sched = Scheduler(session.pool, share=share,
                               max_retries=max_retries)
        self.admission = AdmissionController(slos, default=default_slo)
        self.idle_wait_s = idle_wait_s
        self.t0 = time.time()
        self.queries = 0
        self.shed = 0
        self.errors = 0
        self._jid = itertools.count(1)
        self._inbox: "queue.Queue[QueryJob]" = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "SemanticQueryService":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._pump, name="service-pump", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 60.0) -> None:
        """Graceful: the pump finishes every started job, then exits."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    # -- admission + submit ---------------------------------------------
    def estimate(self, q: Query) -> tuple:
        """(est result rows, est prompt tokens) from the physical plan
        — the admission charge.  Plan lowering is pure (no engine
        work), so this is safe on a handler thread."""
        pplan = q.physical_plan()
        rows = len(q.table)
        for step in pplan.llm_ops:
            rows = max(rows, step.est.invocations)
        return max(1, rows), float(pplan.optimized_cost)

    def submit_spec(self, tenant: str, spec: Dict[str, Any]):
        """Parse + admit one query spec.  Returns a ``QueryJob`` whose
        events stream the execution, or a ``Shed`` verdict (the HTTP
        layer's 429).  Raises ``ValueError`` on a malformed spec (the
        HTTP layer's 400)."""
        q = query_from_spec(spec, self.session)
        return self.submit_query(tenant, q)

    def submit_query(self, tenant: str, q: Query):
        est_rows, est_tokens = self.estimate(q)
        slo = self.admission.slo_for(tenant)
        verdict = self.admission.try_admit(tenant, est_rows, est_tokens)
        if isinstance(verdict, Shed):
            self.shed += 1
            return verdict
        job = QueryJob(next(self._jid), tenant, q,
                       est_rows=est_rows, est_tokens=est_tokens,
                       share=slo.share)
        self.queries += 1
        self._inbox.put(job)
        return job

    # -- the pump -------------------------------------------------------
    def _pump(self) -> None:
        active: List[QueryJob] = []
        while True:
            # drain newly admitted jobs; block briefly when idle so an
            # idle service costs no CPU, never when work is in flight
            try:
                while True:
                    job = (self._inbox.get_nowait() if active else
                           self._inbox.get(timeout=self.idle_wait_s))
                    self._start_job(job, active)
            except queue.Empty:
                pass
            if not active:
                if self._stop.is_set() and self._inbox.empty():
                    return
                continue
            self.sched.step()
            for job in list(active):
                job.driver.poll()
                if job.driver.finished:
                    active.remove(job)
                    self._finish_job(job)

    def _start_job(self, job: QueryJob, active: List[QueryJob]) -> None:
        def on_op(driver, op, outs):
            job.events.put({"event": "op", "index": driver.ops_done,
                            "kind": op.spec.kind, "qsig": op.qsig,
                            "rows": len(outs)})

        job.driver = QueryDriver(self.sched, job.tenant, job.query,
                                 share=job.share, on_op_done=on_op)
        try:
            job.driver.start()
        except Exception as e:     # plan construction failure
            job.driver.error = e
        if job.driver.finished:
            self._finish_job(job)
        else:
            active.append(job)

    def _finish_job(self, job: QueryJob) -> None:
        self.admission.release(job.tenant, job.est_rows)
        d = job.driver
        if d.error is not None:
            self.errors += 1
            job.events.put({"event": "error", "error": str(d.error),
                            "kind": type(d.error).__name__})
            return
        rows = table_rows(d.result)
        for i, row in enumerate(rows):
            job.events.put({"event": "row", "index": i, "row": row})
        job.events.put({"event": "done", "rows": len(rows),
                        "ops": d.ops_done})

    # -- observability --------------------------------------------------
    def stats_dict(self) -> Dict[str, Any]:
        pool = self.session.pool
        ps = pool.stats
        return {
            "service": {"uptime_s": time.time() - self.t0,
                        "queries": self.queries, "shed": self.shed,
                        "errors": self.errors},
            "scheduler": self.sched.stats.as_dict(),
            "admission": self.admission.snapshot(),
            "pool": {"resident_models": len(pool),
                     "resident_bytes": pool.resident_bytes,
                     "hits": ps.hits, "misses": ps.misses,
                     "evictions": ps.evictions},
            "session": {"recalibrations": self.session.recalibrations,
                        "cascade_fits": self.session.cascade_fits,
                        "model_cache": len(self.session.model_cache),
                        "cascade_cache": len(self.session.cascade_cache)},
        }
