"""Per-tenant SLOs and token-budget admission control.

The fair-share ``Scheduler`` (serving/scheduler.py) keeps admitted work
fair *between* tenants, but a long-running service also needs a gate in
FRONT of the scheduler: without one, a single tenant can enqueue
unbounded work and every other tenant's queue wait grows without limit.
``AdmissionController`` is that gate — it decides, per incoming query,
whether the tenant is within its SLO envelope:

  * **in-flight rows**: the number of result rows the tenant has
    admitted-but-unfinished across all its queries must stay under
    ``TenantSLO.max_inflight_rows`` (property-tested in
    tests/test_service.py under random interleavings);
  * **concurrent queries**: at most ``max_queries`` plans in flight;
  * **token budget**: a classic token bucket over *estimated prompt
    tokens* (the physical planner's cost estimate) — capacity
    ``token_budget``, refilled at ``refill_per_s``; a query whose
    estimate exceeds the current level is shed.

A rejected query gets a ``Shed`` verdict carrying the machine-readable
reason and a ``retry_after_s`` hint; the HTTP layer maps it to a 429
with a ``Retry-After`` header and the client (client.py) backs off and
retries within a bounded budget.  Shedding is *load* control, not an
error: the verdict is recorded in per-tenant admission stats surfaced
by ``/stats``.

Thread-safety: the controller is called from HTTP handler threads
(admission) and the service pump thread (release), so every mutation
holds one lock.  Time is injected (``clock=``) so tests can drive the
bucket deterministically.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional


@dataclass(frozen=True)
class TenantSLO:
    """Admission envelope for one tenant.

    ``share`` caps the tenant's in-flight rows *inside* the scheduler
    (forwarded to every submission) — distinct from
    ``max_inflight_rows``, which gates admission of whole queries.
    """
    max_inflight_rows: int = 64
    max_queries: int = 4
    token_budget: float = float("inf")   # bucket capacity (prompt tokens)
    refill_per_s: float = 0.0            # bucket refill rate
    retry_after_s: float = 0.5           # 429 Retry-After hint
    share: Optional[int] = None          # scheduler in-flight row cap


@dataclass(frozen=True)
class Shed:
    """A 429 verdict: why the query was refused and when to retry."""
    reason: str
    retry_after_s: float
    detail: str = ""


@dataclass
class _TenantState:
    inflight_rows: int = 0
    inflight_queries: int = 0
    tokens: float = 0.0                  # current bucket level
    last_refill: float = 0.0
    admitted: int = 0
    shed: int = 0


class AdmissionController:
    """SLO gate in front of the scheduler (see module docstring)."""

    def __init__(self, slos: Optional[Dict[str, TenantSLO]] = None, *,
                 default: Optional[TenantSLO] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.slos = dict(slos or {})
        self.default = default or TenantSLO()
        self._clock = clock
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantState] = {}

    def slo_for(self, tenant: str) -> TenantSLO:
        return self.slos.get(tenant, self.default)

    def _state(self, tenant: str) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            slo = self.slo_for(tenant)
            st = _TenantState(tokens=min(slo.token_budget, 1e18),
                              last_refill=self._clock())
            self._tenants[tenant] = st
        return st

    def _refill(self, tenant: str, st: _TenantState) -> None:
        slo = self.slo_for(tenant)
        now = self._clock()
        if slo.refill_per_s > 0:
            st.tokens = min(slo.token_budget,
                            st.tokens
                            + (now - st.last_refill) * slo.refill_per_s)
        st.last_refill = now

    def try_admit(self, tenant: str, rows: int,
                  tokens: float) -> Optional[Shed]:
        """Admit one query of ``rows`` estimated result rows and
        ``tokens`` estimated prompt tokens; None means admitted (the
        caller MUST later ``release`` the same rows), a ``Shed`` means
        refused with nothing charged."""
        slo = self.slo_for(tenant)
        with self._lock:
            st = self._state(tenant)
            self._refill(tenant, st)
            if st.inflight_queries + 1 > slo.max_queries:
                st.shed += 1
                return Shed("max_queries", slo.retry_after_s,
                            f"{st.inflight_queries} queries in flight "
                            f"(cap {slo.max_queries})")
            if st.inflight_rows + rows > slo.max_inflight_rows:
                st.shed += 1
                return Shed("max_inflight_rows", slo.retry_after_s,
                            f"{st.inflight_rows}+{rows} rows "
                            f"(cap {slo.max_inflight_rows})")
            if tokens > st.tokens:
                st.shed += 1
                # a refill-rate hint beats the static one when we can
                # compute how long the deficit actually takes to clear
                wait = (slo.retry_after_s if slo.refill_per_s <= 0
                        else max(slo.retry_after_s,
                                 (tokens - st.tokens) / slo.refill_per_s))
                return Shed("token_budget", wait,
                            f"need {tokens:.0f} tokens, have "
                            f"{st.tokens:.0f}")
            st.inflight_queries += 1
            st.inflight_rows += rows
            st.tokens -= tokens
            st.admitted += 1
            return None

    def release(self, tenant: str, rows: int) -> None:
        """Return an admitted query's row charge (on completion OR
        failure — the charge tracks liveness, not success)."""
        with self._lock:
            st = self._state(tenant)
            st.inflight_queries = max(0, st.inflight_queries - 1)
            st.inflight_rows = max(0, st.inflight_rows - rows)

    def inflight_rows(self, tenant: str) -> int:
        with self._lock:
            return self._state(tenant).inflight_rows

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Per-tenant admission counters for ``/stats``."""
        with self._lock:
            out = {}
            for name, st in sorted(self._tenants.items()):
                out[name] = {"admitted": st.admitted, "shed": st.shed,
                             "inflight_rows": st.inflight_rows,
                             "inflight_queries": st.inflight_queries,
                             "tokens": st.tokens}
            return out
