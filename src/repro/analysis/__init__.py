"""Static-analysis subsystem: unified diagnostics + the two analyzers.

Layer 1, the plan verifier, lives in ``repro.olap.analysis`` (it is
IR-coupled); layer 2, the jitted hot-path auditor, in
``repro.analysis.jit_audit``.  Both emit ``Diagnostic``s through this
package's framework; ``tools/analyze.py`` is the CLI entry point.
"""
from repro.analysis.diagnostics import (  # noqa: F401
    CODES,
    Baseline,
    Diagnostic,
    load_baseline,
    render_json,
    render_text,
    save_baseline,
    sort_diagnostics,
    summarize,
)
