"""Static auditor for the serving engine's jitted decode hot path.

The engine's throughput story depends on the tick loop staying
device-resident: one compile per bucket shape, no host round-trips
inside jitted functions, donated slot state actually donated and never
read after the call.  Nothing in the type system enforces any of that —
a PR can reintroduce a per-row host sync or a retrace-per-batch-shape
and every test still passes, just slower.  This module makes those
regressions *diagnosable before merge*:

``audit_engine(engine)`` drives a scripted workload through the
engine's real ``generate`` path with shape-recording proxies wrapped
around every jitted target (``_insert``, ``_decode``, and the
``_prefill`` / ``_prefill_from`` bucket ladders), then checks:

  JIT001  host callback primitives (``debug_callback``,
          ``pure_callback``, ``io_callback``) anywhere in a target's
          jaxpr — each one is a device->host round trip per tick
  JIT002  XLA reporting a donated buffer as unusable at compile time
          (a silent defensive copy; platform-unimplemented donation,
          e.g. CPU, is not flagged)
  JIT003  a call site of a donating jitted function whose donated
          argument is not rebound from the call result (AST check over
          the engine source — reading the old binding after the call
          is a use-after-free on accelerators)
  JIT004  weak-typed python scalars in a target's signature (dtype
          promotion surprises; pass ``jnp.int32(x)``-style arrays)
  JIT005  strong f32 scalar literals promoting bf16/f16 operands
  JIT006  retrace hazard: a target compiled more entries than the
          distinct input shape/dtype signatures observed — something
          besides shapes (a changing static, a weak-type flip) is
          forking the jit cache
  JIT007/8/9  per-decode-step FLOP / memory-traffic / collective
          budgets, extracted from the compiled step via
          ``launch/hlo_analysis.py``

The checks are static where possible (jaxprs, AST, compile artifacts);
the scripted workload exists only to collect real example signatures
and exercise the jit caches whose sizes JIT006 reads.
"""
from __future__ import annotations

import ast
import inspect
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.analysis.diagnostics import Diagnostic

CALLBACK_PRIMS = ("debug_callback", "pure_callback", "io_callback",
                  "callback", "host_callback_call", "outside_call")

LOW_PRECISION = ("bfloat16", "float16")


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------

def _subjaxprs(v):
    core = jax.core
    if isinstance(v, core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _subjaxprs(x)


def iter_eqns(jaxpr):
    """Every equation of a (closed) jaxpr, recursing into sub-jaxprs
    (pjit bodies, scan/while/cond branches, vmapped calls)."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def jaxpr_of(fn: Callable, args: Tuple, kwargs: Dict):
    """The function's closed jaxpr for the example signature, or None
    when tracing is impossible (e.g. the example was never recorded)."""
    try:
        return jax.make_jaxpr(fn)(*args, **kwargs)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# signature recording — the retrace oracle
# ---------------------------------------------------------------------------

def _leaf_sig(x) -> Tuple:
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return ("arr", tuple(x.shape), str(x.dtype))
    # python scalars: jit abstracts them by TYPE (weak scalar avals),
    # so the signature deliberately excludes the value — a cache that
    # still forks per call has a non-shape retrace cause
    return ("py", type(x).__name__)


def _abstractify(x):
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype)
    return x


def call_signature(args: Tuple, kwargs: Dict) -> Tuple:
    leaves, treedef = jax.tree.flatten((args, kwargs))
    return (str(treedef), tuple(_leaf_sig(leaf) for leaf in leaves))


class JitCallRecorder:
    """Transparent proxy around a jitted callable: records the distinct
    abstract signatures flowing through it (and one spec-level example
    per run) without perturbing the underlying jit cache."""

    def __init__(self, name: str, fn: Callable):
        self.name = name
        self.fn = fn
        self.calls = 0
        self.signatures: set = set()
        self.example: Optional[Tuple[Tuple, Dict]] = None

    def __call__(self, *args, **kwargs):
        # record BEFORE the call: donated operands are deleted after
        self.signatures.add(call_signature(args, kwargs))
        if self.example is None:
            self.example = (jax.tree.map(_abstractify, args),
                            jax.tree.map(_abstractify, kwargs))
        self.calls += 1
        return self.fn(*args, **kwargs)

    def cache_size(self) -> Optional[int]:
        try:
            return int(self.fn._cache_size())
        except Exception:
            return None


# ---------------------------------------------------------------------------
# individual checks
# ---------------------------------------------------------------------------

def audit_callbacks(name: str, closed) -> List[Diagnostic]:
    out = []
    for eqn in iter_eqns(closed):
        if eqn.primitive.name in CALLBACK_PRIMS:
            out.append(Diagnostic(
                "JIT001",
                f"{eqn.primitive.name} primitive inside the jitted hot "
                "path — a device->host round trip on every invocation",
                f"engine.{name}",
                hint="remove the callback (or debug print) from the "
                     "tick loop; stage debugging through returned "
                     "arrays instead"))
    return out


def audit_weak_args(name: str, closed) -> List[Diagnostic]:
    out = []
    for i, v in enumerate(closed.jaxpr.invars):
        aval = v.aval
        if not getattr(aval, "weak_type", False):
            continue
        dt = str(getattr(aval, "dtype", ""))
        sev = "warning" if dt.startswith("float") else "info"
        out.append(Diagnostic(
            "JIT004",
            f"argument {i} is a weak-typed python scalar ({dt}) — "
            "promotion rules differ from committed dtypes",
            f"engine.{name}", severity=sev,
            hint="pass jnp.asarray(x, dtype) / jnp.int32(x) so the "
                 "operand dtype is explicit"))
    return out


def audit_promotions(name: str, closed) -> List[Diagnostic]:
    """Strong f32 scalar literals silently widening bf16/f16 math."""
    out = []
    for eqn in iter_eqns(closed):
        lits = [v for v in eqn.invars if isinstance(v, jax.core.Literal)]
        arrs = [v for v in eqn.invars
                if not isinstance(v, jax.core.Literal)]
        if not (lits and arrs and eqn.outvars):
            continue
        strong_f32_lit = any(
            str(getattr(v.aval, "dtype", "")) == "float32"
            and not getattr(v.aval, "weak_type", False)
            and getattr(v.aval, "ndim", 1) == 0 for v in lits)
        low_arr = any(str(getattr(v.aval, "dtype", "")) in LOW_PRECISION
                      for v in arrs)
        promoted = any(str(getattr(v.aval, "dtype", "")) == "float32"
                       for v in eqn.outvars)
        if strong_f32_lit and low_arr and promoted:
            out.append(Diagnostic(
                "JIT005",
                f"{eqn.primitive.name}: strong f32 scalar constant "
                "promotes a low-precision operand to f32",
                f"engine.{name}", severity="warning",
                hint="use a weak python float or cast the constant to "
                     "the operand dtype"))
    return out


def audit_retrace(rec: JitCallRecorder) -> List[Diagnostic]:
    cache = rec.cache_size()
    if cache is None or not rec.calls:
        return []
    sigs = len(rec.signatures)
    if cache > sigs:
        return [Diagnostic(
            "JIT006",
            f"{cache} compiled entries for {sigs} distinct input "
            f"signature(s) over {rec.calls} call(s) — the jit cache is "
            "forking on something other than shapes/dtypes",
            f"engine.{rec.name}",
            hint="look for changing static argnums, python-scalar "
                 "dtype flips, or closures rebuilt per call")]
    return []


def audit_donation_compile(name: str, fn, example) -> List[Diagnostic]:
    """Compile the target and surface XLA's donated-buffer-unusable
    warnings (platform-unimplemented donation is not a finding)."""
    if example is None:
        return []
    args, kwargs = example
    out = []
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            fn.lower(*args, **kwargs).compile()
    except Exception:
        return []
    for w in caught:
        msg = str(w.message)
        if "donated" not in msg.lower():
            continue
        if "not implemented" in msg.lower():
            continue          # platform limitation, not a code defect
        out.append(Diagnostic(
            "JIT002", f"XLA: {msg.splitlines()[0][:160]}",
            f"engine.{name}",
            hint="donated operands must match an output's "
                 "shape/dtype for buffer reuse"))
    return out


def audit_donation_sites(source: str, donations: Dict[str, Tuple[int, ...]],
                         location: str) -> List[Diagnostic]:
    """AST check: every call of a donating jitted function must rebind
    its donated argument from the call's result in the same statement.
    Reading the old binding after the call is a use-after-free on
    accelerators (and a silent copy on others)."""
    out = []
    tree = ast.parse(source)
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        fname = None
        if isinstance(call.func, ast.Attribute):
            fname = call.func.attr
        elif isinstance(call.func, ast.Name):
            fname = call.func.id
        if fname not in donations:
            continue
        stmt: ast.AST = call
        while stmt in parents and not isinstance(stmt, ast.stmt):
            stmt = parents[stmt]
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                targets.extend(t.elts if isinstance(
                    t, (ast.Tuple, ast.List)) else [t])
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            targets = [stmt.target]
        # unparse, not ast.dump: the donated arg is a Load and the
        # assignment target a Store — textual identity is the question
        target_dumps = {ast.unparse(t) for t in targets}
        for pos in donations[fname]:
            if pos >= len(call.args):
                continue
            arg = call.args[pos]
            if not isinstance(arg, (ast.Name, ast.Attribute)):
                continue      # temporaries cannot be read again
            if ast.unparse(arg) not in target_dumps:
                out.append(Diagnostic(
                    "JIT003",
                    f"{fname}() donates argument {pos} "
                    f"({ast.unparse(arg)}) but the call site does not "
                    "rebind it from the result",
                    f"{location}:{call.lineno}",
                    hint="write `x = fn(x, ...)` (or unpack into it) "
                         "so the donated binding can never be read "
                         "after the transfer"))
    return out


# ---------------------------------------------------------------------------
# decode-step budgets (reuses launch/hlo_analysis roofline extraction)
# ---------------------------------------------------------------------------

def audit_decode_budget(engine, rec: JitCallRecorder, *,
                        flop_factor: float = 4.0,
                        bytes_factor: float = 16.0
                        ) -> Tuple[List[Diagnostic], Optional[Dict]]:
    """Compile the decode step and check its extracted FLOP/byte/
    collective terms against analytic budgets: ~2·N_active per token
    for compute, params + 2x slot state for traffic, zero collectives
    single-device.

    ``bytes_factor`` is deliberately loose: ``cost_analysis`` counts
    every buffer access (a clean tiny-model step measures ~9x its
    analytic HBM traffic on CPU), while the regression this catches —
    re-touching the whole cache per emitted token, or a prefill inside
    the step — multiplies traffic by O(seq_len)."""
    from repro.configs.base import ShapeSpec
    from repro.launch import hlo_analysis as HLO
    if rec.example is None:
        return [], None
    args, kwargs = rec.example
    try:
        compiled = rec.fn.lower(*args, **kwargs).compile()
        roof = HLO.analyze(compiled, chips=1)
    except Exception:
        return [], None
    spec = ShapeSpec("audit_decode", seq_len=engine.max_len,
                     global_batch=engine.slots, kind="decode")
    expected_flops = HLO.model_flops(engine.cfg, spec)
    param_bytes = sum(x.nbytes for x in jax.tree.leaves(engine.params))
    state_bytes = sum(x.nbytes
                     for x in jax.tree.leaves(engine._slot_state or {}))
    expected_bytes = param_bytes + 2 * state_bytes
    detail = {"flops": roof.flops, "expected_flops": expected_flops,
              "bytes": roof.bytes_accessed,
              "expected_bytes": expected_bytes,
              "coll_bytes": roof.coll_bytes,
              "coll_detail": roof.coll_detail}
    diags = []
    if expected_flops and roof.flops > flop_factor * expected_flops:
        diags.append(Diagnostic(
            "JIT007",
            f"decode step costs {roof.flops:.3g} FLOPs vs "
            f"~{expected_flops:.3g} for 2·N_active·slots "
            f"(>{flop_factor:g}x budget)", "engine._decode",
            severity="warning",
            hint="look for recomputation over the whole cache or an "
                 "accidental prefill inside the step"))
    if expected_bytes and \
            roof.bytes_accessed > bytes_factor * expected_bytes:
        diags.append(Diagnostic(
            "JIT008",
            f"decode step moves {roof.bytes_accessed:.3g} bytes vs "
            f"~{expected_bytes:.3g} for params + 2x slot state "
            f"(>{bytes_factor:g}x budget)", "engine._decode",
            severity="warning",
            hint="the step should read params once and touch slot "
                 "state, nothing larger"))
    if roof.coll_bytes > 0 and engine.mesh is None:
        diags.append(Diagnostic(
            "JIT009",
            f"decode step contains collectives "
            f"({roof.coll_detail}) on a single-device engine",
            "engine._decode"))
    return diags, detail


# ---------------------------------------------------------------------------
# the engine audit
# ---------------------------------------------------------------------------

@dataclass
class AuditReport:
    diagnostics: List[Diagnostic]
    cache_stats: Dict[str, Dict[str, int]] = field(default_factory=dict)
    budget: Optional[Dict] = None

    def to_dict(self) -> Dict:
        return {"diagnostics": [d.to_dict() for d in self.diagnostics],
                "cache_stats": self.cache_stats, "budget": self.budget}


def default_workload(engine) -> List[str]:
    """Deterministic prompts exercising every bucket of the engine's
    ladder plus partial-batch admission (so retrace detection sees the
    admission widths real traffic produces)."""
    prompts = [f"row {i} value v{i}" for i in range(2 * engine.slots + 1)]
    if len(engine.buckets) > 1:
        pad = "x" * (engine.buckets[0] + 2)
        prompts += [f"{pad} long row {i}" for i in range(2)]
    return prompts


def _install(engine) -> Dict[str, JitCallRecorder]:
    recs = {"_insert": JitCallRecorder("_insert", engine._insert),
            "_decode": JitCallRecorder("_decode", engine._decode)}
    engine._insert = recs["_insert"]
    engine._decode = recs["_decode"]
    if getattr(engine, "_seed", None) is not None:
        recs["_seed"] = JitCallRecorder("_seed", engine._seed)
        engine._seed = recs["_seed"]
    for b, fn in list(engine._prefill.items()):
        r = JitCallRecorder(f"_prefill[{b}]", fn)
        recs[r.name] = r
        engine._prefill[b] = r
    for b, fn in list(engine._prefill_from.items()):
        r = JitCallRecorder(f"_prefill_from[{b}]", fn)
        recs[r.name] = r
        engine._prefill_from[b] = r
    return recs


def _restore(engine, recs: Dict[str, JitCallRecorder]) -> None:
    engine._insert = recs["_insert"].fn
    engine._decode = recs["_decode"].fn
    if "_seed" in recs:
        engine._seed = recs["_seed"].fn
    for b in list(engine._prefill):
        engine._prefill[b] = recs[f"_prefill[{b}]"].fn
    for b in list(engine._prefill_from):
        engine._prefill_from[b] = recs[f"_prefill_from[{b}]"].fn


# donated positions of the engine's jitted targets (matches the
# donate_argnums in Engine.__init__); the AST check audits every call
# site of these names in the engine source
ENGINE_DONATIONS: Dict[str, Tuple[int, ...]] = {
    "_insert": (0,),     # slot_state
    "_decode": (1,),     # slot_state
    "_seed": (0,),       # slot_state (paged prefix-block seeding)
}


def audit_engine(engine, prompts: Optional[List[str]] = None, *,
                 max_new: int = 4, flop_factor: float = 4.0,
                 bytes_factor: float = 16.0,
                 source: Optional[str] = None) -> AuditReport:
    """Run the full hot-path audit against a live engine.

    Drives ``prompts`` (default: a bucket-covering scripted workload)
    through ``generate`` — plus a prefix-seeded pass when the engine
    has a prefix cache, so the ``_prefill_from`` ladder is exercised —
    then applies every static check to the recorded targets.
    ``source`` overrides the audited call-site source text (tests use
    this to prove JIT003 fires)."""
    if prompts is None:
        prompts = default_workload(engine)
    recs = _install(engine)
    try:
        engine.generate(list(prompts), max_new=max_new)
        if engine.prefix_cache is not None:
            tpl = "audit template: "
            engine.generate([f"{tpl}row {i}" for i in range(engine.slots)],
                            max_new=max_new, prefix=tpl)
    finally:
        _restore(engine, recs)

    diags: List[Diagnostic] = []
    cache_stats: Dict[str, Dict[str, int]] = {}
    for name, rec in recs.items():
        if not rec.calls:
            continue
        cache_stats[name] = {"calls": rec.calls,
                             "signatures": len(rec.signatures),
                             "compiles": rec.cache_size() or 0}
        diags.extend(audit_retrace(rec))
        closed = (jaxpr_of(rec.fn, *rec.example)
                  if rec.example is not None else None)
        if closed is not None:
            diags.extend(audit_callbacks(name, closed))
            diags.extend(audit_weak_args(name, closed))
            diags.extend(audit_promotions(name, closed))
        diags.extend(audit_donation_compile(name, rec.fn, rec.example))

    if source is None:
        from repro.serving import engine as engine_module
        source = inspect.getsource(engine_module)
    diags.extend(audit_donation_sites(source, ENGINE_DONATIONS,
                                      "serving/engine.py"))
    budget_diags, budget = audit_decode_budget(
        engine, recs["_decode"], flop_factor=flop_factor,
        bytes_factor=bytes_factor)
    diags.extend(budget_diags)
    return AuditReport(diags, cache_stats, budget)
