"""Unified diagnostics for the static-analysis subsystem.

Every finding from the plan verifier (olap/analysis.py) and the jitted
hot-path auditor (analysis/jit_audit.py) is a ``Diagnostic``: a stable
code (``PLAN012``, ``JIT001``, ...), a severity, a location string, a
human message, and a fix hint.  Codes are API — tests, baselines, and
suppression files key on them, so a code is never renamed or reused
(retired codes stay in ``CODES`` with a tombstone note).

CI consumes diagnostics through a **baseline**: ``tools/analyze.py``
fails only on findings that are not in ``tools/analysis_baseline.json``
(matched by fingerprint) and whose code is not in the baseline's
``suppress_codes`` list.  That makes the gate monotone — existing debt
is visible but does not block, while every *new* finding does.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional

SEVERITIES = ("error", "warning", "info")

# The full code table (rendered in src/repro/analysis/README.md).  A
# code's meaning is stable; only the message text may evolve.
CODES: Dict[str, str] = {
    # --- PLAN0xx: generic plan obligations (any rewrite) ---
    "PLAN001": "rewrite changed the plan's output schema",
    "PLAN002": "rewrite changed the scan (input table) of the plan",
    "PLAN003": "rewritten plan is structurally malformed",
    "PLAN004": "node reads a column unavailable in its input schema",
    # --- PLAN01x: pushdown obligations ---
    "PLAN010": "rewrite does not match the claimed rule's shape",
    "PLAN011": "filter pushed across a join (row identity changes)",
    "PLAN012": "filter pushed below the op producing a column it reads",
    "PLAN013": "opaque filter (no declared read set) pushed below a "
               "column-adding op",
    # --- PLAN02x: dedup obligations ---
    "PLAN020": "dedup rewrite changed more than the annotation",
    "PLAN021": "dedup on a derived/rewritten column (scatter invariant "
               "unprovable)",
    "PLAN022": "dedup annotation without duplicate input values",
    # --- PLAN03x: fusion obligations ---
    "PLAN030": "fused node is structurally invalid",
    "PLAN031": "fusion across differing templates (prompt/col/max_new/"
               "kind mismatch)",
    "PLAN032": "fused output columns disagree with the constituents'",
    "PLAN033": "fusion across a data dependency (an op reads a fused "
               "output)",
    "PLAN099": "unknown rewrite rule name",
    # --- JIT00x: jitted hot-path audit ---
    "JIT001": "host callback primitive inside a jitted hot-path function",
    "JIT002": "donated buffer was not usable (silent copy at dispatch)",
    "JIT003": "donated argument not rebound from the call result "
              "(read-after-donate hazard)",
    "JIT004": "weak-typed python scalar passed to a jitted function "
              "(promotion hazard)",
    "JIT005": "strong f32 scalar promotes a lower-precision operand to f32",
    "JIT006": "retrace hazard: more compiles than distinct input "
              "signatures",
    "JIT007": "decode-step FLOP count exceeds its budget",
    "JIT008": "decode-step memory traffic exceeds its budget",
    "JIT009": "collective op in a single-device decode step",
}


@dataclass(frozen=True)
class Diagnostic:
    """One static-analysis finding.

    ``location`` is a stable anchor, not a byte offset: a dotted rule
    site (``optimizer.pushdown``), a jit target (``engine._decode``),
    or a ``path:line`` when the finding is source-anchored.  The
    fingerprint hashes (code, location, message) so a finding stays
    recognized across unrelated edits.
    """
    code: str
    message: str
    location: str
    severity: str = "error"
    hint: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in "
                             f"{SEVERITIES}")
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}; "
                             "register it in diagnostics.CODES")

    def fingerprint(self) -> str:
        h = hashlib.sha256(
            f"{self.code}|{self.location}|{self.message}".encode())
        return h.hexdigest()[:16]

    def to_dict(self) -> Dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d


def _sev_rank(d: Diagnostic) -> int:
    return SEVERITIES.index(d.severity)


def sort_diagnostics(diags: Iterable[Diagnostic]) -> List[Diagnostic]:
    """Stable order for rendering and baselines: severity, code,
    location, message."""
    return sorted(diags, key=lambda d: (_sev_rank(d), d.code,
                                        d.location, d.message))


def render_text(diags: Iterable[Diagnostic]) -> str:
    diags = sort_diagnostics(diags)
    if not diags:
        return "no diagnostics"
    lines = []
    for d in diags:
        lines.append(f"{d.severity.upper():7s} {d.code} @ {d.location}: "
                     f"{d.message}")
        if d.hint:
            lines.append(f"        hint: {d.hint}")
    counts = summarize(diags)
    lines.append("-- " + ", ".join(f"{v} {k}(s)"
                                   for k, v in counts.items() if v))
    return "\n".join(lines)


def render_json(diags: Iterable[Diagnostic], *,
                extra: Optional[Dict] = None) -> str:
    diags = sort_diagnostics(diags)
    doc = {"diagnostics": [d.to_dict() for d in diags],
           "summary": summarize(diags)}
    if extra:
        doc.update(extra)
    return json.dumps(doc, indent=2, sort_keys=True)


def summarize(diags: Iterable[Diagnostic]) -> Dict[str, int]:
    counts = {s: 0 for s in SEVERITIES}
    for d in diags:
        counts[d.severity] += 1
    return counts


# ---------------------------------------------------------------------------
# baseline / suppression
# ---------------------------------------------------------------------------

@dataclass
class Baseline:
    """Known findings + code-level suppressions.

    ``fingerprints`` maps fingerprint -> the finding's dict (kept for
    human diffing of the baseline file); ``suppress_codes`` mutes a
    whole code (used for checks that are advisory on some platforms —
    each entry should carry a justification comment in the file via
    ``suppress_reasons``).
    """
    fingerprints: Dict[str, Dict] = field(default_factory=dict)
    suppress_codes: List[str] = field(default_factory=list)
    suppress_reasons: Dict[str, str] = field(default_factory=dict)

    def is_known(self, d: Diagnostic) -> bool:
        return (d.code in self.suppress_codes
                or d.fingerprint() in self.fingerprints)

    def new_findings(self, diags: Iterable[Diagnostic]) -> List[Diagnostic]:
        """Findings that should gate: not suppressed, not in the
        baseline, and not informational."""
        return [d for d in sort_diagnostics(diags)
                if d.severity != "info" and not self.is_known(d)]


def load_baseline(path: str) -> Baseline:
    with open(path) as f:
        doc = json.load(f)
    return Baseline(fingerprints=doc.get("fingerprints", {}),
                    suppress_codes=list(doc.get("suppress_codes", [])),
                    suppress_reasons=dict(doc.get("suppress_reasons", {})))


def save_baseline(path: str, diags: Iterable[Diagnostic],
                  *, suppress_codes: Optional[List[str]] = None,
                  suppress_reasons: Optional[Dict[str, str]] = None) -> None:
    doc = {
        "suppress_codes": sorted(suppress_codes or []),
        "suppress_reasons": suppress_reasons or {},
        "fingerprints": {d.fingerprint(): d.to_dict()
                         for d in sort_diagnostics(diags)
                         if d.severity != "info"},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
