"""Roofline-term extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` on an SPMD-partitioned module reports
**per-device** FLOPs/bytes (calibrated: a [2048x2048]^2 matmul sharded
over 16 devices reports 1/16 of 2N^3), and the optimized HLO text is the
per-device program, so its collective operand shapes are per-device
shard payloads.  The roofline terms therefore divide by per-chip peaks
directly:

    compute term    = device_FLOPs / peak FLOP/s          (197e12 bf16)
    memory term     = device_bytes / HBM bandwidth        (819e9 B/s)
    collective term = device_collective_bytes / ICI link  (50e9 B/s)

Equivalently: global_FLOPs / (chips x peak) when compute shards
perfectly — deviations between the two ARE the parallelization loss, and
``useful_compute_frac`` = MODEL_FLOPS / (device_FLOPs x chips) makes the
redundancy (remat, replication) visible.  Collective bytes are parsed
from HLO (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute result shapes).  Hardware constants: TPU v5e.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# --- TPU v5e constants (per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

# result shape may be a tuple, including one-level-nested tuples as
# emitted for async pairs: `(bf16[8], (bf16[8], u32[]))` — the inner
# alternative admits one nesting depth
_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"((?:\((?:[^()]|\([^()]*\))*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """bytes of 'bf16[128,1024]{1,0}' or tuple '(f32[2,4], u32[])'.

    Sub-byte dtypes (s4/u4) are packed two-per-byte but a shape's
    buffer is still whole bytes — ceil per array, so 'u4[3]' is 2
    bytes, not 1.5."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += math.ceil(n * _DTYPE_BYTES[dt])
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum of result-shape bytes per collective kind.

    Async collectives appear as a '-start'/'-done' pair whose result
    shapes both carry the payload; only the '-done' (or a synchronous
    op with no suffix) is counted, so a pair contributes once."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-start":
            continue
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_str)
    return out


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    coll_detail: Dict[str, float] = field(default_factory=dict)
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops          # flops are per-device

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / self.hbm_bw     # bytes are per-device

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.ici_bw         # HLO is per-device

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bound": self.bound,
            "coll_detail": self.coll_detail,
        }


def analyze(compiled, chips: int, hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    detail = collective_bytes(txt)
    return Roofline(flops=flops, bytes_accessed=nbytes,
                    coll_bytes=sum(detail.values()), chips=chips,
                    coll_detail=detail)


def memory_per_device(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def model_flops(cfg, shape_spec) -> float:
    """MODEL_FLOPS = 6 N D (dense train) / 2 N D (inference fwd), with
    N = active params; D = processed tokens."""
    n = cfg.active_param_count()
    if shape_spec.kind == "train":
        per_tok = 6 * n
        toks = shape_spec.global_batch * shape_spec.seq_len
    elif shape_spec.kind == "prefill":
        per_tok = 2 * n
        toks = shape_spec.global_batch * shape_spec.seq_len
    else:  # decode: one token per row
        per_tok = 2 * n
        toks = shape_spec.global_batch
    return float(per_tok) * toks
