"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512
chips as (pod=2, data=16, model=16) — the "pod" axis carries pure data
parallelism (+ compressed gradient all-reduce, see
training/grad_compress.py) because inter-pod links are an order of
magnitude slower than in-pod ICI.

A FUNCTION, not a module constant: importing this module must never
touch jax device state (the dry-run needs to set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over host devices (tests)."""
    return jax.make_mesh(shape, axes)
