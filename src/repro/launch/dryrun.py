import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for the
production meshes (16x16 single-pod, 2x16x16 multi-pod) every assigned
architecture x input-shape cell must ``.lower().compile()`` under its
sharding rules, fit per-device memory (``memory_analysis``), and yield
the roofline terms (``cost_analysis`` + HLO collective parsing).

Usage:
  python -m repro.launch.dryrun --cell mistral-nemo-12b:train_4k:single
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
                                [--out results/dryrun.json]

--all orchestrates one subprocess per cell (isolates compile memory,
caches incrementally, survives individual failures).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, input_specs, shape_supported
from repro.configs import registry
from repro.distributed import sharding as SH
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step

BIG_FOR_ADAFACTOR = 50e9     # params; arctic trains with adafactor + fsdp


def _largest_divisor(n: int, cap: int) -> int:
    best = 1
    for d in range(1, cap + 1):
        if n % d == 0:
            best = d
    return best


def _quantize_specs(params_sds, cfg):
    """Map compressible weight leaves to int8 QTensor ShapeDtypeStructs
    (shape-level twin of pipeline._compress_weights — for lowering the
    IOLM-compressed variant of a cell without real weights)."""
    from repro.core.compressed import QTensor
    from repro.core.pipeline import _is_target
    from repro.core.calibrate import _path_str
    from repro.core.quantize import choose_group

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_sds)
    out = []
    for path, leaf in flat:
        p = _path_str(path)
        if not _is_target(p, leaf):
            out.append(leaf)
            continue
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        lead = leaf.shape[:-2]
        g = choose_group(d_in, 128)
        out.append(QTensor(
            jax.ShapeDtypeStruct(lead + (d_in, d_out), jnp.int8),
            jax.ShapeDtypeStruct(lead + (d_in // g, d_out), jnp.float32),
            8, g, (d_in, d_out)))
    return jax.tree_util.tree_unflatten(treedef, out)


def build_cell(arch: str, shape_name: str, mesh, *, unroll: bool = True):
    """Returns (lowered_fn_args, jitted) ready to .lower()."""
    cfg = registry.get_config(arch).replace(scan_unroll=unroll)
    compress = os.environ.get("DRYRUN_COMPRESS", "")
    if compress:
        kv = dict(item.split("=") for item in compress.split(",") if item)
        if "experts_keep" in kv and cfg.family == "moe":
            cfg = cfg.replace(n_experts=int(kv["experts_keep"]))
        if "kv_keep" in kv:
            K2 = int(kv["kv_keep"])
            G = cfg.n_heads // cfg.n_kv_heads
            cfg = cfg.replace(n_kv_heads=K2, n_heads=K2 * G,
                              head_dim=cfg.resolved_head_dim)
    spec = SHAPES[shape_name]
    chips = mesh.devices.size
    # Megatron-style sequence sharding of inter-layer activations (train/
    # prefill): remat-saved [B_loc, S, d] residuals shard S over "model"
    if spec.kind in ("train", "prefill"):
        SH.set_activation_sharding(NamedSharding(
            mesh, P(SH.dp_axes(mesh), "model", None)))
    else:
        SH.set_activation_sharding(None)
    batch_sds = input_specs(cfg, shape_name)
    batch_sh = SH.batch_shardings(cfg, batch_sds, mesh)
    params_sds = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    if compress and "wbits" in (compress or ""):
        params_sds = _quantize_specs(params_sds, cfg)
    nparams = cfg.param_count()
    # FSDP (ZeRO-3-style data-axis weight/optimizer sharding) for train
    # cells whose replicated remainder would not fit HBM otherwise
    fsdp = spec.kind == "train" and nparams > 5e9
    param_sh = SH.param_shardings(cfg, params_sds, mesh, fsdp=fsdp)
    repl = NamedSharding(mesh, P())

    if spec.kind == "train":
        use_adafactor = nparams > BIG_FOR_ADAFACTOR
        opt = OPT.adafactor() if use_adafactor else OPT.adamw()
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_sh = SH.opt_state_shardings(
            param_sh, mesh, "adafactor" if use_adafactor else "adamw")
        # streamed vocab projection: chunk must divide the loss length
        s_loss = spec.seq_len - (cfg.n_img_tokens
                                 if cfg.family == "vlm" else 0)
        xent_chunk = 0
        if cfg.vocab_size >= 32000 and cfg.family in ("dense", "moe", "vlm"):
            xent_chunk = _largest_divisor(s_loss, 1024)
        # 8 microbatches: activation live set drops 8x (batch 256 -> 32).
        # encdec (whisper) uses 16: its non-causal 4k x 4k attention has
        # no flash backward (custom-vjp is future work), so the [B,H,S,S]
        # logits must shrink via the batch axis instead.
        # The unrolled ANALYSIS build runs microbatches=1 instead: same
        # total flops/bytes per step, but unrolling M microbatches x L
        # layers would explode compile time.
        micro = 16 if cfg.family == "encdec" else 8
        step_fn = make_train_step(cfg, opt, xent_chunk=xent_chunk,
                                  microbatches=1 if unroll else micro)
        jitted = jax.jit(step_fn,
                         in_shardings=(param_sh, opt_sh, batch_sh, repl),
                         donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        return jitted, args

    if spec.kind == "prefill":
        step_fn = api.build_prefill_step(cfg, spec)
        jitted = jax.jit(step_fn, in_shardings=(param_sh, batch_sh))
        return jitted, (params_sds, batch_sds)

    # decode
    B = spec.global_batch
    max_len = spec.seq_len
    cache_sds = jax.eval_shape(
        lambda: api.init_cache(cfg, B, max_len, compact_local=True))
    cache_sh = SH.cache_shardings(cfg, cache_sds, mesh)
    tok_sds = batch_sds["tokens"]
    tok_sh = batch_sh["tokens"]
    pos_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_sh = NamedSharding(mesh, P(tok_sh.spec[0]))
    step_fn = api.build_serve_step(cfg, spec)
    jitted = jax.jit(step_fn,
                     in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                     donate_argnums=(1,))
    return jitted, (params_sds, cache_sds, tok_sds, pos_sds)


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    SH.set_opt_from_env(os.environ.get("DRYRUN_OPT", ""))
    cfg = registry.get_config(arch)
    ok, reason = shape_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(mesh.devices.size)
    t0 = time.time()
    # 1) runtime-faithful compile (compact lax.scan): memory analysis is
    #    taken from THIS executable — it is what would run on the pod.
    with mesh:
        jitted, args = build_cell(arch, shape_name, mesh, unroll=False)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_scan = time.time() - t0 - t_lower
        mem = HA.memory_per_device(compiled)
        roof_scan = HA.analyze(compiled, chips)
        del compiled, lowered
    # 2) analysis compile (scan unrolled): XLA cost_analysis counts a
    #    while-body once, so flops/bytes/collectives of the scan build
    #    undercount by the trip count; the unrolled build gives the true
    #    per-step totals for the roofline terms.
    unrolled = False
    roof = roof_scan
    t_unroll = 0.0
    if os.environ.get("DRYRUN_NO_UNROLL", "") != "1":
        try:
            t1 = time.time()
            with mesh:
                jitted_u, args_u = build_cell(arch, shape_name, mesh,
                                              unroll=True)
                compiled_u = jitted_u.lower(*args_u).compile()
                roof = HA.analyze(compiled_u, chips)
                del compiled_u, jitted_u
            unrolled = True
            t_unroll = time.time() - t1
        except Exception:
            roof = roof_scan
    spec = SHAPES[shape_name]
    mf = HA.model_flops(cfg, spec)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips, "unrolled": unrolled,
        "lower_s": round(t_lower, 1), "compile_s": round(t_scan, 1),
        "compile_unrolled_s": round(t_unroll, 1),
        "memory": mem,
        "bytes_per_device": mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0),
        "roofline": roof.to_dict(),
        "roofline_scan": roof_scan.to_dict(),
        "model_flops": mf,
        # MODEL_FLOPS / (device_flops x chips): <1 means remat/replication
        # overhead, >1 means the mesh is bigger than the model needs
        "useful_compute_frac": mf / (roof.flops * chips)
        if roof.flops else 0.0,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:mesh  (mesh = single|multi)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.cell:
        arch, shape, mesh_kind = args.cell.split(":")
        try:
            res = run_cell(arch, shape, mesh_kind)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        print("DRYRUN_RESULT " + json.dumps(res))
        sys.exit(0 if res["status"] in ("ok", "skipped") else 1)

    if not args.all:
        ap.print_help()
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    archs = [args.arch] if args.arch else list(registry.ARCH_IDS)
    # single-pod first (it feeds the roofline table), then multi-pod
    cells = [(a, s, m) for m in meshes for a in archs for s in SHAPES]
    for arch, shape, mesh_kind in cells:
        key = f"{arch}:{shape}:{mesh_kind}"
        if key in results and results[key].get("status") in ("ok", "skipped"):
            continue
        print(f"[dryrun] {key} ...", flush=True)
        t0 = time.time()
        env = {**os.environ, "PYTHONPATH": "src"}
        if mesh_kind == "multi":
            # the roofline table is single-pod; multi-pod cells only need
            # the runtime-faithful compile (proves the pod axis shards)
            env["DRYRUN_NO_UNROLL"] = "1"
        try:
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--cell", key],
                capture_output=True, text=True, timeout=args.timeout,
                env=env)
        except subprocess.TimeoutExpired as te:
            results[key] = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                            "status": "timeout",
                            "wall_s": round(time.time() - t0, 1)}
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            print(f"[dryrun] {key}: timeout", flush=True)
            continue
        res = None
        for line in proc.stdout.splitlines():
            if line.startswith("DRYRUN_RESULT "):
                res = json.loads(line[len("DRYRUN_RESULT "):])
        if res is None:
            res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "crash",
                   "error": (proc.stderr or proc.stdout)[-2000:]}
        res["wall_s"] = round(time.time() - t0, 1)
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] {key}: {res['status']} ({res['wall_s']}s)",
              flush=True)
    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed / {len(results)}")


if __name__ == "__main__":
    main()
