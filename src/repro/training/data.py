"""Data pipeline: tokenizer + synthetic OLAP-text corpora + workloads.

No internet in this container, so the paper's datasets (Amazon Reviews,
GitHub Typo Corpus) are replaced by synthetic generators with the same
*shape*: free-text review rows for summarization, corrupted records for
data correction, and entity-pair tables for fuzzy joins.  The generators
are deterministic given a seed, so distributed workers can re-derive any
batch from (seed, step) — that is the straggler/restart story: a
restarted worker replays identical batches with no data server.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import random
import string
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# tokenizer (byte-level with a few special tokens; vocab-padded per model)
# ---------------------------------------------------------------------------

class ByteTokenizer:
    """Byte-level tokenizer: ids 0..3 special, 4..259 bytes."""
    PAD, BOS, EOS, SEP = 0, 1, 2, 3
    OFFSET = 4

    def __init__(self, vocab_size: int = 260):
        assert vocab_size >= 260
        self.vocab_size = vocab_size

    def encode(self, text: str, *, bos: bool = False,
               eos: bool = False) -> List[int]:
        ids = [b + self.OFFSET for b in text.encode("utf-8")]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        bs = bytes(i - self.OFFSET for i in ids
                   if i >= self.OFFSET and i - self.OFFSET < 256)
        return bs.decode("utf-8", errors="replace")

    def pad_batch(self, rows: List[List[int]], *, seq_len: int,
                  align: str = "right") -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens [B, S], lengths [B]); rows are clipped/padded."""
        B = len(rows)
        out = np.full((B, seq_len), self.PAD, np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(rows):
            r = r[:seq_len]
            lens[i] = len(r)
            if align == "right":
                out[i, :len(r)] = r
            else:
                out[i, seq_len - len(r):] = r
        return out, lens


# ---------------------------------------------------------------------------
# synthetic text building blocks
# ---------------------------------------------------------------------------

_PRODUCTS = ["headphones", "keyboard", "monitor", "webcam", "microphone",
             "laptop stand", "usb hub", "desk lamp", "office chair",
             "mouse pad", "router", "speaker", "charger", "tablet",
             "smartwatch", "printer"]
_ADJ_POS = ["great", "excellent", "fantastic", "solid", "amazing",
            "reliable", "superb", "crisp"]
_ADJ_NEG = ["terrible", "awful", "flimsy", "noisy", "laggy",
            "disappointing", "cheap", "broken"]
_FILLER = ["I bought this last month.", "Shipping was fast.",
           "The packaging was fine.", "My friend recommended it.",
           "I use it every day.", "Setup took five minutes.",
           "Color matches the photos.", "Works with my setup."]
_CATEGORIES = ["python", "javascript", "golang", "rust", "java", "ruby",
               "swift", "kotlin", "csharp", "scala"]
_COMPANIES = ["Acme Corp", "Globex", "Initech", "Umbrella", "Stark Labs",
              "Wayne Tech", "Hooli", "Vandelay", "Wonka Industries",
              "Tyrell Corp"]
_SUFFIXES = ["Inc.", "LLC", "Co.", "Corporation", "Group", "Holdings", ""]


@dataclass
class Row:
    text: str          # model input (the "column value")
    target: str        # ground-truth output for the LLM operator
    meta: Dict = dataclasses.field(default_factory=dict)


def _rng(seed: int, *salt) -> random.Random:
    h = hashlib.sha256(repr((seed,) + salt).encode()).digest()
    return random.Random(int.from_bytes(h[:8], "big"))


# --- workload 1: summarization (reviews -> "<sentiment> <product>") -------

def gen_review(seed: int, i: int) -> Row:
    r = _rng(seed, "review", i)
    prod = r.choice(_PRODUCTS)
    pos = r.random() < 0.5
    adj = r.choice(_ADJ_POS if pos else _ADJ_NEG)
    n_fill = r.randint(2, 5)
    fillers = r.sample(_FILLER, n_fill)
    sent = f"The {prod} is {adj}."
    pieces = fillers[:n_fill // 2] + [sent] + fillers[n_fill // 2:]
    return Row(text=" ".join(pieces),
               target=f"{'positive' if pos else 'negative'} {prod}",
               meta={"sentiment": pos, "product": prod})


# --- workload 2: data correction (typo'd category -> canonical) -----------

def _typo(word: str, r: random.Random) -> str:
    if len(word) < 3:
        return word
    kind = r.randrange(4)
    i = r.randrange(1, len(word) - 1)
    if kind == 0:     # swap
        return word[:i] + word[i + 1] + word[i] + word[i + 2:]
    if kind == 1:     # drop
        return word[:i] + word[i + 1:]
    if kind == 2:     # double
        return word[:i] + word[i] + word[i:]
    return word[:i] + r.choice(string.ascii_lowercase) + word[i + 1:]


def gen_typo(seed: int, i: int) -> Row:
    r = _rng(seed, "typo", i)
    cat = r.choice(_CATEGORIES)
    bad = _typo(cat, r)
    # ~20% duplicated rows: the result-cache workload signal
    if r.random() < 0.2:
        r2 = _rng(seed, "typo", max(i - r.randint(1, 8), 0))
        cat = r2.choice(_CATEGORIES)
        bad = _typo(cat, r2)
    return Row(text=bad, target=cat, meta={"clean": cat})


# --- workload 3: fuzzy join (entity pair -> same/different) ----------------

def _variant(name: str, r: random.Random) -> str:
    v = name
    if r.random() < 0.5:
        v = v.replace(" ", ", ") if r.random() < 0.3 else v
    suf = r.choice(_SUFFIXES)
    if suf and r.random() < 0.7:
        v = f"{v} {suf}"
    if r.random() < 0.3:
        v = v.lower()
    if r.random() < 0.2:
        v = v.replace("o", "0", 1)
    return v


def gen_entity_pair(seed: int, i: int) -> Row:
    r = _rng(seed, "join", i)
    a = r.choice(_COMPANIES)
    same = r.random() < 0.5
    b = a if same else r.choice([c for c in _COMPANIES if c != a])
    return Row(text=f"{_variant(a, r)} | {_variant(b, r)}",
               target="same" if same else "different",
               meta={"same": same})


WORKLOADS = {
    "summarize": gen_review,
    "correct": gen_typo,
    "join": gen_entity_pair,
}

PROMPTS = {
    "summarize": "summarize: ",
    "correct": "fix: ",
    "join": "match: ",
}


def workload_rows(name: str, n: int, *, seed: int = 0) -> List[Row]:
    gen = WORKLOADS[name]
    return [gen(seed, i) for i in range(n)]


# ---------------------------------------------------------------------------
# LM training batches (mixture of all three tasks, prompt-formatted)
# ---------------------------------------------------------------------------

def format_example(task: str, row: Row, tok: ByteTokenizer) -> List[int]:
    """``<bos> prompt text <sep> target <eos>`` — loss over the whole row."""
    ids = tok.encode(PROMPTS[task] + row.text, bos=True)
    ids += [tok.SEP] + tok.encode(row.target, eos=True)
    return ids


def train_batch(step: int, *, batch: int, seq_len: int,
                tok: ByteTokenizer, seed: int = 0,
                tasks: Sequence[str] = ("summarize", "correct", "join")):
    """Deterministic (seed, step) -> batch; restart-safe by construction."""
    rows, labels = [], []
    for b in range(batch):
        r = _rng(seed, "mix", step, b)
        task = tasks[r.randrange(len(tasks))]
        row = WORKLOADS[task](seed * 97 + 13, step * batch + b)
        ids = format_example(task, row, tok)
        rows.append(ids)
    toks, lens = tok.pad_batch(rows, seq_len=seq_len + 1)
    tokens = toks[:, :-1]
    labels = toks[:, 1:].copy()
    # no loss on padding
    labels[labels == tok.PAD] = 0
    weights = (toks[:, 1:] != tok.PAD).astype(np.float32)
    return {"tokens": tokens, "labels": labels, "weights": weights}


def eval_rows(task: str, n: int, *, seed: int = 10_000) -> List[Row]:
    """Held-out rows (disjoint salt from training)."""
    gen = WORKLOADS[task]
    return [gen(seed, i) for i in range(n)]
