"""Atomic, elastic checkpointing.

Fault-tolerance contract:
  - **atomic**: state is written to ``<dir>/tmp.<nonce>`` and renamed to
    ``<dir>/step_<n>`` only after every file и the manifest (with content
    hashes) are fsync'd — a preempted writer never corrupts the latest
    checkpoint.
  - **elastic**: arrays are stored device-agnostic (full numpy); load
    re-shards onto whatever mesh/device count the restarted job has.
  - **self-validating**: the manifest stores sha256 per array; load
    verifies before handing the state to the trainer.

Compressed containers (QTensor/BlockSparseTensor/QEmbed) round-trip with
their static metadata, so a serving node can restart from an
instance-optimized model directly.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.compressed import BlockSparseTensor, QEmbed, QTensor

_CONTAINERS = (QTensor, BlockSparseTensor, QEmbed)


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, _CONTAINERS))


def _path_str(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(out)


def _record_structure_only(tree, path, out) -> None:
    """Collect pytree nodes a leaf-path manifest cannot represent:
    empty dicts/lists/tuples and ``None`` leaves (jax flattening drops
    all of them).  ``restore`` never needs this (its ``target`` carries
    the structure); ``restore_tree`` re-inserts them so a template-free
    load round-trips e.g. a params dict whose ``tail`` list is empty."""
    if tree is None:
        out.append({"path": "/".join(path), "kind": "none"})
    elif isinstance(tree, _CONTAINERS):
        pass
    elif isinstance(tree, dict):
        if not tree:
            out.append({"path": "/".join(path), "kind": "dict"})
        for k, v in tree.items():
            _record_structure_only(v, path + [str(k)], out)
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out.append({"path": "/".join(path), "kind": "list"})
        for i, v in enumerate(tree):
            _record_structure_only(v, path + [str(i)], out)


def save(ckpt_dir: str, step: int, state, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Write ``state`` (any pytree, compressed containers included)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, treedef = _flatten(state)
    tmp = tempfile.mkdtemp(prefix="tmp.", dir=ckpt_dir)
    manifest: Dict[str, Any] = {"step": int(step), "arrays": {},
                                "extra": extra or {}}
    structure_only: list = []
    _record_structure_only(state, [], structure_only)
    if structure_only:
        manifest["structure_only"] = structure_only
    arrays: Dict[str, np.ndarray] = {}
    for i, (path, leaf) in enumerate(flat):
        name = f"a{i}"
        meta: Dict[str, Any] = {"path": _path_str(path)}
        if isinstance(leaf, QTensor):
            meta["kind"] = "qtensor"
            meta["bits"], meta["group"] = leaf.bits, leaf.group
            meta["shape"] = list(leaf.shape)
            arrays[name + ".q"] = np.asarray(jax.device_get(leaf.q))
            arrays[name + ".scale"] = np.asarray(jax.device_get(leaf.scale))
            meta["has_in_scale"] = leaf.in_scale is not None
            if leaf.in_scale is not None:
                arrays[name + ".in_scale"] = np.asarray(
                    jax.device_get(leaf.in_scale))
        elif isinstance(leaf, BlockSparseTensor):
            meta["kind"] = "blocksparse"
            meta["bs"] = leaf.bs
            arrays[name + ".w"] = np.asarray(jax.device_get(leaf.w))
            arrays[name + ".mask"] = np.asarray(jax.device_get(leaf.mask))
            meta["has_idx"] = leaf.idx is not None
            if leaf.idx is not None:
                arrays[name + ".idx"] = np.asarray(jax.device_get(leaf.idx))
        elif isinstance(leaf, QEmbed):
            meta["kind"] = "qembed"
            arrays[name + ".q"] = np.asarray(jax.device_get(leaf.q))
            arrays[name + ".scale"] = np.asarray(jax.device_get(leaf.scale))
        else:
            meta["kind"] = "array"
            arrays[name] = np.asarray(jax.device_get(leaf))
        manifest["arrays"][name] = meta

    npz_path = os.path.join(tmp, "arrays.npz")
    # bfloat16 has no numpy dtype string round-trip; store via view + tag
    save_arrays = {}
    for k, a in arrays.items():
        if a.dtype.name == "bfloat16":
            save_arrays[k] = a.view(np.uint16)
            manifest.setdefault("bf16", []).append(k)
        else:
            save_arrays[k] = a
    np.savez(npz_path, **save_arrays)
    with open(npz_path, "rb") as f:
        manifest["sha256"] = hashlib.sha256(f.read()).hexdigest()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target, *, step: Optional[int] = None,
            shardings=None, verify: bool = True) -> Tuple[Any, int, Dict]:
    """Rebuild ``target``-structured state from disk (elastic re-shard).

    ``target``: a pytree of arrays OR ShapeDtypeStructs with the desired
    structure; ``shardings``: matching pytree of NamedSharding (optional)
    — arrays are placed per-shard via jax.device_put.
    """
    import jax.numpy as jnp
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(d, "arrays.npz")
    if verify:
        with open(npz_path, "rb") as f:
            h = hashlib.sha256(f.read()).hexdigest()
        if h != manifest["sha256"]:
            raise IOError(f"checkpoint {d} corrupt: hash mismatch")
    data = np.load(npz_path)
    bf16 = set(manifest.get("bf16", []))

    def get(name):
        a = data[name]
        if name in bf16:
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        return a

    flat_t, treedef = _flatten(target)
    leaves = []
    for i, (_path, _tgt) in enumerate(flat_t):
        name = f"a{i}"
        meta = manifest["arrays"][name]
        if meta["kind"] == "qtensor":
            leaves.append(QTensor(
                jnp.asarray(get(name + ".q")),
                jnp.asarray(get(name + ".scale")),
                meta["bits"], meta["group"], tuple(meta["shape"]),
                jnp.asarray(get(name + ".in_scale"))
                if meta.get("has_in_scale") else None))
        elif meta["kind"] == "blocksparse":
            leaves.append(BlockSparseTensor(
                jnp.asarray(get(name + ".w")),
                jnp.asarray(get(name + ".mask")), meta["bs"],
                jnp.asarray(get(name + ".idx"))
                if meta.get("has_idx") else None))
        elif meta["kind"] == "qembed":
            leaves.append(QEmbed(jnp.asarray(get(name + ".q")),
                                 jnp.asarray(get(name + ".scale"))))
        else:
            a = get(name)
            leaves.append(jnp.asarray(a))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            state, shardings,
            is_leaf=lambda x: isinstance(x, _CONTAINERS))
    return state, step, manifest.get("extra", {})


def _leaf_from_meta(meta, name, get):
    """One manifest entry -> its runtime leaf (shared with restore)."""
    import jax.numpy as jnp
    if meta["kind"] == "qtensor":
        return QTensor(
            jnp.asarray(get(name + ".q")),
            jnp.asarray(get(name + ".scale")),
            meta["bits"], meta["group"], tuple(meta["shape"]),
            jnp.asarray(get(name + ".in_scale"))
            if meta.get("has_in_scale") else None)
    if meta["kind"] == "blocksparse":
        return BlockSparseTensor(
            jnp.asarray(get(name + ".w")),
            jnp.asarray(get(name + ".mask")), meta["bs"],
            jnp.asarray(get(name + ".idx"))
            if meta.get("has_idx") else None)
    if meta["kind"] == "qembed":
        return QEmbed(jnp.asarray(get(name + ".q")),
                      jnp.asarray(get(name + ".scale")))
    return jnp.asarray(get(name))


def restore_tree(ckpt_dir: str, *, step: Optional[int] = None,
                 verify: bool = True) -> Tuple[Any, int, Dict]:
    """Structure-free restore: rebuild the pytree from the manifest's
    recorded key paths alone, no ``target`` template needed.

    ``restore`` requires the caller to already hold a pytree with the
    right structure — fine for a trainer resuming its own state, wrong
    for a *warm-restarting service* (repro/service/checkpoint.py) that
    must reload compressed models it has never built in this process.
    Key paths are re-nested from the manifest's ``path`` strings;
    dicts whose keys are exactly ``0..n-1`` (as strings) were sequence
    entries and convert back to lists.  Leaf reconstruction (QTensor /
    BlockSparseTensor / QEmbed / array) is byte-identical to
    ``restore``'s.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(d, "arrays.npz")
    if verify:
        with open(npz_path, "rb") as f:
            h = hashlib.sha256(f.read()).hexdigest()
        if h != manifest["sha256"]:
            raise IOError(f"checkpoint {d} corrupt: hash mismatch")
    data = np.load(npz_path)
    bf16 = set(manifest.get("bf16", []))

    def get(name):
        a = data[name]
        if name in bf16:
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        return a

    root: Dict[str, Any] = {}

    def insert(parts, value):
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    for name, meta in manifest["arrays"].items():
        parts = meta["path"].split("/") if meta["path"] else []
        leaf = _leaf_from_meta(meta, name, get)
        if not parts:               # scalar/array state: the tree IS it
            return leaf, step, manifest.get("extra", {})
        insert(parts, leaf)
    # re-insert what leaf flattening dropped: empty containers + Nones
    for s in manifest.get("structure_only", []):
        value = {"none": None, "dict": {}, "list": []}[s["kind"]]
        parts = s["path"].split("/") if s["path"] else []
        if not parts:
            return value, step, manifest.get("extra", {})
        insert(parts, value)

    def listify(node):
        if not isinstance(node, dict):
            return node
        out = {k: listify(v) for k, v in node.items()}
        keys = list(out)
        if keys and all(k.isdigit() for k in keys):
            idx = sorted(int(k) for k in keys)
            if idx == list(range(len(idx))):
                return [out[str(i)] for i in idx]
        return out

    return listify(root), step, manifest.get("extra", {})


def atomic_write_json(path: str, obj: Any) -> None:
    """Crash-safe JSON write: temp file in the destination directory,
    flush + fsync, then ``os.replace`` — readers only ever see the old
    or the complete new content.  The service's warm-state manifest
    writer (non-array state: recipes, cascade thresholds, residency)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".tmp.", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
