"""Gradient compression for cross-pod all-reduce (int8 + error feedback).

The multi-pod mesh pays ~4 bytes/param/step of inter-pod DCI traffic for
gradient all-reduce.  This module implements a *compressed all-reduce*:

    reduce-scatter phase:  all_to_all of int8-quantized gradient chunks
    local sum:             f32 accumulation of the received chunks
    all-gather phase:      all_gather of the requantized int8 partials

Wire bytes drop 4x (int8 + one f32 scale per chunk vs f32 everywhere).
Quantization error is carried in a local *error-feedback residual* that
is added to the next step's gradient before quantization — the standard
convergence-preserving trick (1-bit Adam lineage).

Everything is expressed with ``lax`` collectives inside ``shard_map`` so
XLA sees real all_to_all/all_gather ops on the pod axis (verifiable in
the dry-run HLO, testable on host devices).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.rint(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compressed_allreduce_leaf(g: jax.Array, res: jax.Array, axis: str,
                               n: int):
    """Mean-all-reduce one gradient leaf over ``axis`` (n shards) with int8
    wire format and error feedback.  Runs inside shard_map."""
    shape = g.shape
    gf = g.astype(jnp.float32) + res
    flat = gf.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)

    # --- reduce-scatter (int8 on the wire) ---
    q, scale = _quantize(chunks)                       # one scale per step
    sent = q.astype(jnp.float32) * scale               # what peers receive
    local_err = chunks - sent                          # error feedback
    recv = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0,
                              tiled=False)             # [n, chunk]
    scales = jax.lax.all_gather(scale, axis)           # [n]
    part = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0) / n

    # --- all-gather (int8 on the wire) ---
    q2, scale2 = _quantize(part)
    sent2 = q2.astype(jnp.float32) * scale2
    idx = jax.lax.axis_index(axis)
    local_err += jnp.zeros_like(chunks).at[idx].set(part - sent2) * n
    got = jax.lax.all_gather(q2, axis)                 # [n, chunk]
    scs = jax.lax.all_gather(scale2, axis)
    out = (got.astype(jnp.float32) * scs[:, None]).reshape(-1)
    out = out[: gf.size].reshape(shape)
    new_res = local_err.reshape(-1)[: gf.size].reshape(shape)
    return out.astype(g.dtype), new_res


def compressed_allreduce(grads, residual, *, axis: str, mesh):
    """Mean-all-reduce every leaf over the mesh ``axis`` with int8 wire
    format; returns (grads, new_residual).  Leaves are assumed replicated
    over ``axis`` pre-call (each pod holds its own pod-local mean)."""
    n = mesh.shape[axis]
    if n == 1:
        return grads, residual

    from jax.experimental.shard_map import shard_map

    def body(g_tree, r_tree):
        pairs = jax.tree.map(
            functools.partial(_compressed_allreduce_leaf, axis=axis, n=n),
            g_tree, r_tree)
        is_t = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda t: t[0], pairs, is_leaf=is_t),
                jax.tree.map(lambda t: t[1], pairs, is_leaf=is_t))

    # replicate in/out over all axes; internal collectives act on `axis`
    gspec = jax.tree.map(lambda _: P(), grads)
    rspec = jax.tree.map(lambda _: P(), residual)
    fn = shard_map(body, mesh=mesh, in_specs=(gspec, rspec),
                   out_specs=(gspec, rspec), check_rep=False)
    return fn(grads, residual)
