"""Training substrate: optimizers, loop, checkpointing, grad compression,
synthetic data pipeline."""
