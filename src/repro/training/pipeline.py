"""Pipeline parallelism: GPipe-style microbatch pipelining over a
``stage`` mesh axis (off in the graded dry-run, whose
production mesh fixes axes to pod/data/model; provided for users whose
mesh exposes a stage axis).

The model is split into S stages of equal layer count; microbatches
stream through stages via ``shard_map`` + ``lax.ppermute``.  The classic
GPipe schedule runs S + M - 1 ticks for M microbatches; each device
computes its stage's layers on the microbatch it holds, then permutes
activations to the next stage.  Bubble fraction = (S-1)/(S+M-1) — the
test asserts the schedule produces the exact sequential result.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn: Callable, params_stacked, x_mb, *,
                     mesh: Mesh, axis: str = "stage"):
    """Run M microbatches through S pipeline stages.

    stage_fn(stage_params, x) -> x            (one stage's computation)
    params_stacked: pytree with leading [S] axis, sharded over ``axis``
    x_mb: [M, mb, ...] microbatches (replicated)
    Returns [M, mb, ...] outputs (replicated).
    """
    S = mesh.shape[axis]
    M = x_mb.shape[0]
    T = S + M - 1                                 # schedule ticks

    def per_stage(params_local, x_all):
        # params_local: this stage's params (leading axis sliced to 1)
        p = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(x_all[0])            # activation in flight
        outs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any)
            take = jnp.clip(t, 0, M - 1)
            fresh = x_all[take]
            buf = jnp.where(sid == 0,
                            jnp.where(t < M, fresh, jnp.zeros_like(fresh)),
                            buf)
            # every stage computes on what it holds
            y = stage_fn(p, buf)
            # last stage retires microbatch t - (S - 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            live = (t - (S - 1) >= 0) & (t - (S - 1) < M)
            outs = jnp.where(
                (sid == S - 1) & live,
                outs.at[out_idx].set(y), outs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # gather the last stage's outputs to everyone
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    from jax.experimental.shard_map import shard_map
    spec_p = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x_mb)


def split_stages(layer_params, n_stages: int):
    """Re-stack [L, ...] layer params into [S, L/S, ...] stage params."""
    def re(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree.map(re, layer_params)
