"""Sharded optimizers: AdamW and Adafactor, functional style.

States are pytrees mirroring the params, so the same PartitionSpec rules
shard them (Adafactor's factored second moment keeps only row/col
statistics — the memory-frugal choice for the arctic-480b train cells).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), tree), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]        # (params, grads, state, step)
    global_norm: Callable[[Any], jax.Array] = global_norm


def _warmup_cosine(lr: float, warmup: int, total: int):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return sched


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip: float = 1.0, warmup: int = 100,
          total_steps: int = 10000) -> Optimizer:
    sched = _warmup_cosine(lr, warmup, total_steps)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(params, grads, state, step):
        grads, _ = clip_by_global_norm(grads, clip)
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** (jnp.asarray(step, jnp.float32) + 1)
        bc2 = 1.0 - b2 ** (jnp.asarray(step, jnp.float32) + 1)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        params2 = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m2 = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        v2 = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return params2, {"m": m2, "v": v2}

    return Optimizer(init=init, update=update)


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip: float = 1.0, weight_decay: float = 0.0,
              warmup: int = 100, total_steps: int = 10000) -> Optimizer:
    """Factored second-moment optimizer (rank-1 v for matrices)."""
    sched = _warmup_cosine(lr, warmup, total_steps)

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params)}

    def update(params, grads, state, step):
        grads, _ = clip_by_global_norm(grads, clip)
        lr_t = sched(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = vr[..., :, None] * vc[..., None, :] \
                    / jnp.maximum(vr.mean(-1)[..., None, None], eps)
                u = gf * jax.lax.rsqrt(denom + eps)
                s2 = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                s2 = {"v": v}
            # update clipping (Adafactor RMS rule)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            if p.ndim >= 2 and weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), s2

        flat, treedef = jax.tree_util.tree_flatten(params)
        gflat = treedef.flatten_up_to(grads)
        sflat = treedef.flatten_up_to(state["f"])
        pairs = [upd(p, g, s) for p, g, s in zip(flat, gflat, sflat)]
        params2 = treedef.unflatten([a for a, _ in pairs])
        state2 = {"f": treedef.unflatten([b for _, b in pairs])}
        return params2, state2

    return Optimizer(init=init, update=update)
