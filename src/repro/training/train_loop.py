"""Training loop: microbatch accumulation, remat, checkpoint/restart.

Designed for preemptible fleets:
  - deterministic (seed, step) -> batch (see data.py) so any worker can
    be killed and replayed with no data-service coordination;
  - atomic checkpoints every ``ckpt_every`` steps; on start the loop
    resumes from the latest valid checkpoint automatically;
  - gradient accumulation over ``microbatches`` via ``lax.scan`` keeps
    the per-step activation footprint at 1/M;
  - optional int8 error-feedback gradient compression on a mesh axis
    (multi-pod training, see grad_compress.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.training import checkpoint as ckpt
from repro.training import data as D
from repro.training.optimizer import Optimizer, global_norm


@dataclass
class TrainConfig:
    steps: int = 200
    batch: int = 16
    seq_len: int = 128
    microbatches: int = 1
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 20
    xent_chunk: int = 0
    aux_weight: float = 0.01


def make_train_step(model_cfg, optimizer: Optimizer, *,
                    microbatches: int = 1, xent_chunk: int = 0,
                    grad_compressor: Optional[Callable] = None,
                    aux_weight: float = 0.01):
    """(params, opt_state, batch, step[, residual]) -> updated state.

    ``batch["tokens"/"labels"]``: [B, S]; B must divide by microbatches.
    """
    def loss(p, b):
        return api.loss_fn(p, model_cfg, b, xent_chunk=xent_chunk,
                           aux_weight=aux_weight)

    def train_step(params, opt_state, batch, step, residual=None):
        if microbatches == 1:
            lv, grads = jax.value_and_grad(loss)(params, batch)
        else:
            M = microbatches
            mb = jax.tree.map(
                lambda a: a.reshape(M, a.shape[0] // M, *a.shape[1:]), batch)

            def acc_body(carry, mbatch):
                lv, g = jax.value_and_grad(loss)(params, mbatch)
                return (carry[0] + lv,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (lv, grads), _ = jax.lax.scan(acc_body, zero, mb,
                                          unroll=model_cfg.scan_unroll)
            lv = lv / M
            grads = jax.tree.map(lambda g: g / M, grads)
        if grad_compressor is not None:
            grads, residual = grad_compressor(grads, residual)
        gnorm = global_norm(grads)
        params, opt_state = optimizer.update(params, grads, opt_state, step)
        metrics = {"loss": lv, "grad_norm": gnorm}
        if grad_compressor is not None:
            return params, opt_state, residual, metrics
        return params, opt_state, metrics

    return train_step


def train(model_cfg, tcfg: TrainConfig, optimizer: Optimizer, *,
          params=None, log: Callable[[str], None] = print,
          batch_fn: Optional[Callable] = None) -> Dict[str, Any]:
    """End-to-end single-host training with restart support."""
    tok = D.ByteTokenizer(max(model_cfg.vocab_size, 260))
    if batch_fn is None:
        def batch_fn(step):
            return D.train_batch(step, batch=tcfg.batch,
                                 seq_len=tcfg.seq_len, tok=tok,
                                 seed=tcfg.seed)
    if params is None:
        params = api.init_params(jax.random.PRNGKey(tcfg.seed), model_cfg)
    opt_state = optimizer.init(params)
    start = 0
    if tcfg.ckpt_dir and ckpt.latest_step(tcfg.ckpt_dir) is not None:
        (params, opt_state), start, extra = ckpt.restore(
            tcfg.ckpt_dir, (params, opt_state))
        log(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(model_cfg, optimizer,
                                      microbatches=tcfg.microbatches,
                                      xent_chunk=tcfg.xent_chunk,
                                      aux_weight=tcfg.aux_weight),
                      donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start, tcfg.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()
                 if k in ("tokens", "labels")}
        params, opt_state, metrics = step_fn(params, opt_state, batch, step)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            lv = float(metrics["loss"])
            losses.append((step, lv))
            log(f"[train] step {step:5d} loss {lv:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time() - t0):.1f}s)")
        if tcfg.ckpt_dir and tcfg.ckpt_every \
                and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_dir, step + 1, (params, opt_state))
    if tcfg.ckpt_dir:
        ckpt.save(tcfg.ckpt_dir, tcfg.steps, (params, opt_state))
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "tokenizer": tok}
