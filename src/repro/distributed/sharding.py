"""Per-architecture PartitionSpec rules: DP / TP / EP / SP on one mesh.

Axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Batch parallelism runs over ("pod","data"); tensor
parallelism over "model"; expert parallelism places experts on "data"
(tokens flow to experts via XLA all-to-all); sequence parallelism puts
the KV-cache/sequence axis on "data" when the batch axis cannot use it
(long-context, batch=1).

Rules are divisibility-guarded: a dim is sharded only when the axis size
divides it, otherwise it degrades to replication — every (arch x shape x
mesh) cell lowers to a *valid* program, and the roofline analysis then
shows what the degradation costs.

Megatron-style attention TP: wq column-parallel over heads, wk/wv
column-parallel only when kv-heads divide the model axis (else KV is
replicated — the standard GQA fallback), wo row-parallel.  MLP: wi/wg
column-, wo row-parallel.  Embedding vocab-sharded, unembed
vocab-column-sharded.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return int(n)


def _div(dim: int, n: int) -> bool:
    return n > 1 and dim % n == 0


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL = {"wq", "wi", "wg", "in_proj", "wa1", "unembed"}   # d_out -> model
_ROW = {"wo", "out_proj", "wa2"}                          # d_in  -> model
_KV = {"wk", "wv"}                                        # guarded by kv div
_REPL = {"router", "mu", "w0", "u", "gn", "conv_w", "conv_b", "A_log", "D",
         "dt_bias", "pos_enc", "pos_dec"}


def _leaf_name(path) -> str:
    """Last string key (skips container-child index keys, e.g. QTensor.q)."""
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
    return ""


def _path_str(path) -> str:
    return ".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec_fn(cfg, mesh: Mesh, *, fsdp: bool = False):
    """Returns fn(path, shape_tuple) -> PartitionSpec for raw params."""
    M = axis_size(mesh, "model")
    D = axis_size(mesh, "data")
    kv_ok = _div(cfg.n_kv_heads, M)

    def spec(path, shape) -> P:
        name = _leaf_name(path)
        pstr = _path_str(path)
        rank = len(shape)
        lead = rank - 2          # stacked layer axes before the matrix
        pre = (None,) * max(lead, 0)

        def guard(s: P) -> P:
            """Drop shardings that don't divide; optionally add FSDP."""
            dims = list(s)
            out = []
            for i, ax in enumerate(dims):
                d = shape[lead + i] if lead >= 0 else shape[i]
                if ax is None:
                    out.append(None)
                elif _div(d, axis_size(mesh, ax)):
                    out.append(ax)
                else:
                    out.append(None)
            # FSDP: shard the remaining replicated matrix dim over data
            if fsdp and rank >= 2:
                for i in range(len(out)):
                    d = shape[lead + i]
                    if out[i] is None and _div(d, D):
                        out[i] = "data"
                        break
            return P(*pre, *out)

        if name == "embed":
            return guard(P("model", None)) if rank == 2 else P()
        if rank < 2 or name in _REPL or "ln" in name or name == "w" \
                or name == "b":
            return P(*(None,) * rank)
        # MoE expert stacks: [.., E, d_in, d_out]
        if ".moe." in f".{pstr}." and name in ("wi", "wg", "wo"):
            E = shape[lead - 1] if lead >= 1 else shape[0]
            e_ax = "data" if _div(E, D) else None
            epre = (None,) * max(lead - 1, 0)
            if name == "wo":
                body = ("model" if _div(shape[-2], M) else None, None)
            else:
                body = (None, "model" if _div(shape[-1], M) else None)
            if fsdp and e_ax is None:
                pass
            return P(*epre, e_ax, *body)
        if name in _COL:
            return guard(P(None, "model"))
        if name in _ROW:
            return guard(P("model", None))
        if name in _KV:
            if ".cm." in f".{pstr}.":        # rwkv channel-mix: plain MLP
                return guard(P(None, "model") if name == "wk"
                             else P("model", None))
            if kv_ok:
                return guard(P(None, "model"))
            return guard(P(None, None))      # replicate KV (GQA fallback)
        if name in ("wr", "wg2"):
            return guard(P(None, "model"))
        return P(*(None,) * rank)

    return spec


def param_shardings(cfg, params_or_shapes, mesh: Mesh, *, fsdp: bool = False):
    """NamedSharding pytree for a (possibly abstract) param tree."""
    fn = param_spec_fn(cfg, mesh, fsdp=fsdp)

    def one(path, leaf):
        return NamedSharding(mesh, fn(path, tuple(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, params_or_shapes)


def opt_state_shardings(param_specs_tree, mesh: Mesh, kind: str = "adamw"):
    """Optimizer-state shardings derived from param shardings.

    adamw: m/v mirror params.  adafactor: vr keeps the row spec, vc the
    column spec of the factored matrix.
    """
    if kind == "adamw":
        return {"m": param_specs_tree, "v": param_specs_tree}

    def factored(sh):
        spec = sh.spec
        if len(spec) >= 2:
            return {"vr": NamedSharding(mesh, P(*spec[:-1])),
                    "vc": NamedSharding(mesh, P(*spec[:-2], spec[-1]))}
        return {"v": sh}

    return {"f": jax.tree.map(factored, param_specs_tree)}


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_shardings(cfg, batch_shapes: Dict[str, Any], mesh: Mesh):
    """Token/label/frontend-stub input shardings (DP, falling back to SP)."""
    dp = dp_axes(mesh)
    dpn = axis_size(mesh, dp)

    def one(name, sds):
        shape = sds.shape
        rank = len(shape)
        B = shape[0]
        bspec = dp if _div(B, dpn) else None
        if rank == 2:        # tokens / labels [B, S]
            S = shape[1]
            sspec = None
            if bspec is None and _div(S, axis_size(mesh, "data")) and S > 1:
                sspec = "data"       # sequence parallelism for batch=1 cells
            return NamedSharding(mesh, P(bspec, sspec))
        if rank == 3:        # frame/patch embeddings [B, T, d]
            return NamedSharding(
                mesh, P(bspec, None,
                        "model" if _div(shape[-1], axis_size(mesh, "model"))
                        else None))
        return NamedSharding(mesh, P(bspec, *(None,) * (rank - 1)))

    return {k: one(k, v) for k, v in batch_shapes.items()}


def cache_shardings(cfg, cache_shapes, mesh: Mesh):
    """KV-cache / recurrent-state shardings.

    Attention k/v leaves [..., B, T, K, hd]: batch over DP when it
    divides, else the sequence axis goes to "data" (SP — the long_500k
    cells); KV heads over "model" when they divide, else head_dim.
    Recurrent states (rwkv S, mamba h/conv): batch over DP.
    """
    dp = dp_axes(mesh)
    dpn = axis_size(mesh, dp)
    M = axis_size(mesh, "model")
    Dn = axis_size(mesh, "data")

    def one(path, sds):
        shape = sds.shape
        rank = len(shape)
        name = _leaf_name(path)
        if name in ("k", "v") and rank >= 4:
            B, T, K, hd = shape[-4], shape[-3], shape[-2], shape[-1]
            pre = (None,) * (rank - 4)
            bspec = dp if _div(B, dpn) else None
            tspec = None
            if bspec is None and _div(T, Dn):
                tspec = "data"
            kspec, hspec = None, None
            if _div(K, M):
                kspec = "model"
            elif OPT["kv_seq_shard"] and tspec is None and _div(T, M):
                tspec = "model"          # sequence-shard the cache instead
            elif _div(hd, M):
                hspec = "model"
            return NamedSharding(mesh, P(*pre, bspec, tspec, kspec, hspec))
        if name in ("S", "h") and rank >= 4:  # rwkv S / mamba h [..,B,H,*,*]
            pre = (None,) * (rank - 4)
            B, H = shape[-4], shape[-3]
            bspec = dp if _div(B, dpn) else None
            hspec = "model" if _div(H, M) else None
            return NamedSharding(mesh, P(*pre, bspec, hspec, None, None))
        if name == "conv" and rank >= 3:      # mamba conv state [..,B,K-1,ch]
            pre = (None,) * (rank - 3)
            bspec = dp if _div(shape[-3], dpn) else None
            return NamedSharding(mesh, P(*pre, bspec, None, None))
        if name in ("tm_x", "cm_x") and rank >= 2:  # rwkv shifts [..,B,d]
            pre = (None,) * (rank - 2)
            bspec = dp if _div(shape[-2], dpn) else None
            return NamedSharding(mesh, P(*pre, bspec, None))
        if name == "enc_len":
            B = shape[-1]
            return NamedSharding(mesh, P(dp if _div(B, dpn) else None))
        return NamedSharding(mesh, P(*(None,) * rank))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def logits_sharding(cfg, mesh: Mesh, batch: int):
    dp = dp_axes(mesh)
    bspec = dp if _div(batch, axis_size(mesh, dp)) else None
    vspec = "model" if _div(cfg.vocab_size, axis_size(mesh, "model")) else None
    return NamedSharding(mesh, P(bspec, None, vspec))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# activation sharding (Megatron-style sequence parallelism between layers)
# ---------------------------------------------------------------------------
# Remat saves each scan step's block input: [B_loc, S, d] x n_layers.  At
# train_4k that is tens of GB per device unless the sequence axis is also
# sharded between layers; GSPMD then all-gathers S at each attention/MLP
# entry and reduce-scatters at exit.  The constraint is installed per
# lowering (the models call constrain() unconditionally; it is a no-op
# unless a spec is active and divisibility holds).

_ACT_SHARDING = None

# ---------------------------------------------------------------------------
# §Perf opt-in switches (see distributed/README.md): the hillclimb
# iterations.
# Baselines lower with everything False; `set_opt(...)`/env DRYRUN_OPT
# flips individual optimizations for the before/after measurements.
# ---------------------------------------------------------------------------
OPT = {
    # MoE dispatch buffers [E, C, d] get explicit token/expert sharding +
    # capacity rounded to a shardable multiple (qwen/arctic cells)
    "moe_sharded_dispatch": False,
    # decode KV update as masked select instead of batch-indexed scatter
    # (keeps the cache sharding; kills the involuntary all-gather)
    "masked_cache_update": False,
    # decode KV cache sequence-sharded over "model" when kv-heads don't
    # divide it (cross-shard softmax costs tiny psums; head_dim-sharding
    # makes GSPMD all-gather the whole cache every step)
    "kv_seq_shard": False,
    # blocked-flash attention already at 4k sequences (train cells)
    "flash_at_4k": False,
    # decode-time MoE capacity 4x mean load instead of dropless C=T
    "moe_decode_capacity": False,
    # eval capacity factor 1.25 instead of 2.0 (probability-ordered
    # dropping makes the extra slack unnecessary)
    "moe_eval_cf125": False,
}


def set_opt(**kw) -> None:
    for k, v in kw.items():
        assert k in OPT, k
        OPT[k] = bool(v)


def set_opt_from_env(env: str = "") -> None:
    for k in env.split(","):
        k = k.strip()
        if k:
            set_opt(**{k: True})


def constrain_moe(x):
    """Sharding constraint for MoE dispatch tensors [E, C, d_or_ff]."""
    if not OPT["moe_sharded_dispatch"] or _ACT_SHARDING is None \
            or x.ndim != 3:
        return x
    mesh = _ACT_SHARDING.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    E, C, d = x.shape
    e_ax = "data" if E % sizes.get("data", 1) == 0 else None
    c_ax = "data" if e_ax is None and C % sizes.get("data", 1) == 0 else None
    d_ax = "model" if d % sizes.get("model", 1) == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(e_ax, c_ax, d_ax)))


def set_activation_sharding(ns) -> None:
    """ns: NamedSharding for [B, S, d] activations, or None to disable."""
    global _ACT_SHARDING
    _ACT_SHARDING = ns


def constrain(x):
    ns = _ACT_SHARDING
    if ns is None or x.ndim != 3:
        return x
    for dim, ax in zip(x.shape, ns.spec):
        if ax is not None:
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= dict(zip(ns.mesh.axis_names, ns.mesh.devices.shape))[a]
            if dim % n:
                return x
    return jax.lax.with_sharding_constraint(x, ns)
