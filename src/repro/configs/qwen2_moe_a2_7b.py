"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

MoE decoder: 24L, d_model 2048, 16 heads MHA (kv=16), head_dim 128,
60 routed experts top-4 + 4 always-active shared experts, per-expert
d_ff 1408, vocab 151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    max_seq=32768,
    supports_long_context=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=96, moe_d_ff=96, n_experts=6,
        top_k=2, n_shared_experts=1, vocab_size=256, max_seq=512)
