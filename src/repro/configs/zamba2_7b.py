"""Zamba2-7B [arXiv:2411.15242].

Mamba2 backbone with a SHARED attention+MLP block interleaved:
81 block applications = 70 Mamba2 layers + 11 applications of one shared
transformer block (every 7th position).  d_model 3584, 32 heads
(kv=32, head_dim 112), d_ff 14336, ssm_state 64, expand 2
(d_inner 7168 = 112 SSD heads x 64).  vocab 32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    d_state=64,
    ssd_head_dim=64,
    expand=2,
    conv_kernel=4,
    shared_attn_every=6,   # 81 // 7 = 11 shared sites, 70 mamba layers
    max_seq=1 << 20,
    supports_long_context=True,
    notes="pruning the shared block affects all 11 call sites at once",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-7b-smoke", n_layers=9, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, d_state=16,
        ssd_head_dim=16, shared_attn_every=2, max_seq=512)
