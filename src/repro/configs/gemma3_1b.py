"""Gemma3-1B [hf:google/gemma-3-1b-pt].

Dense decoder, 5:1 local:global attention, 128k ctx on global layers:
26L, d_model 1152, 4 q / 1 kv head (MQA), head_dim 256, d_ff 6912,
vocab 262144.  Local window 512, local rope theta 10k, global 1M.
26 = 4x(LLLLLG) + LL remainder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    attn_pattern="LLLLLG" * 4 + "LL",
    window_size=512,
    rope_theta=1_000_000.0,
    rope_theta_local=10000.0,
    rms_offset=True,
    post_norms=True,
    emb_scale=True,
    tie_embeddings=True,
    max_seq=131072,
    # 5:1 local:global, kv=1 -> only ~4 global layers hold 500k KV (~2 GB): runnable
    supports_long_context=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma3-1b-smoke", n_layers=8, attn_pattern="LLLLLG" + "LL",
        d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
        vocab_size=256, window_size=64, max_seq=512)
