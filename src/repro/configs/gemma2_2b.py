"""Gemma2-2B [arXiv:2408.00118].

Dense decoder with alternating local(4096-window)/global attention and
logit softcapping: 26L, d_model 2304, 8 q / 4 kv heads, head_dim 256,
d_ff 9216, vocab 256000.  Embeddings tied + scaled by sqrt(d); RMSNorm
uses the (1+w) gemma convention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_pattern="LG" * 13,
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10000.0,
    rms_offset=True,
    post_norms=True,
    emb_scale=True,
    tie_embeddings=True,
    max_seq=8192,
    # 1:1 local:global alternation -> 13 full-attention layers at 500k; skipped
    supports_long_context=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-2b-smoke", n_layers=4, attn_pattern="LG" * 2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        window_size=64, max_seq=512)
