"""Granite-20B-Code [arXiv:2405.04324].

Dense llama-arch code model: 52L, d_model 6144, 48 heads with MQA (kv=1),
head_dim 128, d_ff 24576, vocab 49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_gated=False,   # GPT-BigCode lineage: plain 2-matrix GELU MLP
    rope_theta=10000.0,
    max_seq=8192 * 4,
    supports_long_context=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-20b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256, max_seq=512)
