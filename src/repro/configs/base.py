"""Model configuration system.

One ``ModelConfig`` dataclass covers every assigned architecture family
(dense / moe / encdec / rwkv / hybrid / vlm).  Each architecture file in
this package instantiates the exact published config and provides a
``reduced()`` smoke-test variant that preserves the family's structural
features (attention pattern, MoE routing, hybrid interleaving, ...) at a
fraction of the size.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | encdec | rwkv | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention pattern (dense/vlm/gemma families) ---
    # string of 'L' (local sliding-window) / 'G' (global) per layer; None = all global
    attn_pattern: Optional[str] = None
    window_size: int = 4096
    attn_softcap: float = 0.0        # gemma2-style tanh softcap on attn logits
    final_softcap: float = 0.0       # softcap on output logits
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0    # 0 -> use rope_theta for local layers too

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0        # qwen2-moe: always-active shared experts
    moe_d_ff: int = 0                # per-expert hidden size
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_ctx: int = 1500              # encoder output length for cross-attn stubs

    # --- rwkv6 ---
    rwkv_head_dim: int = 64

    # --- mamba2 / zamba2 hybrid ---
    d_state: int = 0                 # SSM state size N
    ssd_head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    shared_attn_every: int = 0       # zamba2: shared attn block every K mamba layers

    # --- vlm ---
    n_img_tokens: int = 0            # stub patch-embedding prefix length

    # --- common ---
    mlp_gated: bool = True           # gated silu (llama) vs plain 2-mat MLP
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    rms_offset: bool = False         # gemma: scale by (1 + w)
    post_norms: bool = False         # gemma2/3: sandwich (post-sublayer) norms
    emb_scale: bool = False          # gemma: embeddings * sqrt(d_model)
    tie_embeddings: bool = False
    max_seq: int = 131072
    param_dtype: str = "bfloat16"
    # which shapes this arch supports (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False
    # unroll lax.scan over layers: XLA cost_analysis counts a scan body
    # once (trip count unknown), so the dry-run unrolls for faithful
    # roofline FLOPs/bytes; runtime configs keep the compact scan
    scan_unroll: bool = False
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def pattern(self) -> str:
        if self.attn_pattern is not None:
            assert len(self.attn_pattern) == self.n_layers, self.name
            return self.attn_pattern
        return "G" * self.n_layers

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------- parameter counting ----------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params up to norm vectors)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        mlp_dense = (3 if self.mlp_gated else 2) * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            per_layer = attn + mlp_dense
            return self.n_layers * per_layer + emb
        if self.family == "moe":
            moe = self.n_experts * 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * 3 * d * self.moe_d_ff
            dense_res = mlp_dense if self.dense_residual else 0
            router = d * self.n_experts
            per_layer = attn + moe + shared + dense_res + router
            return self.n_layers * per_layer + emb
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp_dense)
            dec = self.n_dec_layers * (2 * attn + mlp_dense)
            return enc + dec + emb
        if self.family == "rwkv":
            # timemix: r,k,v,g,o (d*d each) + decay/lora small; channelmix ~ 2*d*dff
            per_layer = 5 * d * d + 2 * d * self.d_ff + 6 * d * 96
            return self.n_layers * per_layer + emb
        if self.family == "hybrid":
            di = self.expand * d
            mamba = d * 2 * di + d * (2 * self.d_state + di // self.ssd_head_dim) \
                + di * d + self.conv_kernel * (di + 2 * self.d_state)
            n_mamba, n_shared = self.hybrid_layout()
            shared = attn + mlp_dense
            return n_mamba * mamba + shared + emb
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        active_experts = self.n_layers * self.top_k * 3 * d * self.moe_d_ff
        return full - all_experts + active_experts

    def hybrid_layout(self) -> Tuple[int, int]:
        """(n_mamba_layers, n_shared_attn_sites) for zamba2-style hybrids."""
        assert self.family == "hybrid"
        k = self.shared_attn_every
        # n_layers counts every block application (mamba blocks + shared-attn sites)
        n_sites = self.n_layers // (k + 1)
        n_mamba = self.n_layers - n_sites
        return n_mamba, n_sites


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch is paired with these four shape cells.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if skipped."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 500k decode KV unjustifiable"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation.  ``train``: token/label batches.  ``prefill``:
    token batch.  ``decode``: one-token batch + cache state shapes are
    produced by the step builders in repro.models.api (the cache is an
    explicit argument there so its specs live beside the step function).
    """
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.family == "encdec":
        if spec.kind == "train":
            return {
                "enc_inputs": sds((B, S, cfg.d_model), cfg.dtype),  # stub frame embs
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
            }
        if spec.kind == "prefill":
            return {
                "enc_inputs": sds((B, S, cfg.d_model), cfg.dtype),
                "tokens": sds((B, 1), i32),
            }
        # decode: one decoder token; cross-attn context of enc_ctx frames
        return {"tokens": sds((B, 1), i32)}
    if cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        if spec.kind == "train":
            return {
                "img_embs": sds((B, n_img, cfg.d_model), cfg.dtype),  # stub patches
                "tokens": sds((B, S - n_img), i32),
                "labels": sds((B, S - n_img), i32),
            }
        if spec.kind == "prefill":
            return {
                "img_embs": sds((B, n_img, cfg.d_model), cfg.dtype),
                "tokens": sds((B, S - n_img), i32),
            }
        return {"tokens": sds((B, 1), i32)}
    # LM families (dense/moe/rwkv/hybrid)
    if spec.kind == "train":
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    if spec.kind == "prefill":
        return {"tokens": sds((B, S), i32)}
    return {"tokens": sds((B, 1), i32)}
