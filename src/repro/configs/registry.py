"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Dict

from repro.configs import (arctic_480b, gemma2_2b, gemma3_1b, granite_20b,
                           mistral_nemo_12b, paligemma_3b, qwen2_moe_a2_7b,
                           rwkv6_3b, whisper_base, zamba2_7b)
from repro.configs.base import SHAPES, ModelConfig, input_specs, shape_supported

_MODULES = {
    "mistral-nemo-12b": mistral_nemo_12b,
    "granite-20b": granite_20b,
    "gemma2-2b": gemma2_2b,
    "gemma3-1b": gemma3_1b,
    "arctic-480b": arctic_480b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "whisper-base": whisper_base,
    "paligemma-3b": paligemma_3b,
    "rwkv6-3b": rwkv6_3b,
    "zamba2-7b": zamba2_7b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _MODULES[arch].reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.CONFIG for k, m in _MODULES.items()}


def all_cells():
    """Every (arch, shape) cell with its supported/skip status."""
    out = []
    for arch, mod in _MODULES.items():
        for shape in SHAPES:
            ok, reason = shape_supported(mod.CONFIG, shape)
            out.append((arch, shape, ok, reason))
    return out


__all__ = ["ARCH_IDS", "get_config", "get_reduced", "all_configs",
           "all_cells", "input_specs", "SHAPES"]
