"""RWKV6-3B "Finch" [arXiv:2404.05892].

Attention-free linear-recurrence LM with data-dependent decay:
32L, d_model 2560 (40 heads x 64), d_ff 8960, vocab 65536.
O(1) recurrent state per layer -> long_500k decode is supported natively.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # d_model / rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    max_seq=1 << 20,
    supports_long_context=True,
    notes="attention-free: head-pruning stage of the IOLM pipeline is a no-op",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, rwkv_head_dim=16, d_ff=128,
        vocab_size=256, max_seq=512)
