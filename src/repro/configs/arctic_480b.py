"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: 35L, d_model 7168, 56 q / 8 kv heads, head_dim 128,
128 experts top-2 with per-expert d_ff 4864, PLUS a dense residual FFN in
parallel with the MoE at every layer.  vocab 32000.
~480B total / ~17B active parameters.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,            # dense residual branch
    moe_d_ff=4864,        # per-expert hidden
    n_experts=128,
    top_k=2,
    dense_residual=True,
    vocab_size=32000,
    rope_theta=10000.0,
    max_seq=4096 * 8,
    supports_long_context=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="arctic-480b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=96, moe_d_ff=96, n_experts=8,
        top_k=2, vocab_size=256, max_seq=512)
