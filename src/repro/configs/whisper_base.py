"""Whisper-base [arXiv:2212.04356].

Encoder-decoder: 6 enc + 6 dec layers, d_model 512, 8 heads (MHA),
head_dim 64, d_ff 2048, vocab 51865.  LayerNorm, learned absolute
positions.  Conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, T, d_model) directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,              # reported per-stack depth
    n_enc_layers=6,
    n_dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    norm_type="layernorm",
    enc_ctx=1500,
    max_seq=65536,           # stress shapes push decoder ctx to 32k
    supports_long_context=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-base-smoke", n_layers=2, n_enc_layers=2, n_dec_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=256, enc_ctx=32, max_seq=512)
