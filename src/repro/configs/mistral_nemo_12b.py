"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

Dense decoder: 40L, d_model 5120, 32 q heads / 8 kv (GQA), head_dim 128,
d_ff 14336, vocab 131072, 128k ctx (rope theta 1e6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    max_seq=131072,
    supports_long_context=False,  # pure full attention -> long_500k skipped
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mistral-nemo-12b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, max_seq=512)
