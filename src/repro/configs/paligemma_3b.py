"""PaliGemma-3B [arXiv:2407.07726].

VLM: SigLIP vision tower (STUB: precomputed patch embeddings) feeding a
gemma-style decoder backbone: 18L, d_model 2048, 8 q / 1 kv head (MQA),
head_dim 256, d_ff 16384, vocab 257216.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    n_img_tokens=256,
    rope_theta=10000.0,
    emb_scale=True,
    tie_embeddings=True,
    max_seq=8192,
    supports_long_context=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="paligemma-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
        n_img_tokens=8, max_seq=512)
