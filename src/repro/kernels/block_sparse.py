"""Pallas TPU kernel: block-sparse matmul that skips pruned MXU tiles.

The paper uses 2:4 fine-grained sparsity on Ampere sparse tensor cores;
TPUs have no sparse MXU, so the hardware adaptation prunes
whole ``bs x bs`` blocks (bs = 128, the MXU tile) and *skips them
entirely*: the grid's K dimension runs over only the ``keep`` surviving
input blocks of each output block column, gathered through a scalar-
prefetched index array.  FLOPs and HBM traffic both drop by the density
factor — this is where sparsity actually pays on TPU.

idx: [N/bs, keep] int32 — kept input-block rows per output block column
(uniform ``keep`` per column, enforced by sparsify.block_sparse_mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_sparse_matmul_kernel(x, w, idx, *, bs: int, bm: int = 128,
                               interpret: bool = False):
    """x [M, K] @ w [K, N] skipping pruned blocks -> [M, N].

    ``w`` is the dense zero-filled weight (only kept blocks are read);
    ``idx`` [N/bs, keep] selects which K-blocks each N-block consumes.
    """
    M, K = x.shape
    K2, N = w.shape
    nbn, keep = idx.shape
    assert K == K2 and N % bs == 0 and K % bs == 0 and nbn == N // bs
    bm = min(bm, M)
    assert M % bm == 0, (M, bm)
    grid = (M // bm, nbn, keep)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bs), lambda i, j, k, idx_ref: (i, idx_ref[j, k])),
            pl.BlockSpec((bs, bs), lambda i, j, k, idx_ref: (idx_ref[j, k], j)),
        ],
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, k, idx_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nk=keep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(idx, x, w)
