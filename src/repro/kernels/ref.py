"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def quant_matmul(x, q, scale, *, group: int, in_scale=None):
    """x [.., K] @ dequant(q [K, N] int8, scale [K/g, N]) -> [.., N]."""
    K, N = q.shape
    w = q.astype(jnp.float32).reshape(K // group, group, N) * scale[:, None, :]
    w = w.reshape(K, N)
    if in_scale is not None:
        x = x.astype(jnp.float32) * in_scale
    return jnp.einsum("...i,io->...o", x.astype(jnp.float32), w)


def block_sparse_matmul(x, w, mask, *, bs: int):
    """x [.., K] @ (w zeroed outside mask blocks) -> [.., N]."""
    big = jnp.kron(mask.astype(jnp.float32),
                   jnp.ones((bs, bs), jnp.float32))
    wz = w.astype(jnp.float32) * big
    return jnp.einsum("...i,io->...o", x.astype(jnp.float32), wz)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, t_real: int = 0, q_offset: int = 0):
    """q [BH, S, D], k/v [BK, T, D], GQA group = BH // BK -> [BH, S, D]."""
    BH, S, D = q.shape
    BK, T, _ = k.shape
    G = BH // BK
    t_real = t_real or T
    kx = jnp.repeat(k, G, axis=0)
    vx = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("hsd,htd->hst", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    mask = kpos < t_real
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("hst,htd->hsd", p, vx.astype(jnp.float32)).astype(q.dtype)
