"""Pallas TPU kernel: tiled online-softmax attention (flash attention).

Supports the assigned architectures' attention variants in one kernel:
GQA head grouping, causal masking, sliding-window (gemma local layers),
and gemma2-style tanh logit softcap.  The online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across the KV grid
dimension; causal/window-excluded KV tiles are skipped via ``pl.when``
so the MXU does no work for fully-masked tiles.

Layout: the ops.py wrapper flattens heads into the batch dimension —
q [BH, S, D], k/v [BK, T, D] — and passes the (static) GQA group size so
the kernel's index maps pick the right KV head for each Q head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nkv: int, bq: int, bkv: int, scale: float, causal: bool,
            window: int, softcap: float, t_real: int, q_offset: int):
    i = pl.program_id(1)   # query block
    j = pl.program_id(2)   # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + q_offset
    kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # tile-level skip: can any (q, k) pair in this tile pair attend?
    first_q = i * bq + q_offset
    last_q = first_q + bq - 1
    first_k, last_k = j * bkv, j * bkv + bkv - 1
    live = first_k < t_real
    if causal:
        live &= first_k <= last_q
    if window:
        live &= last_k >= first_q - window + 1

    @pl.when(live)
    def _tile():
        q = q_ref[0]              # [bq, D]
        k = k_ref[0]              # [bkv, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = kpos < t_real
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                      # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)           # [bq, 1]
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, group: int, causal: bool = True,
                           window: int = 0, softcap: float = 0.0,
                           t_real: int = 0, q_offset: int = 0,
                           bq: int = 256, bkv: int = 256,
                           interpret: bool = False):
    """q [BH, S, D], k/v [BK, T, D] with BH = BK * group -> [BH, S, D].

    ``t_real``: true KV length (<= padded T); ``q_offset``: absolute
    position of q row 0 (for decode/chunked prefill).
    """
    BH, S, D = q.shape
    BK, T, _ = k.shape
    assert BH == BK * group, (BH, BK, group)
    bq, bkv = min(bq, S), min(bkv, T)
    assert S % bq == 0 and T % bkv == 0, (S, T, bq, bkv)
    t_real = t_real or T
    grid = (BH, S // bq, T // bkv)
    scale = 1.0 / math.sqrt(D)
    return pl.pallas_call(
        functools.partial(_kernel, nkv=T // bkv, bq=bq, bkv=bkv, scale=scale,
                          causal=causal, window=window, softcap=softcap,
                          t_real=t_real, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),   # running numerator
        ],
        interpret=interpret,
    )(q, k, v)
