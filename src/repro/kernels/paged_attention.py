"""Pallas TPU kernel: paged KV-cache decode attention.

The serving engine stores K/V in fixed-size blocks inside one global
pool — ``[num_blocks, block_size, Kh, D]`` — and each decode slot owns a
block *table* (``[slots, T // block_size]`` int32) mapping its logical
positions onto pool blocks.  Shared prompt prefixes alias the same
blocks across slots, so the kernel must gather K/V through the table
instead of reading a contiguous ``[slot, T, ...]`` tensor.

Decode is one query token per slot, so the kernel computes an *exact*
softmax (not the online/flash recurrence): the grid walks the slot's
blocks, accumulating the full ``[T, G]`` score matrix and a gathered
``[T, D]`` V copy in VMEM scratch (T = max_len fits comfortably for
serving-sized contexts), then on the last block applies the
length/window mask and the same max-subtracted softmax as the reference
``_sdpa`` — keeping greedy decode token-identical to the jnp path.

The block table and per-slot lengths ride in scalar-prefetch operands
(``PrefetchScalarGridSpec``) so the K/V index maps can dereference the
table while Pallas schedules the block DMAs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, s_ref, v_scr, *,
            nblk: int, bs: int, scale: float, softcap: float, window: int):
    s_idx = pl.program_id(0)   # slot
    j = pl.program_id(2)       # block within the slot's table

    q = q_ref[0, 0]            # [G, D]
    k = k_ref[0, :, 0, :]      # [bs, D]
    # scores for this block, [bs, G]; contraction over D is exact math, so
    # blocking T cannot change the result vs the one-shot einsum.
    s = jax.lax.dot_general(k, q, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pl.store(s_ref, (pl.ds(j * bs, bs), slice(None)), s)
    pl.store(v_scr, (pl.ds(j * bs, bs), slice(None)), v_ref[0, :, 0, :])

    @pl.when(j == nblk - 1)
    def _done():
        length = len_ref[s_idx]
        kpos = jax.lax.broadcasted_iota(jnp.int32, (nblk * bs, 1), 0)
        valid = kpos < length
        if window:
            valid &= kpos >= length - window
        logits = jnp.where(valid, s_ref[...], NEG_INF)   # [T, G]
        m = jnp.max(logits, axis=0, keepdims=True)
        p = jnp.exp(logits - m)
        probs = p / p.sum(axis=0, keepdims=True)
        out = jax.lax.dot_general(probs.astype(v_scr.dtype), v_scr[...],
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_kernel(q, k_pool, v_pool, tables, lengths, *,
                           softcap: float = 0.0, window: int = 0,
                           interpret: bool = False):
    """q [S, Kh, G, D], pools [nb, bs, Kh, D], tables [S, nblk] int32,
    lengths [S] int32 -> [S, Kh, G, D].  One decode token per slot."""
    S, Kh, G, D = q.shape
    nb, bs, Khp, _ = k_pool.shape
    St, nblk = tables.shape
    assert Kh == Khp and S == St and lengths.shape == (S,), \
        (q.shape, k_pool.shape, tables.shape, lengths.shape)
    T = nblk * bs
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, Kh, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda s, h, j, tbl, ln: (s, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, j, tbl, ln: (tbl[s, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda s, h, j, tbl, ln: (tbl[s, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda s, h, j, tbl, ln: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T, G), jnp.float32),     # full score matrix
            pltpu.VMEM((T, D), v_pool.dtype),    # gathered V
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nblk=nblk, bs=bs,
                          scale=1.0 / math.sqrt(D),
                          softcap=softcap, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, Kh, G, D), q.dtype),
        interpret=interpret,
    )(tables, lengths, q, k_pool, v_pool)
