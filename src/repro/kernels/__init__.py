"""Pallas TPU kernels for the compute hot-spots IOLM-DB optimizes:
int8 dequant-in-VMEM matmul, block-sparse (tile-skipping) matmul, and
flash attention.  ops.py = jit'd wrappers, ref.py = pure-jnp oracles."""
