"""Pallas TPU kernels for the compute hot-spots IOLM-DB optimizes:
int8 dequant-in-VMEM matmul, block-sparse (tile-skipping) matmul, flash
attention, and paged KV-cache decode attention.  ops.py = jit'd
wrappers, ref.py = pure-jnp oracles, backend.py = the KernelBackend
("reference" | "pallas" | "auto") selection API."""
