"""KernelBackend: the explicit replacement for the ``use_kernels()`` flag.

A backend names which implementation of the compute hot-spots runs:

``"reference"``   pure-jnp paths (portable, the numerical oracle)
``"pallas"``      the fused Pallas kernels (interpret-mode on CPU, so CI
                  stays bit-faithful on hosts without a TPU)
``"auto"``        resolve at use time: ``"pallas"`` on TPU, else
                  ``"reference"``

The backend is threaded explicitly — ``IOLMSession(backend=…)`` →
``ModelPool`` → ``Engine`` → physical plan — instead of living in a
process-wide mutable flag, so the fan-out scheduler can host engines
with different backends and ``Query.explain()`` can show the choice.
``repro.core.compressed.kernel_backend`` is the scoped context manager
that engines wrap around their jit trace sites.
"""
from __future__ import annotations

BACKENDS = ("reference", "pallas", "auto")


def normalize_backend(backend) -> str:
    """Validate and canonicalize a backend name (``None`` -> ``"auto"``)."""
    if backend is None:
        return "auto"
    b = str(backend).lower()
    if b not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    return b


def resolve_backend(backend="auto") -> str:
    """Resolve to a concrete backend: ``"reference"`` or ``"pallas"``."""
    b = normalize_backend(backend)
    if b == "auto":
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return b
