"""Pallas TPU kernel: int8 group-quantized matmul with dequant-in-VMEM.

Hardware adaptation of the paper's INT8 CUDA GEMM: the
weight lives in HBM as int8 (+ f32 group scales), halving the memory
roofline term that dominates decode; each grid step copies one
``[bk, bn]`` int8 tile into VMEM, dequantizes it to bf16 *in VMEM*, and
feeds the MXU.  Accumulation is f32 in a VMEM scratch tile across the K
grid dimension.

Tile choice (v5e): bm=*rows*, bn=128 (lane width), bk=512.  The working
set per step is  x[bm,bk] bf16 + q[bk,bn] int8 + scale[bk/g,bn] f32 +
acc[bm,bn] f32  ≈ 128·512·2 + 512·128·1 + 4·128·4 + 128·128·4 ≈ 0.26 MB
— comfortably inside the ~16 MB VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, nk: int, group: int,
            out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequantize the int8 tile in VMEM: [bk, bn] * scale[bk/g, bn]
    q = q_ref[...].astype(jnp.float32)
    bk, bn = q.shape
    s = s_ref[...]                                    # [bk // g, bn]
    w = (q.reshape(bk // group, group, bn) * s[:, None, :]) \
        .reshape(bk, bn).astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def quant_matmul_kernel(x, q, scale, *, group: int, bm: int = 128,
                        bn: int = 128, bk: int = 512,
                        interpret: bool = False):
    """x [M, K] bf16 @ dequant(q [K, N] int8, scale [K/g, N] f32) -> [M, N].

    Shapes must tile exactly (the ops.py wrapper pads).
    """
    M, K = x.shape
    K2, N = q.shape
    assert K == K2 and scale.shape == (K // group, N), (x.shape, q.shape,
                                                        scale.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % group == 0, (bk, group)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, group=group, out_dtype=x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale)
