"""Jit'd public wrappers around the Pallas kernels.

Shape plumbing lives here: flattening batch dims, padding to tile
multiples, head/batch reshapes for attention, and the interpret-mode
fallback so the kernels run (slowly, but bit-faithfully) on CPU for
tests.  ``repro.core.compressed.matmul`` and the model layers call these
when ``use_kernels(True)`` is active.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse import block_sparse_matmul_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.quant_matmul import quant_matmul_kernel


@functools.lru_cache(None)
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x2, bm):
    M = x2.shape[0]
    pad = (-M) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, M


def quant_matmul(x, q, scale, *, group: int, in_scale=None,
                 interpret=None):
    """x [..., K] @ dequant(q, scale) with int8 codes kept in HBM."""
    if interpret is None:
        interpret = _interpret_default()
    K, N = q.shape
    if in_scale is not None:
        x = (x.astype(jnp.float32) * in_scale).astype(x.dtype)
    x2 = x.reshape(-1, K)
    bm = 128 if x2.shape[0] >= 128 else 8
    x2, M = _pad_rows(x2, bm)
    bk = 512 if K % 512 == 0 else K
    while K % bk:
        bk //= 2
    bk = max(bk, group)
    y = quant_matmul_kernel(x2, q, scale, group=group, bm=bm, bk=bk,
                            bn=128 if N % 128 == 0 else N,
                            interpret=interpret)
    return y[:M].reshape(*x.shape[:-1], N)


def block_sparse_matmul(x, w, idx, *, bs: int, interpret=None):
    """x [..., K] @ block-sparse w, skipping pruned tiles via idx."""
    if interpret is None:
        interpret = _interpret_default()
    K, N = w.shape
    x2 = x.reshape(-1, K)
    bm = 128 if x2.shape[0] >= 128 else 8
    x2, M = _pad_rows(x2, bm)
    y = block_sparse_matmul_kernel(x2, w, idx, bs=bs, bm=bm,
                                   interpret=interpret)
    return y[:M].reshape(*x.shape[:-1], N)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0,
                    interpret=None):
    """q [B, S, H, D], k/v [B, T, Kh, D] -> [B, S, H, D] (GQA-aware)."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, H, D = q.shape
    _, T, Kh, _ = k.shape
    G = H // Kh
    # flatten heads into batch: [B*H, S, D] / [B*Kh, T, D]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, T, D)
    bq = 256 if S % 256 == 0 else _largest_tile(S)
    bkv = 256 if T % 256 == 0 else _largest_tile(T)
    t_real = T
    pad_t = (-T) % bkv
    if pad_t:
        kf = jnp.pad(kf, ((0, 0), (0, pad_t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_t), (0, 0)))
    o = flash_attention_kernel(qf, kf, vf, group=G, causal=causal,
                               window=window, softcap=softcap,
                               t_real=t_real, q_offset=q_offset,
                               bq=bq, bkv=bkv, interpret=interpret)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _largest_tile(n: int, cap: int = 256) -> int:
    t = 1
    for c in (8, 16, 32, 64, 128, 256):
        if c <= cap and n % c == 0:
            t = c
    return t
