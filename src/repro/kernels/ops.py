"""Jit'd public wrappers around the Pallas kernels.

Shape plumbing lives here: flattening batch dims, padding to tile
multiples, head/batch reshapes for attention, and the interpret-mode
fallback so the kernels run (slowly, but bit-faithfully) on CPU for
tests.  ``repro.core.compressed.matmul`` and the model layers call these
when the active KernelBackend resolves to ``"pallas"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse import block_sparse_matmul_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.paged_attention import paged_attention_kernel
from repro.kernels.quant_matmul import quant_matmul_kernel


def _interpret_default(*arrays) -> bool:
    """Interpret-mode default, resolved per call from the inputs' actual
    devices — never cached: tests (and multi-backend processes) change the
    effective platform after import, and under ``jit`` the inputs are
    tracers so the live default backend is the right answer."""
    for a in arrays:
        try:
            devs = a.devices()
        except Exception:            # tracers / abstract values
            continue
        return not any(d.platform == "tpu" for d in devs)
    return jax.default_backend() != "tpu"


def _pad_rows(x2, bm):
    M = x2.shape[0]
    pad = (-M) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, M


def quant_matmul(x, q, scale, *, group: int, in_scale=None,
                 interpret=None):
    """x [..., K] @ dequant(q, scale) with int8 codes kept in HBM.

    When the device resolution says off-TPU (``interpret=None`` and no
    TPU input), this computes the reference dequantize-then-einsum
    formula verbatim instead of emulating the tiled kernel: the tiling
    changes f32 accumulation order, and the ``"pallas"`` backend must
    be BYTE-identical to ``"reference"`` on CPU (the serving identity
    gate in tests/test_paged_cache.py and ``benchmarks/roofline.py
    --smoke``).  Pass ``interpret=True`` explicitly to run the real
    kernel under the Pallas interpreter (tests/test_kernels.py)."""
    K, N = q.shape
    if interpret is None:
        if _interpret_default(x, q):
            from repro.core.compressed import QTensor, _q_matmul_jnp
            return _q_matmul_jnp(x, QTensor(q, scale, 8, group, (K, N),
                                            in_scale))
        interpret = False
    if in_scale is not None:
        x = (x.astype(jnp.float32) * in_scale).astype(x.dtype)
    x2 = x.reshape(-1, K)
    bm = 128 if x2.shape[0] >= 128 else 8
    x2, M = _pad_rows(x2, bm)
    bk = 512 if K % 512 == 0 else K
    while K % bk:
        bk //= 2
    bk = max(bk, group)
    y = quant_matmul_kernel(x2, q, scale, group=group, bm=bm, bk=bk,
                            bn=128 if N % 128 == 0 else N,
                            interpret=interpret)
    return y[:M].reshape(*x.shape[:-1], N)


def block_sparse_matmul(x, w, idx, *, bs: int, interpret=None):
    """x [..., K] @ block-sparse w, skipping pruned tiles via idx.

    Off-TPU (``interpret=None`` resolution) this is the reference dense
    einsum over the zero-filled ``w`` (same byte-identity contract as
    ``quant_matmul``); ``interpret=True`` runs the gather kernel under
    the interpreter."""
    if interpret is None:
        if _interpret_default(x, w):
            return jnp.einsum("...i,io->...o", x, w.astype(x.dtype),
                              preferred_element_type=jnp.float32
                              ).astype(x.dtype)
        interpret = False
    K, N = w.shape
    x2 = x.reshape(-1, K)
    bm = 128 if x2.shape[0] >= 128 else 8
    x2, M = _pad_rows(x2, bm)
    y = block_sparse_matmul_kernel(x2, w, idx, bs=bs, bm=bm,
                                   interpret=interpret)
    return y[:M].reshape(*x.shape[:-1], N)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0,
                    interpret=None):
    """q [B, S, H, D], k/v [B, T, Kh, D] -> [B, S, H, D] (GQA-aware)."""
    if interpret is None:
        interpret = _interpret_default(q, k)
    B, S, H, D = q.shape
    _, T, Kh, _ = k.shape
    G = H // Kh
    # flatten heads into batch: [B*H, S, D] / [B*Kh, T, D]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, T, D)
    bq = 256 if S % 256 == 0 else _largest_tile(S)
    bkv = 256 if T % 256 == 0 else _largest_tile(T)
    t_real = T
    pad_t = (-T) % bkv
    if pad_t:
        kf = jnp.pad(kf, ((0, 0), (0, pad_t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_t), (0, 0)))
    o = flash_attention_kernel(qf, kf, vf, group=G, causal=causal,
                               window=window, softcap=softcap,
                               t_real=t_real, q_offset=q_offset,
                               bq=bq, bkv=bkv, interpret=interpret)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def paged_attention(q, k_pool, v_pool, tables, lengths, *,
                    softcap: float = 0.0, window: int = 0, interpret=None):
    """Paged-KV decode attention.

    q [S, 1, H, D] (one decode token per slot), k/v pools
    [num_blocks, block_size, Kh, D], tables [S, T // block_size] int32
    block ids per slot, lengths [S] int32 valid KV lengths
    -> [S, 1, H, D].
    """
    if interpret is None:
        interpret = _interpret_default(q, k_pool)
    S, one, H, D = q.shape
    assert one == 1, q.shape
    _, _, Kh, _ = k_pool.shape
    G = H // Kh
    # heads split as (Kh, G) — the same ordering layers._masked_decode uses
    # when it reshapes [B, 1, H, D] -> [B, 1, K, H//K, D].
    qr = q[:, 0].reshape(S, Kh, G, D)
    o = paged_attention_kernel(qr, k_pool, v_pool,
                               jnp.asarray(tables, jnp.int32),
                               jnp.asarray(lengths, jnp.int32),
                               softcap=softcap, window=window,
                               interpret=interpret)
    return o.reshape(S, 1, H, D)


def _largest_tile(n: int, cap: int = 256) -> int:
    t = 1
    for c in (8, 16, 32, 64, 128, 256):
        if c <= cap and n % c == 0:
            t = c
    return t
