"""Recipe search: derive IOLM-DB-Perf and IOLM-DB-Acc variants per query.

The paper evaluates two instance-optimized variants per workload
(Table 1): *Perf* (highest throughput) and *Acc* (highest accuracy,
normalized against the uncompressed baseline = 1).  This module
reproduces that policy: enumerate a family-aware recipe grid, compress,
score each candidate by

  - accuracy  = agreement with the BASELINE model's outputs on held-out
    rows (exact-match of greedy decodes — the paper's normalization)
  - cost      = measured rows/s where runnable (small models), plus an
    analytic FLOPs+bytes proxy that scales to big models

and pick argmax-throughput subject to an accuracy floor (Perf) and
argmax-accuracy with bytes tie-break (Acc).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressed import param_bytes
from repro.core.pipeline import InstanceOptimizer, Recipe


# ---------------------------------------------------------------------------
# recipe space
# ---------------------------------------------------------------------------

def default_recipe_space(cfg, *, aggressive: bool = True) -> List[Recipe]:
    """Family-aware candidate grid, ordered roughly mild -> aggressive."""
    rs: List[Recipe] = [
        Recipe(name="w8-gptq", wbits=8, quant_method="gptq"),
        Recipe(name="w8-absmax", wbits=8, quant_method="absmax"),
        Recipe(name="w8-smooth", wbits=8, smooth_alpha=0.5),
        Recipe(name="w8-24", wbits=8, nm=(2, 4)),
        Recipe(name="w4-gptq", wbits=4, group=64),
    ]
    if aggressive:
        rs += [
            Recipe(name="w8-ffn75", wbits=8, ffn_keep_frac=0.75),
            Recipe(name="w8-24-ffn75", wbits=8, nm=(2, 4),
                   ffn_keep_frac=0.75),
            Recipe(name="w4-24", wbits=4, group=64, nm=(2, 4)),
        ]
        if cfg.family != "rwkv" and cfg.n_kv_heads >= 2:
            rs.append(Recipe(name="w8-kv50", wbits=8, kv_keep_frac=0.5))
        if cfg.family == "moe":
            keep = max(cfg.top_k, cfg.n_experts // 2)
            rs.append(Recipe(name="w8-expert50", wbits=8, experts_keep=keep))
            rs.append(Recipe(name="w8-expert25", wbits=8,
                             experts_keep=max(cfg.top_k, cfg.n_experts // 4)))
    return rs


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def greedy_decode(params, cfg, prompts: jnp.ndarray, max_new: int,
                  *, lengths=None) -> np.ndarray:
    """Greedy generation for a [B, S] right-padded prompt batch.

    ``lengths`` [B]: true prompt lengths (defaults to S).  First-token
    logits are gathered at each row's last REAL position and decode
    positions advance per row.
    """
    from repro.models import api
    B, S = prompts.shape
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    max_len = S + max_new
    logits, cache = api.prefill(params, cfg, {"tokens": prompts},
                                max_len=max_len, compact_local=False)
    last = jnp.take_along_axis(logits, (lengths - 1)[:, None, None],
                               axis=1)[:, 0]
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]

    step = jax.jit(lambda p, c, t, pos: api.decode_step(
        p, cfg, c, t, pos, max_len=max_len))
    for t in range(max_new - 1):
        lg, cache = step(params, cache, tok, lengths + t)
        tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    return np.asarray(jnp.concatenate(outs, axis=1))


@dataclass
class EvalResult:
    accuracy: float          # exact-match agreement with baseline
    token_agreement: float   # per-token agreement (softer signal)
    rows_per_s: float
    bytes: int
    cost_proxy: float        # analytic decode cost (bytes/token moved)


def make_agreement_eval(base_params, base_cfg, prompts, *, max_new: int = 16,
                        lengths=None, timed: bool = True) -> Callable:
    """Returns eval_fn(params, cfg) scoring agreement vs the baseline."""
    ref = greedy_decode(base_params, base_cfg, prompts, max_new,
                        lengths=lengths)

    def eval_fn(params, cfg) -> EvalResult:
        t0 = time.time()
        out = greedy_decode(params, cfg, prompts, max_new, lengths=lengths)
        dt = time.time() - t0
        exact = float(np.mean(np.all(out == ref, axis=1)))
        tok = float(np.mean(out == ref))
        nbytes = param_bytes(params)
        return EvalResult(accuracy=exact, token_agreement=tok,
                          rows_per_s=prompts.shape[0] / max(dt, 1e-9),
                          bytes=nbytes,
                          cost_proxy=float(nbytes))
    return eval_fn


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

@dataclass
class Candidate:
    recipe: Recipe
    result: EvalResult
    report: Any
    params: Any = None
    cfg: Any = None


@dataclass
class SearchOutcome:
    baseline: EvalResult
    candidates: List[Candidate]
    perf: Optional[Candidate]
    acc: Optional[Candidate]

    def table(self) -> str:
        rows = [f"{'recipe':24s} {'acc':>5s} {'tok':>5s} {'rows/s':>8s} "
                f"{'MB':>8s}"]
        rows.append(f"{'baseline':24s} {self.baseline.accuracy:5.2f} "
                    f"{self.baseline.token_agreement:5.2f} "
                    f"{self.baseline.rows_per_s:8.2f} "
                    f"{self.baseline.bytes / 1e6:8.1f}")
        for c in self.candidates:
            tag = ""
            if self.perf is c:
                tag += " <- Perf"
            if self.acc is c:
                tag += " <- Acc"
            rows.append(f"{c.recipe.name:24s} {c.result.accuracy:5.2f} "
                        f"{c.result.token_agreement:5.2f} "
                        f"{c.result.rows_per_s:8.2f} "
                        f"{c.result.bytes / 1e6:8.1f}{tag}")
        return "\n".join(rows)


def search(optimizer: InstanceOptimizer, eval_fn: Callable,
           recipes: List[Recipe], *, acc_floor: float = 0.9,
           keep_params: bool = False) -> SearchOutcome:
    """Compress with every recipe, evaluate, select Perf/Acc variants."""
    baseline = eval_fn(optimizer.params, optimizer.cfg)
    cands: List[Candidate] = []
    for r in recipes:
        try:
            params2, cfg2, report = optimizer.apply(r)
            res = eval_fn(params2, cfg2)
        except Exception as e:  # a recipe inapplicable to this family
            continue
        cands.append(Candidate(recipe=r, result=res, report=report,
                               params=params2 if keep_params else None,
                               cfg=cfg2))
    perf = acc = None
    ok = [c for c in cands if c.result.accuracy >= acc_floor]
    pool = ok or cands
    if pool:
        perf = max(pool, key=lambda c: (c.result.rows_per_s,
                                        -c.result.bytes))
        acc = max(cands, key=lambda c: (c.result.accuracy,
                                        c.result.token_agreement,
                                        -c.result.bytes))
    return SearchOutcome(baseline=baseline, candidates=cands, perf=perf,
                         acc=acc)
