"""Compressed-parameter containers and the universal matmul dispatch.

The IOLM-DB pipeline rewrites selected weight matrices of a model's param
pytree into ``QTensor`` (quantized, optionally group-wise, optionally with
SmoothQuant input scales) or ``BlockSparseTensor`` (TPU block-sparse, the
hardware adaptation of the paper's 2:4 sparsity).
Every linear layer in ``repro.models`` calls :func:`matmul`, which
dispatches on the container type, so compression is transparent to all
architecture families.

The jnp paths here are the portable fallback (and the oracle for the
Pallas kernels in ``repro.kernels``); the fused kernels take over when
the active :mod:`repro.kernels.backend` resolves to ``"pallas"`` —
scoped per call site via :func:`kernel_backend`, threaded explicitly
from ``IOLMSession(backend=…)`` down through pool and engine rather
than flipped through a process-wide flag.

Calibration: ``set_record_hook`` installs an eager-mode observer that the
matmul dispatch (and the MoE block) feeds with (weight, activation)
pairs; ``repro.core.calibrate`` uses it to gather Hessians / channel
norms / routing statistics without any model-code changes.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import normalize_backend, resolve_backend

# Process default (mutated only by the deprecated use_kernels() shim) and
# the scoped override.  A ContextVar — not a module global — so engines
# running under the fan-out scheduler, threads, or nested traces each see
# their own backend.
_BACKEND_DEFAULT = "auto"
_BACKEND: contextvars.ContextVar = contextvars.ContextVar(
    "kernel_backend", default=None)

_RECORD_HOOK: Optional[Callable] = None
_ROUTE_HOOK: Optional[Callable] = None


@contextlib.contextmanager
def kernel_backend(backend):
    """Scope a KernelBackend over a block of (trace-time) compute.

    Engines wrap their jit call sites in this, so the dispatch below picks
    the engine's backend while tracing — no global state survives the
    ``with`` block.
    """
    token = _BACKEND.set(normalize_backend(backend))
    try:
        yield
    finally:
        _BACKEND.reset(token)


def current_backend() -> str:
    """The resolved backend in effect: ``"reference"`` or ``"pallas"``."""
    b = _BACKEND.get()
    return resolve_backend(b if b is not None else _BACKEND_DEFAULT)


def use_kernels(flag: bool) -> None:
    """Deprecated: set the process-default backend.

    Use ``IOLMSession(backend=…)`` / ``Engine(backend=…)`` or the scoped
    :func:`kernel_backend` context manager instead.
    """
    warnings.warn(
        "use_kernels() is deprecated; pass backend='pallas'/'reference' to "
        "IOLMSession/Engine or use repro.core.compressed.kernel_backend()",
        DeprecationWarning, stacklevel=2)
    global _BACKEND_DEFAULT
    _BACKEND_DEFAULT = "pallas" if flag else "reference"


def kernels_enabled() -> bool:
    """Deprecated: query whether the current backend resolves to pallas."""
    warnings.warn(
        "kernels_enabled() is deprecated; use "
        "repro.core.compressed.current_backend() == 'pallas'",
        DeprecationWarning, stacklevel=2)
    return current_backend() == "pallas"


def set_record_hook(fn: Optional[Callable]) -> None:
    """fn(w, x) observes eager matmuls; x is [..., d_in] (or [E, C, d_in]
    together with a per-expert valid-count for stacked expert weights)."""
    global _RECORD_HOOK
    _RECORD_HOOK = fn


def set_route_hook(fn: Optional[Callable]) -> None:
    """fn(router_w, counts, probs_mean) observes MoE routing statistics."""
    global _ROUTE_HOOK
    _ROUTE_HOOK = fn


def record(w, x, valid=None) -> None:
    """Explicit calibration record (used by MoE expert einsums)."""
    if _RECORD_HOOK is not None and not isinstance(x, jax.core.Tracer):
        _RECORD_HOOK(w, x, valid)


def record_routing(router_w, counts, probs_mean) -> None:
    if _ROUTE_HOOK is not None and not isinstance(counts, jax.core.Tracer):
        _ROUTE_HOOK(router_w, counts, probs_mean)


@jax.tree_util.register_pytree_node_class
class QTensor:
    """Group-wise quantized weight matrix ``[d_in, d_out]``.

    q         int8 codes ``[d_in, d_out]`` (int4: packed two-per-byte along
              d_in -> ``[d_in // 2, d_out]`` uint8)
    scale     f32 per-(group, out-channel) scales ``[d_in // group, d_out]``
    in_scale  optional f32 ``[d_in]`` SmoothQuant per-channel input scale
              (x is multiplied by it before the quantized matmul; the
              inverse was folded into the stored codes at quantization)
    bits      4 or 8 (static)

    Children may carry an extra leading layer axis when stacked for
    ``lax.scan`` — methods are only invoked on per-layer slices.
    """

    def __init__(self, q, scale, bits: int, group: int, shape, in_scale=None):
        self.q = q
        self.scale = scale
        self.in_scale = in_scale
        self.bits = int(bits)
        self.group = int(group)
        self.shape = tuple(shape)

    # --- pytree protocol ---
    def tree_flatten(self):
        return (self.q, self.scale, self.in_scale), (self.bits, self.group,
                                                     self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale, in_scale = children
        return cls(q, scale, aux[0], aux[1], aux[2], in_scale)

    @property
    def dtype(self):
        return jnp.bfloat16

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        """Stored bytes, computed from the actual children (stacked-safe)."""
        b = self.q.size * self.q.dtype.itemsize
        b += self.scale.size * self.scale.dtype.itemsize
        if self.in_scale is not None:
            b += self.in_scale.size * self.in_scale.dtype.itemsize
        return int(b)

    def unpack(self) -> jax.Array:
        """int8 logical codes [d_in, d_out] (unpacks int4)."""
        if self.bits == 8:
            return self.q
        u = self.q  # uint8 [d_in//2, d_out]
        lo = (u & 0xF).astype(jnp.int8)
        hi = (u >> 4).astype(jnp.int8)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        d_in = self.shape[-2]
        out = jnp.zeros((d_in, self.shape[-1]), jnp.int8)
        out = out.at[0::2].set(lo).at[1::2].set(hi)
        return out

    def dequantize(self) -> jax.Array:
        """Dense bf16 reconstruction (folds in_scale back into the weight)."""
        w = self.unpack().astype(jnp.float32)
        g = self.group
        d_in, d_out = self.shape[-2], self.shape[-1]
        w = w.reshape(d_in // g, g, d_out) * self.scale[:, None, :]
        w = w.reshape(d_in, d_out)
        if self.in_scale is not None:
            w = w * self.in_scale[:, None]
        return w.astype(jnp.bfloat16)


def pack_int4(codes: jax.Array) -> jax.Array:
    """int8 codes in [-8, 7], even first dim -> packed uint8 pairs."""
    lo = codes[0::2].astype(jnp.uint8) & 0xF
    hi = codes[1::2].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


@jax.tree_util.register_pytree_node_class
class QEmbed:
    """Int8 embedding table with per-row (per-vocab-entry) scales.

    Supports the two operations embeddings need: row gather (lookup) and
    tied-unembedding logits  x @ W^T = (x @ q^T) * s  — the per-row scale
    factors out of the reduction, so the matmul runs on int8 codes.
    """

    def __init__(self, q, scale):
        self.q = q            # int8 [V, d]
        self.scale = scale    # f32 [V]

    def tree_flatten(self):
        return (self.q, self.scale), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        return jnp.bfloat16

    @property
    def nbytes(self) -> int:
        return int(self.q.size + self.scale.size * 4)

    def lookup(self, tokens):
        return (self.q[tokens].astype(jnp.float32)
                * self.scale[tokens][..., None]).astype(jnp.bfloat16)

    def logits(self, x):
        y = jnp.einsum("...d,vd->...v", x.astype(jnp.bfloat16),
                       self.q.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        return y * self.scale


def quantize_embed(table, bits: int = 8) -> QEmbed:
    """Per-row absmax int8 quantization of an embedding table."""
    assert bits == 8, "embedding tables are int8 only"
    w = np.asarray(jax.device_get(table), np.float32)
    s = np.abs(w).max(1) / 127.0 + 1e-12
    q = np.clip(np.rint(w / s[:, None]), -127, 127).astype(np.int8)
    return QEmbed(jnp.asarray(q), jnp.asarray(s.astype(np.float32)))


@jax.tree_util.register_pytree_node_class
class BlockSparseTensor:
    """Block-sparse weight ``[d_in, d_out]`` with ``bs x bs`` zero blocks.

    TPU adaptation of the paper's 2:4 sparsity: whole 128-aligned blocks
    are pruned so the MXU can skip them (gather-based Pallas kernel);
    storage keeps only nonzero blocks + a bitmap.  ``w`` here is the
    dense zero-filled array (portable fallback / oracle); ``mask`` is the
    static block bitmap [d_in/bs, d_out/bs] (f32 0/1 so it scans cleanly);
    ``idx`` [d_out/bs, keep] int32 lists the kept input-block rows per
    output block column (uniform ``keep`` — the Pallas kernel's static
    gather length).
    """

    def __init__(self, w, mask, bs: int, idx=None):
        self.w = w
        self.mask = mask
        self.bs = int(bs)
        self.idx = idx

    def tree_flatten(self):
        return (self.w, self.mask, self.idx), (self.bs,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], children[2])

    @property
    def shape(self):
        return self.w.shape

    @property
    def ndim(self) -> int:
        return self.w.ndim

    @property
    def dtype(self):
        return self.w.dtype

    @property
    def nbytes(self) -> int:
        nnz = float(jax.device_get(self.mask.sum()))
        return int(nnz * self.bs * self.bs * self.w.dtype.itemsize
                   + self.mask.size / 8 + 1)

    def density(self) -> float:
        return float(jax.device_get(self.mask.mean()))


def param_bytes(tree) -> int:
    """Total stored bytes of a (possibly compressed) param pytree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, (QTensor, BlockSparseTensor))):
        if isinstance(leaf, (QTensor, BlockSparseTensor)):
            total += leaf.nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


def _q_matmul_jnp(x: jax.Array, w: QTensor) -> jax.Array:
    """Dequantize-then-dot: the same schedule the Pallas kernel uses
    (int8 codes scaled to bf16 right before the MXU contraction); XLA
    fuses the dequant into the matmul so codes stream from HBM as int8."""
    if w.in_scale is not None:
        x = (x.astype(jnp.float32) * w.in_scale).astype(x.dtype)
    y = jnp.einsum("...i,io->...o", x, w.dequantize(),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def matmul(x: jax.Array, w) -> jax.Array:
    """Universal ``x @ w`` over raw / quantized / block-sparse weights."""
    if isinstance(w, QTensor):
        if w.bits == 8 and current_backend() == "pallas":
            from repro.kernels import ops as kops
            return kops.quant_matmul(x, w.q, w.scale, group=w.group,
                                     in_scale=w.in_scale)
        return _q_matmul_jnp(x, w)
    if isinstance(w, BlockSparseTensor):
        if w.idx is not None and current_backend() == "pallas":
            from repro.kernels import ops as kops
            return kops.block_sparse_matmul(x, w.w, w.idx, bs=w.bs)
        return jnp.einsum("...i,io->...o", x, w.w.astype(x.dtype),
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if _RECORD_HOOK is not None and not isinstance(x, jax.core.Tracer):
        _RECORD_HOOK(w, x, None)
    return jnp.einsum("...i,io->...o", x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def expert_matmul(x: jax.Array, w) -> jax.Array:
    """Batched per-expert matmul ``[E, C, d_in] @ [E, d_in, d_out]`` over
    raw or quantized expert stacks (MoE layers call this)."""
    if isinstance(w, QTensor):
        def one(xe, qe, se, ise):
            wq = QTensor(qe, se, w.bits, w.group, w.shape[-2:], ise)
            return matmul(xe, wq)
        if w.in_scale is None:
            return jax.vmap(lambda xe, qe, se: one(xe, qe, se, None))(
                x, w.q, w.scale)
        return jax.vmap(one)(x, w.q, w.scale, w.in_scale)
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def is_weight_leaf(x) -> bool:
    return isinstance(x, (QTensor, BlockSparseTensor)) or hasattr(x, "shape")
