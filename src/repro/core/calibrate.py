"""Calibration: per-weight activation statistics from a data sample.

The paper (§3.2) fine-tunes quantization parameters and pruning
thresholds on *calibration data* — "small, unlabeled samples representing
the query's input domain".  This module runs the model **eagerly** (no
jit) layer-by-layer on such a sample and collects, per weight matrix:

  - ``H``       Gram matrix  X^T X  of the layer's inputs  (GPTQ [21] /
                SparseGPT [11] need the full input Hessian proxy)
  - ``sqnorm``  per-input-channel  sum x^2   (Wanda pruning metric)
  - ``amax``    per-input-channel  max |x|   (SmoothQuant [22] scales)
  - ``count``   number of observed rows
  - ``route_count`` (MoE routers) per-expert dispatch counts — the
                signal for *instance-optimized expert pruning*

plus per-block input/output cosine similarity (layer-drop scores: a block
whose output ≈ input is structurally redundant **for this query's data**,
which is exactly the instance-optimization the paper argues for).

Weights are keyed by their path in the param pytree (e.g.
``blocks.0.3.attn.wq``); the interception happens inside
``repro.core.compressed.matmul`` via ``set_record_hook`` so NO model code
needs to know about calibration.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressed


@dataclasses.dataclass
class WeightStats:
    shape: Tuple[int, ...]
    count: int = 0
    H: Optional[np.ndarray] = None        # [d_in, d_in] (or [E, d_in, d_in])
    sqnorm: Optional[np.ndarray] = None   # [d_in] (or [E, d_in])
    amax: Optional[np.ndarray] = None     # [d_in] (or [E, d_in])
    count_e: Optional[np.ndarray] = None  # stacked experts: per-expert rows [E]
    route_count: Optional[np.ndarray] = None  # routers only: [E]
    route_prob: Optional[np.ndarray] = None   # routers only: [E]

    def merge_norm(self):
        """Per-channel RMS norm of inputs (Wanda metric).

        Stacked-expert stats (``sqnorm`` is [E, d]) normalize each
        expert by ITS row count: dividing by the global ``count`` (the
        sum over experts) deflated every expert's norm by its routing
        share, biasing the Wanda metric toward heavily-routed experts.
        """
        if self.sqnorm is not None and self.sqnorm.ndim == 2 \
                and self.count_e is not None:
            denom = np.maximum(self.count_e, 1).astype(np.float64)
            return np.sqrt(self.sqnorm / denom[:, None])
        return np.sqrt(self.sqnorm / max(self.count, 1))


@dataclasses.dataclass
class CalibStats:
    weights: Dict[str, WeightStats]
    block_sim: Dict[str, float]      # path -> cos(x_in, x_out)
    n_tokens: int = 0

    def get(self, path: str) -> Optional[WeightStats]:
        return self.weights.get(path)


class Recorder:
    """Accumulates statistics for weights registered under a path scope."""

    def __init__(self, hessian: bool = True):
        self.hessian = hessian
        self.stats: Dict[str, WeightStats] = {}
        self.block_sim: Dict[str, float] = {}
        self._block_acc: Dict[str, List[float]] = {}   # path -> [sum, count]
        self._id2path: Dict[int, str] = {}
        self.n_tokens = 0

    # ---- scope management ----
    def register(self, prefix: str, tree) -> None:
        """Map every array leaf of ``tree`` to ``prefix.<path>``."""
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in flat:
            name = prefix + "." + _path_str(path) if prefix else _path_str(path)
            self._id2path[id(leaf)] = name

    @contextlib.contextmanager
    def active(self):
        compressed.set_record_hook(self._on_matmul)
        compressed.set_route_hook(self._on_route)
        try:
            yield self
        finally:
            compressed.set_record_hook(None)
            compressed.set_route_hook(None)

    # ---- hooks ----
    def _on_matmul(self, w, x, valid=None) -> None:
        path = self._id2path.get(id(w))
        if path is None or getattr(w, "ndim", 0) < 2:
            return
        st = self.stats.get(path)
        if st is None:
            st = WeightStats(shape=tuple(w.shape))
            self.stats[path] = st
        if w.ndim == 3 and valid is not None:
            # stacked expert weights: x is [E, C, d_in], valid [E] counts
            xe = np.asarray(x, np.float32)                  # [E, C, d]
            E, C, d = xe.shape
            mask = (np.arange(C)[None, :]
                    < np.asarray(valid)[:, None]).astype(np.float32)
            xm = xe * mask[..., None]
            if st.sqnorm is None:
                st.sqnorm = np.zeros((E, d), np.float32)
                st.amax = np.zeros((E, d), np.float32)
                if self.hessian:
                    st.H = np.zeros((E, d, d), np.float64)
            st.sqnorm += (xm ** 2).sum(1)
            st.amax = np.maximum(st.amax, np.abs(xm).max(1))
            if self.hessian:
                st.H += np.einsum("eci,ecj->eij", xm, xm, optimize=True)
            rows_e = np.asarray(valid, np.int64)        # per-expert rows [E]
            if st.count_e is None:
                st.count_e = np.zeros((E,), np.int64)
            st.count_e += rows_e
            st.count += int(rows_e.sum())
            return
        xf = np.asarray(x, np.float32).reshape(-1, x.shape[-1])  # [N, d_in]
        d = xf.shape[1]
        if st.sqnorm is None:
            st.sqnorm = np.zeros((d,), np.float32)
            st.amax = np.zeros((d,), np.float32)
            if self.hessian:
                st.H = np.zeros((d, d), np.float64)
        st.sqnorm += (xf ** 2).sum(0)
        st.amax = np.maximum(st.amax, np.abs(xf).max(0))
        if self.hessian:
            st.H += xf.T.astype(np.float64) @ xf.astype(np.float64)
        st.count += xf.shape[0]

    def _on_route(self, router_w, counts, probs_mean) -> None:
        path = self._id2path.get(id(router_w))
        if path is None:
            return
        st = self.stats.get(path)
        if st is None:
            st = WeightStats(shape=tuple(router_w.shape))
            self.stats[path] = st
        c = np.asarray(counts, np.float64)
        p = np.asarray(probs_mean, np.float64)
        st.route_count = c if st.route_count is None else st.route_count + c
        st.route_prob = p if st.route_prob is None else st.route_prob + p

    def record_block(self, path: str, x_in, x_out) -> None:
        a = np.asarray(x_in, np.float32).reshape(-1)
        b = np.asarray(x_out, np.float32).reshape(-1)
        cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9))
        # blocks visited multiple times (hybrid shared block) accumulate
        # sum+count here; finish() divides ONCE.  A running pairwise
        # average (0.5*(old+new)) weights visit k by 2^-(n-k) — the last
        # visit dominates exponentially instead of counting 1/n.
        acc = self._block_acc.setdefault(path, [0.0, 0])
        acc[0] += cos
        acc[1] += 1

    def finish(self) -> CalibStats:
        self.block_sim = {p: s / n for p, (s, n) in self._block_acc.items()}
        return CalibStats(weights=self.stats, block_sim=self.block_sim,
                          n_tokens=self.n_tokens)


@dataclasses.dataclass(frozen=True)
class CascadeCalibration:
    """Fitted acceptance rule for a proxy→base model cascade.

    ``threshold`` is the smallest confidence at which proxy answers are
    accepted; rows with ``confidence < threshold`` escalate to the base
    model.  ``expected_escalation`` is the escalation rate the fit
    predicts on its own sample — the number the physical planner's cost
    inequality and ``EXPLAIN`` report."""
    threshold: float
    expected_escalation: float
    accuracy_budget: float
    n_fit: int

    # warm-restart serialization (service/checkpoint.py).  ``inf``
    # thresholds survive the trip: json emits the literal Infinity,
    # which Python's json reader parses back to float('inf').
    def to_dict(self) -> dict:
        return {"threshold": self.threshold,
                "expected_escalation": self.expected_escalation,
                "accuracy_budget": self.accuracy_budget,
                "n_fit": self.n_fit}

    @staticmethod
    def from_dict(d: dict) -> "CascadeCalibration":
        return CascadeCalibration(
            threshold=float(d["threshold"]),
            expected_escalation=float(d["expected_escalation"]),
            accuracy_budget=float(d["accuracy_budget"]),
            n_fit=int(d["n_fit"]))


def fit_confidence_threshold(confidences, agreements,
                             accuracy_budget: float) -> CascadeCalibration:
    """Fit the cascade acceptance threshold on a held-out probe.

    ``confidences[i]`` is the proxy's confidence on holdout row i and
    ``agreements[i]`` whether the proxy's answer matched the base
    model's.  The fit picks the SMALLEST threshold (most rows accepted,
    fewest escalations) such that accepted-but-wrong rows stay within
    the per-op accuracy budget, measured against the WHOLE sample:

        |{i : conf_i >= thr  and  not agree_i}| / n  <=  accuracy_budget

    Lowering the threshold only grows the accepted set, so the
    constraint is monotone and the scan below finds the optimum.  A
    budget of 0 (or none satisfiable) returns ``threshold = inf``:
    every row escalates and the cascade degenerates to base-only —
    the exactness contract (tests/test_cascade.py).  Deterministic:
    the result is a pure function of the (sorted) sample.
    """
    conf = np.asarray(confidences, np.float64)
    agree = np.asarray(agreements, bool)
    n = conf.size
    if accuracy_budget is None or accuracy_budget <= 0.0 or n == 0:
        return CascadeCalibration(threshold=float("inf"),
                                  expected_escalation=1.0,
                                  accuracy_budget=float(accuracy_budget or 0.0),
                                  n_fit=int(n))
    best = float("inf")
    for thr in np.unique(conf):          # ascending: first hit is smallest
        wrong = int(np.sum((conf >= thr) & ~agree))
        if wrong <= accuracy_budget * n:
            best = float(thr)
            break
    esc = float(np.mean(conf < best)) if np.isfinite(best) else 1.0
    return CascadeCalibration(threshold=best, expected_escalation=esc,
                              accuracy_budget=float(accuracy_budget),
                              n_fit=int(n))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def slice_layer(tree, i: int):
    """Concrete per-layer slice of stacked params (holds references so the
    recorder's id-keying stays valid for the duration of the block run)."""
    return jax.tree.map(lambda a: a[i], tree)


# ---------------------------------------------------------------------------
# family drivers — mirror the forward() execution order exactly
# ---------------------------------------------------------------------------

def calibrate(params, cfg, batch: Dict[str, Any], *, hessian: bool = True,
              include_head: bool = True) -> CalibStats:
    """Run the model eagerly on ``batch`` and gather calibration stats."""
    rec = Recorder(hessian=hessian)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        _calib_transformer(rec, params, cfg, batch, include_head)
    elif fam == "rwkv":
        _calib_rwkv(rec, params, cfg, batch, include_head)
    elif fam == "hybrid":
        _calib_hybrid(rec, params, cfg, batch, include_head)
    elif fam == "encdec":
        _calib_encdec(rec, params, cfg, batch, include_head)
    else:
        raise ValueError(fam)
    return rec.finish()


def _calib_transformer(rec, params, cfg, batch, include_head):
    from repro.models import layers as L
    from repro.models import transformer as TF
    tokens = batch["tokens"]
    x = L.embed(params, cfg, tokens)
    if cfg.family == "vlm" and batch.get("img_embs") is not None:
        x = jnp.concatenate([batch["img_embs"].astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    rec.n_tokens = B * S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    unit, R, tail = TF.pattern_unit(cfg)
    with rec.active():
        for r in range(R):
            for u, kind in enumerate(unit):
                bp = slice_layer(params["blocks"][u], r)
                path = f"blocks.{u}.{r}"
                rec.register(path, bp)
                x2, _ = TF.block_apply(bp, x, cfg, kind=kind,
                                       positions=positions, train=False)
                rec.record_block(path, x, x2)
                x = x2
        for i, bp in enumerate(params["tail"]):
            path = f"tail.{i}"
            rec.register(path, bp)
            x2, _ = TF.block_apply(bp, x, cfg, kind=unit[i % len(unit)],
                                   positions=positions, train=False)
            rec.record_block(path, x, x2)
            x = x2
        if include_head and not cfg.tie_embeddings:
            x = L.norm(x, params["ln_f"], cfg)
            rec.register("", {"unembed": params["unembed"]})
            L.matmul(x, params["unembed"])


def _calib_rwkv(rec, params, cfg, batch, include_head):
    from repro.models import layers as L
    from repro.models import rwkv as RW
    x = L.embed(params, cfg, batch["tokens"])
    B, S, _ = x.shape
    rec.n_tokens = B * S
    n = params["blocks"][0]["ln1"]["w"].shape[0]
    with rec.active():
        for r in range(n):
            bp = slice_layer(params["blocks"][0], r)
            path = f"blocks.0.{r}"
            rec.register(path, bp)
            x2, _ = RW.block_apply(bp, x, cfg)
            rec.record_block(path, x, x2)
            x = x2
        if include_head and not cfg.tie_embeddings:
            x = L.norm(x, params["ln_f"], cfg)
            rec.register("", {"unembed": params["unembed"]})
            L.matmul(x, params["unembed"])


def _calib_hybrid(rec, params, cfg, batch, include_head):
    from repro.models import hybrid as HY
    from repro.models import layers as L
    from repro.models import mamba as M
    from repro.models import transformer as TF
    x = L.embed(params, cfg, batch["tokens"])
    B, S, _ = x.shape
    rec.n_tokens = B * S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    G, K, tail, _ = HY.layout(cfg)
    shared = params["shared"]
    rec.register("shared", shared)
    with rec.active():
        for g in range(G):
            for k in range(K):
                bp = jax.tree.map(lambda a: a[g][k], params["mamba_groups"])
                path = f"mamba_groups.{g}.{k}"
                rec.register(path, bp)
                x2, _ = M.block_apply(bp, x, cfg)
                rec.record_block(path, x, x2)
                x = x2
            x2, _ = TF.block_apply(shared, x, cfg, kind="G",
                                   positions=positions, train=False)
            rec.record_block("shared", x, x2)
            x = x2
        for i in range(tail):
            bp = slice_layer(params["mamba_tail"], i)
            path = f"mamba_tail.{i}"
            rec.register(path, bp)
            x2, _ = M.block_apply(bp, x, cfg)
            rec.record_block(path, x, x2)
            x = x2
        if include_head and not cfg.tie_embeddings:
            x = L.norm(x, params["ln_f"], cfg)
            rec.register("", {"unembed": params["unembed"]})
            L.matmul(x, params["unembed"])


def _calib_encdec(rec, params, cfg, batch, include_head):
    from repro.models import encdec as ED
    from repro.models import layers as L
    from repro.models.layers import norm
    enc_inputs, tokens = batch["enc_inputs"], batch["tokens"]
    B = tokens.shape[0]
    rec.n_tokens = tokens.size
    with rec.active():
        x = enc_inputs + params["pos_enc"][None, :enc_inputs.shape[1]]
        for i, p in enumerate(params["enc_blocks"]):
            path = f"enc_blocks.{i}"
            rec.register(path, p)
            a, _, _ = ED._mha(p["attn"], norm(x, p["ln1"], cfg), cfg,
                              causal=False)
            x2 = x + a
            x2 = x2 + ED._gelu_mlp(p["mlp"], norm(x2, p["ln2"], cfg))
            rec.record_block(path, x, x2)
            x = x2
        enc_out = norm(x, params["ln_enc"], cfg)
        x = L.embed(params, cfg, tokens)
        x = x + params["pos_dec"][None, :tokens.shape[1]]
        for i, p in enumerate(params["dec_blocks"]):
            path = f"dec_blocks.{i}"
            rec.register(path, p)
            a, _, _ = ED._mha(p["attn"], norm(x, p["ln1"], cfg), cfg,
                              causal=True)
            x2 = x + a
            a, _, _ = ED._mha(p["xattn"], norm(x2, p["lnx"], cfg), cfg,
                              kv_x=enc_out, causal=False)
            x2 = x2 + a
            x2 = x2 + ED._gelu_mlp(p["mlp"], norm(x2, p["ln2"], cfg))
            rec.record_block(path, x, x2)
            x = x2
        if include_head and not cfg.tie_embeddings:
            x = norm(x, params["ln_f"], cfg)
            rec.register("", {"unembed": params["unembed"]})
            L.matmul(x, params["unembed"])
