"""IOLM-DB core: instance-optimized model generation (the paper's
contribution).  calibrate -> {prune, sparsify, quantize} -> policy."""
from repro.core.compressed import (BlockSparseTensor, QEmbed, QTensor,
                                   current_backend, kernel_backend, matmul,
                                   param_bytes, use_kernels)
from repro.core.pipeline import InstanceOptimizer, Recipe
