"""Weight quantization: absmax round-to-nearest and GPTQ, plus SmoothQuant.

All functions consume/produce numpy (compression is an offline, per-query
step in IOLM-DB — single-digit minutes in the paper, §5.2); the result is
packed into :class:`repro.core.compressed.QTensor` whose jnp/Pallas
matmul runs in the serving path.

GPTQ [Frantar et al. 21]: quantize weight columns (input dims) one at a
time in Cholesky order of the inverse input Hessian H = X^T X, pushing
the rounding error onto not-yet-quantized columns.  SmoothQuant [Xiao et
al. 22]: per-channel scale s_j = amax_x(j)^alpha / amax_w(j)^(1-alpha)
migrates activation outliers into weights before quantization; the
inverse scale is carried in ``QTensor.in_scale``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.compressed import QTensor, pack_int4


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1          # 127 for int8, 7 for int4


def _round_clip(w: np.ndarray, scale: np.ndarray, bits: int) -> np.ndarray:
    q = np.rint(w / np.maximum(scale, 1e-12))
    lo = -_qmax(bits) - 1
    return np.clip(q, lo, _qmax(bits))


def group_scales(w: np.ndarray, bits: int, group: int) -> np.ndarray:
    """absmax scale per (input group, output channel): [d_in/g, d_out]."""
    d_in, d_out = w.shape
    wg = w.reshape(d_in // group, group, d_out)
    return np.abs(wg).max(1) / _qmax(bits) + 1e-12


def choose_group(d_in: int, group: int) -> int:
    """Largest divisor of d_in that is <= requested group size."""
    g = min(group, d_in)
    while d_in % g:
        g -= 1
    return g


def smooth_scales(amax_x: np.ndarray, w: np.ndarray,
                  alpha: float = 0.5) -> np.ndarray:
    """SmoothQuant per-input-channel migration scale s (apply w*s, x/s)."""
    amax_w = np.abs(w).max(1) + 1e-9
    ax = np.maximum(amax_x, 1e-9)
    s = ax ** alpha / amax_w ** (1.0 - alpha)
    s = s / np.exp(np.mean(np.log(s)))     # normalize geometric mean to 1
    return np.clip(s, 1e-3, 1e3)


def _pack(codes: np.ndarray, scale: np.ndarray, bits: int, group: int,
          shape, in_scale: Optional[np.ndarray]) -> QTensor:
    if bits == 4:
        q = pack_int4(jnp.asarray(codes.astype(np.int8)))
    else:
        q = jnp.asarray(codes.astype(np.int8))
    return QTensor(q, jnp.asarray(scale.astype(np.float32)), bits, group,
                   tuple(shape),
                   None if in_scale is None else
                   jnp.asarray(in_scale.astype(np.float32)))


def absmax_quantize(w: np.ndarray, *, bits: int = 8, group: int = 128,
                    amax_x: Optional[np.ndarray] = None,
                    smooth_alpha: float = 0.0) -> QTensor:
    """Round-to-nearest group-wise quantization (the non-calibrated path)."""
    w = np.asarray(w, np.float32)
    in_scale = None
    if smooth_alpha and amax_x is not None:
        s = smooth_scales(amax_x, w, smooth_alpha)
        w = w * s[:, None]
        in_scale = 1.0 / s
    g = choose_group(w.shape[0], group)
    scale = group_scales(w, bits, g)
    codes = _round_clip(w.reshape(w.shape[0] // g, g, -1),
                        scale[:, None, :], bits).reshape(w.shape)
    return _pack(codes, scale, bits, g, w.shape, in_scale)


def gptq_quantize(w: np.ndarray, H: np.ndarray, *, bits: int = 8,
                  group: int = 128, percdamp: float = 0.01,
                  blocksize: int = 128,
                  amax_x: Optional[np.ndarray] = None,
                  smooth_alpha: float = 0.0,
                  mask: Optional[np.ndarray] = None) -> QTensor:
    """GPTQ quantization of ``w [d_in, d_out]`` with input Hessian ``H``.

    ``mask`` (optional, [d_in, d_out] bool, True = keep): a sparsity
    pattern to respect — masked-out entries are forced to code 0 and
    their error is propagated like any rounding error, which is exactly
    the SparseGPT + quantization composition the paper uses.
    """
    w = np.asarray(w, np.float64).copy()
    H = np.asarray(H, np.float64).copy()
    d_in, d_out = w.shape
    in_scale = None
    if smooth_alpha and amax_x is not None:
        s = smooth_scales(amax_x, w.astype(np.float32), smooth_alpha)
        w = w * s[:, None].astype(np.float64)
        H = H / s[:, None] / s[None, :]    # H of the scaled inputs x/s
        in_scale = 1.0 / s
    g = choose_group(d_in, group)

    dead = np.diag(H) <= 0
    H[dead, dead] = 1.0
    w[dead] = 0.0
    damp = percdamp * np.mean(np.diag(H))
    H[np.arange(d_in), np.arange(d_in)] += damp
    # Hinv via Cholesky: process columns in natural order (group-aligned)
    Hinv = np.linalg.inv(H)
    # upper Cholesky of Hinv, as in the reference implementation
    Lc = np.linalg.cholesky(Hinv)
    U = Lc.T.copy()                        # upper triangular

    codes = np.zeros_like(w)
    scales = np.zeros((d_in // g, d_out), np.float64)
    Q = np.zeros_like(w)

    for bs in range(0, d_in, blocksize):
        be = min(bs + blocksize, d_in)
        Werr = np.zeros((be - bs, d_out))
        for j in range(bs, be):
            if j % g == 0:
                # group scale from the *current* (error-compensated) block
                je = min(j + g, d_in)
                scales[j // g] = np.abs(w[j:je]).max(0) / _qmax(bits) + 1e-12
            sc = scales[j // g]
            q = _round_clip(w[j], sc, bits)
            if mask is not None:
                q = np.where(mask[j], q, 0.0)
            dq = q * sc
            codes[j] = q
            Q[j] = dq
            err = (w[j] - dq) / U[j, j]
            w[j + 1:be] -= np.outer(U[j, j + 1:be], err)
            Werr[j - bs] = err
        if be < d_in:
            w[be:] -= U[bs:be, be:].T @ Werr
    return _pack(codes, scales, bits, g, (d_in, d_out), in_scale)


def quant_error(w: np.ndarray, qt: QTensor,
                H: Optional[np.ndarray] = None) -> float:
    """||W - Ŵ||_F (or sqrt(tr(E^T H E)) — the proxy GPTQ minimizes)."""
    wq = np.asarray(qt.dequantize(), np.float32)
    e = np.asarray(w, np.float32) - wq
    if H is None:
        return float(np.linalg.norm(e))
    return float(np.sqrt(max(np.einsum("io,ij,jo->", e, H, e), 0.0)))
