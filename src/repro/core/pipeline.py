"""The IOLM-DB instance-optimization pipeline.

``InstanceOptimizer`` turns a (params, config) pair plus a calibration
sample into a compressed, query-specialized model:

    opt = InstanceOptimizer(params, cfg)
    opt.run_calibration(sample_batch)
    new_params, new_cfg, report = opt.apply(Recipe(...))

Stages (paper §3.2), in order:
  1. structural pruning  — layer drop, KV-group prune, FFN-channel prune,
     expert prune (MoE), all driven by calibration statistics
  2. sparsification      — SparseGPT / Wanda masks (N:M or unstructured),
     or TPU block sparsity (whole MXU tiles skipped by the Pallas kernel)
  3. quantization        — GPTQ / absmax int8 or int4, group-wise scales,
     optional SmoothQuant activation-outlier migration; masks from stage
     2 are respected inside the GPTQ sweep (the SparseGPT+GPTQ
     composition the paper cites)

The result's weight matrices are ``QTensor`` / ``BlockSparseTensor``
containers that every model family consumes transparently through
``repro.core.compressed.matmul``.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as C
from repro.core import prune as P
from repro.core import quantize as Q
from repro.core import sparsify as S
from repro.core.compressed import (BlockSparseTensor, QTensor, param_bytes,
                                   quantize_embed)


@dataclass(frozen=True)
class Recipe:
    """One point in the compression design space."""
    name: str = "recipe"
    # --- structural ---
    drop_units: int = 0                # scan repeats (pattern units) to drop
    kv_keep_frac: float = 1.0          # fraction of KV groups kept
    ffn_keep_frac: float = 1.0         # fraction of FFN hidden channels kept
    experts_keep: int = 0              # MoE: experts kept per layer (0 = all)
    # --- sparsity ---
    sparsity: float = 0.0              # unstructured fraction REMOVED
    nm: Tuple[int, int] = (0, 0)       # (n, m) structured: keep n of m
    sparse_method: str = "sparsegpt"   # sparsegpt | wanda
    block_bs: int = 0                  # TPU block-sparse tile (0 = off)
    block_density: float = 1.0         # fraction of tiles kept
    # --- quantization ---
    wbits: int = 16                    # 16 = none, 8, 4
    group: int = 128
    quant_method: str = "gptq"         # gptq | absmax
    smooth_alpha: float = 0.0          # SmoothQuant (0 = off)
    quant_embed: bool = False

    def describe(self) -> str:
        parts = []
        if self.drop_units:
            parts.append(f"drop{self.drop_units}u")
        if self.kv_keep_frac < 1:
            parts.append(f"kv{self.kv_keep_frac:.2f}")
        if self.ffn_keep_frac < 1:
            parts.append(f"ffn{self.ffn_keep_frac:.2f}")
        if self.experts_keep:
            parts.append(f"E{self.experts_keep}")
        if self.nm[1]:
            parts.append(f"{self.nm[0]}:{self.nm[1]}")
        elif self.sparsity:
            parts.append(f"sp{self.sparsity:.2f}")
        if self.block_bs:
            parts.append(f"bs{self.block_bs}@{self.block_density:.2f}")
        if self.wbits < 16:
            parts.append(f"w{self.wbits}g{self.group}:{self.quant_method}")
        if self.smooth_alpha:
            parts.append(f"sq{self.smooth_alpha}")
        return "+".join(parts) or "identity"


# weights eligible for quantization/sparsification, by leaf name
_COMPRESS_NAMES = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wg", "wr", "unembed",
    "in_proj", "out_proj",
})
_SKIP_SUBTREES = ("gn",)   # rwkv groupnorm has a "w" that is 1D anyway


def _leaf_name(path: str) -> str:
    return path.rsplit(".", 1)[-1]


def _is_target(path: str, leaf) -> bool:
    if isinstance(leaf, (QTensor, BlockSparseTensor)):
        return False
    name = _leaf_name(path)
    if name not in _COMPRESS_NAMES:
        return False
    return getattr(leaf, "ndim", 0) >= 2


def _stack_depth(cfg, path: str) -> int:
    """Leading stacked-layer axes of a param subtree (cf. calibrate paths)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "rwkv"):
        return 1 if path.startswith("blocks.") else 0
    if fam == "hybrid":
        if path.startswith("mamba_groups."):
            return 2
        if path.startswith("mamba_tail."):
            return 1
        return 0
    return 0   # encdec: unrolled lists, indices already in the tree path


def _stats_key(cfg, path: str, idx: Tuple[int, ...]) -> str:
    """Map a tree path + stack indices to the calibration stats key."""
    parts = path.split(".")
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "rwkv") and parts[0] == "blocks":
        return ".".join(parts[:2] + [str(idx[0])] + parts[2:])
    if fam == "hybrid" and parts[0] == "mamba_groups":
        return ".".join([parts[0], str(idx[0]), str(idx[1])] + parts[1:])
    if fam == "hybrid" and parts[0] == "mamba_tail":
        return ".".join([parts[0], str(idx[0])] + parts[1:])
    return path


@dataclass
class Report:
    recipe: Recipe
    bytes_before: int
    bytes_after: int
    params_before: int
    params_after: int
    seconds: float
    per_weight: List[Dict[str, Any]]
    cfg_before: Any = None
    cfg_after: Any = None

    @property
    def compression(self) -> float:
        return self.bytes_before / max(self.bytes_after, 1)

    def summary(self) -> str:
        return (f"[{self.recipe.name}] {self.recipe.describe()}: "
                f"{self.bytes_before / 1e6:.1f} MB -> "
                f"{self.bytes_after / 1e6:.1f} MB "
                f"({self.compression:.2f}x) in {self.seconds:.1f}s")


def _param_count(tree) -> int:
    n = 0
    for leaf in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, (QTensor, BlockSparseTensor))):
        if isinstance(leaf, QTensor):
            n += int(np.prod(leaf.q.shape)) * (2 if leaf.bits == 4 else 1)
        elif isinstance(leaf, BlockSparseTensor):
            n += int(leaf.w.size * leaf.density())
        else:
            n += leaf.size
    return n


class InstanceOptimizer:
    """Generates a query-specialized compressed model (the paper's core)."""

    def __init__(self, params, cfg):
        self.params = params
        self.cfg = cfg
        self.stats: Optional[C.CalibStats] = None

    # -- stage 0: calibration ------------------------------------------------
    def run_calibration(self, batch: Dict[str, Any], *, hessian: bool = True):
        self.stats = C.calibrate(self.params, self.cfg, batch, hessian=hessian)
        return self.stats

    # -- full pipeline -------------------------------------------------------
    def apply(self, recipe: Recipe):
        t0 = time.time()
        if self.stats is None:
            self.stats = C.CalibStats({}, {}, 0)
        params, cfg, stats = self.params, self.cfg, self.stats
        bytes_before = param_bytes(params)
        n_before = _param_count(params)

        # 1. structural
        if recipe.drop_units:
            params, cfg, stats = P.drop_layers(params, cfg, stats,
                                               recipe.drop_units)
        if recipe.kv_keep_frac < 1.0 and cfg.family != "rwkv":
            keep = max(1, int(round(recipe.kv_keep_frac * cfg.n_kv_heads)))
            params, cfg, stats = P.prune_kv_groups(params, cfg, stats, keep)
        if recipe.ffn_keep_frac < 1.0:
            params, cfg, stats = P.prune_ffn(params, cfg, stats,
                                             recipe.ffn_keep_frac)
        if recipe.experts_keep and cfg.family == "moe":
            params, cfg, stats = P.prune_experts(params, cfg, stats,
                                                 recipe.experts_keep)

        # 2+3. sparsify + quantize, per weight
        per_weight: List[Dict[str, Any]] = []
        if (recipe.wbits < 16 or recipe.sparsity or recipe.nm[1]
                or recipe.block_bs):
            params = self._compress_weights(params, cfg, stats, recipe,
                                            per_weight)
        if recipe.quant_embed:
            params = dict(params)
            params["embed"] = quantize_embed(params["embed"])

        report = Report(recipe=recipe, bytes_before=bytes_before,
                        bytes_after=param_bytes(params),
                        params_before=n_before,
                        params_after=_param_count(params),
                        seconds=time.time() - t0, per_weight=per_weight,
                        cfg_before=self.cfg, cfg_after=cfg)
        return params, cfg, report

    # -- weight-level compression ---------------------------------------------
    def _compress_weights(self, params, cfg, stats, recipe, per_weight):
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: isinstance(x, (QTensor,
                                                     BlockSparseTensor)))
        out_leaves = []
        for path_t, leaf in flat:
            path = C._path_str(path_t)
            if not _is_target(path, leaf):
                out_leaves.append(leaf)
                continue
            depth = _stack_depth(cfg, path)
            is_expert = ".moe." in f".{path}." and _leaf_name(path) in (
                "wi", "wg", "wo")
            out_leaves.append(self._compress_one(
                leaf, cfg, stats, recipe, path, depth, is_expert, per_weight))
        return jax.tree_util.tree_unflatten(treedef, out_leaves)

    def _compress_one(self, leaf, cfg, stats, recipe, path, depth,
                      is_expert, per_weight):
        """Compress one (possibly layer-stacked, possibly expert-stacked)
        weight; returns a stacked QTensor/BlockSparseTensor/array."""
        w_np = np.asarray(jax.device_get(leaf), np.float32)
        shape = w_np.shape
        # enumerate layer indices
        if depth == 0:
            idxs = [()]
        elif depth == 1:
            idxs = [(r,) for r in range(shape[0])]
        else:
            idxs = [(g, k) for g in range(shape[0]) for k in range(shape[1])]

        results = []
        for idx in idxs:
            w = w_np[idx] if idx else w_np
            st = stats.get(_stats_key(cfg, path, idx))
            if is_expert:
                sub = [self._one_matrix(w[e], recipe, _expert_stats(st, e),
                                        path, per_weight, log=e == 0
                                        and idx in ((), (0,), (0, 0)))
                       for e in range(w.shape[0])]
                results.append(_stack_q(sub))
            else:
                results.append(self._one_matrix(
                    w, recipe, st, path, per_weight,
                    log=idx in ((), (0,), (0, 0))))
        out = _stack_q(results) if depth else results[0]
        if depth == 2:
            # regroup flat (g*k) stacking into [G, K, ...]
            G, K = shape[0], shape[1]
            out = jax.tree.map(lambda a: a.reshape(G, K, *a.shape[1:]), out)
        return out

    def _one_matrix(self, w, recipe, st, path, per_weight, log=False):
        """Sparsify+quantize a single [d_in, d_out] matrix."""
        d_in, d_out = w.shape
        H = st.H if st is not None else None
        act_norm = (np.sqrt(st.sqnorm / max(st.count, 1))
                    if st is not None and st.sqnorm is not None
                    else np.ones(d_in, np.float32))
        amax = st.amax if st is not None and st.amax is not None else None
        mask = None
        entry = {"path": path, "shape": (d_in, d_out)}

        # --- TPU block sparsity: container-level, kernel skips tiles ---
        if recipe.block_bs and recipe.block_density < 1.0 \
                and d_in % recipe.block_bs == 0 and d_out % recipe.block_bs == 0:
            bmask = S.block_sparse_mask(w, bs=recipe.block_bs,
                                        density=recipe.block_density,
                                        act_norm=act_norm)
            if recipe.wbits >= 16:
                if log:
                    entry["kind"] = f"block_sparse@{recipe.block_density}"
                    per_weight.append(entry)
                return S.apply_block_mask(w, bmask, recipe.block_bs)
            # compose: zero the tiles, then quantize below
            big = np.kron(bmask.astype(np.float32),
                          np.ones((recipe.block_bs, recipe.block_bs),
                                  np.float32))
            mask = big > 0
            w = w * big

        # --- fine-grained sparsity (size reduction; composes with quant) ---
        n, m = recipe.nm
        if (m or recipe.sparsity) and mask is None:
            if recipe.sparse_method == "sparsegpt" and H is not None:
                w, mask = S.sparsegpt_prune(w, H, sparsity=recipe.sparsity,
                                            n=n, m=m)
            else:
                mask = S.wanda_mask(w, act_norm, sparsity=recipe.sparsity,
                                    n=n, m=m)
                w = np.where(mask, w, 0.0)

        # --- quantization ---
        if recipe.wbits < 16:
            alpha = recipe.smooth_alpha
            if recipe.quant_method == "gptq" and H is not None:
                qt = Q.gptq_quantize(w, H, bits=recipe.wbits,
                                     group=recipe.group, amax_x=amax,
                                     smooth_alpha=alpha, mask=mask)
            else:
                qt = Q.absmax_quantize(w, bits=recipe.wbits,
                                       group=recipe.group, amax_x=amax,
                                       smooth_alpha=alpha)
                if mask is not None:
                    codes = np.asarray(jax.device_get(qt.unpack()))
                    codes = np.where(mask, codes, 0).astype(np.int8)
                    from repro.core.compressed import pack_int4
                    q = (pack_int4(jnp.asarray(codes)) if recipe.wbits == 4
                         else jnp.asarray(codes))
                    qt = QTensor(q, qt.scale, qt.bits, qt.group, qt.shape,
                                 qt.in_scale)
            if log:
                entry["kind"] = f"quant w{recipe.wbits}"
                per_weight.append(entry)
            return qt
        if mask is not None:
            if log:
                entry["kind"] = "sparse (dense container)"
                per_weight.append(entry)
            return jnp.asarray(w.astype(np.float32), dtype=jnp.bfloat16)
        return jnp.asarray(w.astype(np.float32), dtype=jnp.bfloat16)


def _expert_stats(st, e):
    if st is None or st.sqnorm is None:
        return None
    # per-expert row count, NOT the global sum over experts: the Wanda
    # act_norm divides sqnorm[e] by this, and the global count deflates
    # lightly-routed experts' norms by their routing share
    count = int(st.count_e[e]) if st.count_e is not None else st.count
    return C.WeightStats(shape=tuple(st.shape[1:]), count=count,
                         H=None if st.H is None else st.H[e],
                         sqnorm=st.sqnorm[e], amax=st.amax[e])


def _stack_q(items):
    """Stack per-layer compression results along a new axis 0."""
    first = items[0]
    if isinstance(first, QTensor):
        q = jnp.stack([it.q for it in items])
        s = jnp.stack([it.scale for it in items])
        ins = (None if first.in_scale is None
               else jnp.stack([it.in_scale for it in items]))
        return QTensor(q, s, first.bits, first.group, first.shape[-2:], ins)
    if isinstance(first, BlockSparseTensor):
        return BlockSparseTensor(jnp.stack([it.w for it in items]),
                                 jnp.stack([it.mask for it in items]),
                                 first.bs)
    return jnp.stack(items)
