"""Structural pruning: KV-head groups, FFN channels, whole layers, experts.

LLM-Pruner-style [20] removal of entire components, driven by the
calibration statistics (so the *same data sample* that tunes quantization
also decides what structure this query does not need).

TPU-native design decision: pruned counts are **uniform
across layers** (every layer keeps the same number of KV groups / FFN
channels / experts, each layer choosing its own least-important members).
XLA requires static uniform shapes inside ``lax.scan`` stacks, and
uniform budgets keep one compiled kernel for all layers; the per-layer
*choice* is where the instance-optimization lives.  Layer dropping
operates at pattern-unit granularity for scanned stacks (per-layer for
unrolled stacks like whisper's).

Every transform returns ``(new_params, new_cfg, new_stats)`` — the stats
are re-sliced/re-keyed so downstream quantization/sparsification still
has correct Hessians for the reduced shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import CalibStats, WeightStats


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _take_stacked(stacked, idx: np.ndarray, axis: int):
    """stacked [R, ...]; idx [R, k] per-layer indices along ``axis``."""
    idxj = jnp.asarray(idx)
    return jax.vmap(lambda w, i: jnp.take(w, i, axis=axis))(stacked, idxj)


def _channel_importance(st: Optional[WeightStats], w_np: np.ndarray) -> np.ndarray:
    """Per-input-channel importance of a [d_in, d_out] weight: Wanda-style
    ||x||^2 * mean w^2 per row, falling back to weight norms alone."""
    row = (w_np.astype(np.float32) ** 2).mean(1)
    if st is not None and st.sqnorm is not None:
        return st.sqnorm / max(st.count, 1) * row
    return row


def _slice_stats(st: Optional[WeightStats], idx: np.ndarray) -> Optional[WeightStats]:
    """Restrict input-channel stats to ``idx`` (for downstream quant)."""
    if st is None:
        return None
    return WeightStats(
        shape=(len(idx),) + tuple(st.shape[1:]),
        count=st.count,
        H=None if st.H is None else st.H[np.ix_(idx, idx)],
        sqnorm=None if st.sqnorm is None else st.sqnorm[idx],
        amax=None if st.amax is None else st.amax[idx],
    )


def _np(x) -> np.ndarray:
    return np.asarray(jax.device_get(x), np.float32)


# ---------------------------------------------------------------------------
# KV-group (GQA head) pruning
# ---------------------------------------------------------------------------

def prune_kv_groups(params, cfg, stats: CalibStats, keep: int):
    """Keep the ``keep`` most important KV groups in every attention block.

    Inapplicable families (rwkv) are returned unchanged.
    """
    if cfg.family == "rwkv":
        return params, cfg, stats
    K, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    assert 1 <= keep <= K, (keep, K)
    if keep == K:
        return params, cfg, stats
    params = jax.tree.map(lambda a: a, params)  # shallow copy
    new_stats = dict(stats.weights)

    def group_imp(wo_st: Optional[WeightStats], wo_np: np.ndarray) -> np.ndarray:
        imp = _channel_importance(wo_st, wo_np)          # [H*hd]
        return imp.reshape(K, G * hd).sum(1)             # [K]

    def prune_one(attn, paths: List[str]) -> Dict:
        """attn leaves stacked [R, ...]; paths[r] = stats key prefix."""
        R = attn["wo"].shape[0] if attn["wq"].ndim == 3 else 1
        stacked = attn["wq"].ndim == 3
        idx = np.zeros((R, keep), np.int64)
        for r in range(R):
            wo_np = _np(attn["wo"][r] if stacked else attn["wo"])
            st = stats.get(paths[r] + ".wo")
            order = np.argsort(-group_imp(st, wo_np), kind="stable")[:keep]
            idx[r] = np.sort(order)
        if stacked:
            d = attn["wq"].shape[1]
            wq = _take_stacked(attn["wq"].reshape(R, d, K, G * hd), idx, 1)
            wq = wq.reshape(R, d, keep * G * hd)
            wk = _take_stacked(attn["wk"].reshape(R, d, K, hd), idx, 1)
            wk = wk.reshape(R, d, keep * hd)
            wv = _take_stacked(attn["wv"].reshape(R, d, K, hd), idx, 1)
            wv = wv.reshape(R, d, keep * hd)
            wo = _take_stacked(attn["wo"].reshape(R, K, G * hd, d), idx, 0)
            wo = wo.reshape(R, keep * G * hd, d)
        else:
            d = attn["wq"].shape[0]
            i0 = jnp.asarray(idx[0])
            wq = jnp.take(attn["wq"].reshape(d, K, G * hd), i0, 1).reshape(d, -1)
            wk = jnp.take(attn["wk"].reshape(d, K, hd), i0, 1).reshape(d, -1)
            wv = jnp.take(attn["wv"].reshape(d, K, hd), i0, 1).reshape(d, -1)
            wo = jnp.take(attn["wo"].reshape(K, G * hd, d), i0, 0).reshape(-1, d)
        # stats: wo input channels restricted to kept groups
        for r in range(R):
            ch = np.concatenate([idx[r, j] * G * hd + np.arange(G * hd)
                                 for j in range(keep)])
            key = paths[r] + ".wo"
            if key in new_stats:
                new_stats[key] = _slice_stats(new_stats[key], ch)
        return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models.transformer import pattern_unit
        unit, R, tail = pattern_unit(cfg)
        for u in range(len(unit)):
            paths = [f"blocks.{u}.{r}.attn" for r in range(R)]
            params["blocks"][u] = dict(params["blocks"][u])
            params["blocks"][u]["attn"] = prune_one(
                params["blocks"][u]["attn"], paths)
        for i in range(tail):
            params["tail"][i] = dict(params["tail"][i])
            params["tail"][i]["attn"] = prune_one(
                params["tail"][i]["attn"], [f"tail.{i}.attn"])
    elif fam == "hybrid":
        params["shared"] = dict(params["shared"])
        params["shared"]["attn"] = prune_one(params["shared"]["attn"],
                                             ["shared.attn"])
    elif fam == "encdec":
        for lst, nm in (("enc_blocks", "attn"), ("dec_blocks", "attn"),
                        ("dec_blocks", "xattn")):
            for i in range(len(params[lst])):
                params[lst][i] = dict(params[lst][i])
                params[lst][i][nm] = prune_one(params[lst][i][nm],
                                               [f"{lst}.{i}.{nm}"])
    # pin head_dim: n_heads changes would silently alter d_model//n_heads
    new_cfg = cfg.replace(n_kv_heads=keep, n_heads=keep * G,
                          head_dim=cfg.resolved_head_dim)
    return params, new_cfg, CalibStats(new_stats, stats.block_sim,
                                       stats.n_tokens)


# ---------------------------------------------------------------------------
# FFN channel pruning
# ---------------------------------------------------------------------------

def prune_ffn(params, cfg, stats: CalibStats, keep_frac: float):
    """Keep the top ``keep_frac`` FFN hidden channels (per layer choice).

    Covers dense MLPs (wi/wg/wo), MoE expert FFNs (per-expert channels),
    qwen's shared MLP, arctic's dense-residual MLP, rwkv channel-mix, and
    whisper GELU MLPs.  Mamba inner channels are left alone (the SSD
    state/headdim coupling makes channel removal a different operation).
    """
    if keep_frac >= 1.0:
        return params, cfg, stats
    params = jax.tree.map(lambda a: a, params)
    new_stats = dict(stats.weights)

    def prune_mlp(mlp: Dict, paths: List[str], gated: bool = True) -> Dict:
        stacked = mlp["wo"].ndim == 3
        R = mlp["wo"].shape[0] if stacked else 1
        ff = mlp["wo"].shape[-2]
        keep_ff = max(8, int(round(keep_frac * ff)) // 8 * 8)
        idx = np.zeros((R, keep_ff), np.int64)
        for r in range(R):
            wo_np = _np(mlp["wo"][r] if stacked else mlp["wo"])
            st = stats.get(paths[r] + ".wo")
            imp = _channel_importance(st, wo_np)
            idx[r] = np.sort(np.argsort(-imp, kind="stable")[:keep_ff])
        out = dict(mlp)
        if stacked:
            out["wo"] = _take_stacked(mlp["wo"], idx, 0)
            out["wi"] = _take_stacked(mlp["wi"], idx, 1)
            if gated and "wg" in mlp:
                out["wg"] = _take_stacked(mlp["wg"], idx, 1)
        else:
            i0 = jnp.asarray(idx[0])
            out["wo"] = jnp.take(mlp["wo"], i0, 0)
            out["wi"] = jnp.take(mlp["wi"], i0, 1)
            if gated and "wg" in mlp:
                out["wg"] = jnp.take(mlp["wg"], i0, 1)
        for r in range(R):
            key = paths[r] + ".wo"
            if key in new_stats:
                new_stats[key] = _slice_stats(new_stats[key], idx[r])
        return out

    def prune_moe(moe: Dict, paths: List[str]) -> Dict:
        """Per-expert channel pruning: uniform keep count, per-(layer,
        expert) choice.  Expert weights [R?, E, d, ffe] / wo [R?, E, ffe, d]."""
        stacked = moe["wo"].ndim == 4
        R = moe["wo"].shape[0] if stacked else 1
        E, ffe = moe["wo"].shape[-3], moe["wo"].shape[-2]
        keep_ff = max(8, int(round(keep_frac * ffe)) // 8 * 8)
        idx = np.zeros((R, E, keep_ff), np.int64)
        for r in range(R):
            wo_np = _np(moe["wo"][r] if stacked else moe["wo"])  # [E, ffe, d]
            st = stats.get(paths[r] + ".wo")
            for e in range(E):
                row = (wo_np[e] ** 2).mean(1)
                if st is not None and st.sqnorm is not None:
                    imp = st.sqnorm[e] / max(st.count, 1) * row
                else:
                    imp = row
                idx[r, e] = np.sort(np.argsort(-imp, kind="stable")[:keep_ff])
        out = dict(moe)

        def tk(w, axis):
            idxj = jnp.asarray(idx)
            if stacked:
                return jax.vmap(jax.vmap(
                    lambda we, i: jnp.take(we, i, axis=axis - 1)))(
                        w, idxj)
            return jax.vmap(lambda we, i: jnp.take(we, i, axis=axis - 1))(
                w, idxj[0])

        out["wo"] = tk(moe["wo"], 1)      # [.., E, keep_ff, d]
        out["wi"] = tk(moe["wi"], 2)      # [.., E, d, keep_ff]
        out["wg"] = tk(moe["wg"], 2)
        for r in range(R):
            key = paths[r] + ".wo"
            st = new_stats.get(key)
            if st is not None and st.sqnorm is not None:
                new_stats[key] = WeightStats(
                    shape=(E, keep_ff, moe["wo"].shape[-1]),
                    count=st.count,
                    H=None if st.H is None else np.stack(
                        [st.H[e][np.ix_(idx[r, e], idx[r, e])]
                         for e in range(E)]),
                    sqnorm=np.stack([st.sqnorm[e][idx[r, e]]
                                     for e in range(E)]),
                    amax=np.stack([st.amax[e][idx[r, e]] for e in range(E)]),
                )
        return out

    fam = cfg.family
    new_ff, new_moe_ff = cfg.d_ff, cfg.moe_d_ff
    if fam in ("dense", "moe", "vlm"):
        from repro.models.transformer import pattern_unit
        unit, R, tail = pattern_unit(cfg)
        for u in range(len(unit)):
            blk = dict(params["blocks"][u])
            if "mlp" in blk:
                blk["mlp"] = prune_mlp(blk["mlp"],
                                       [f"blocks.{u}.{r}.mlp" for r in range(R)])
                new_ff = blk["mlp"]["wo"].shape[-2]
            if "moe" in blk:
                blk["moe"] = prune_moe(blk["moe"],
                                       [f"blocks.{u}.{r}.moe" for r in range(R)])
                new_moe_ff = blk["moe"]["wo"].shape[-2]
            if "shared_mlp" in blk:
                blk["shared_mlp"] = prune_mlp(
                    blk["shared_mlp"],
                    [f"blocks.{u}.{r}.shared_mlp" for r in range(R)])
            if "dense_mlp" in blk:
                blk["dense_mlp"] = prune_mlp(
                    blk["dense_mlp"],
                    [f"blocks.{u}.{r}.dense_mlp" for r in range(R)])
                new_ff = blk["dense_mlp"]["wo"].shape[-2]
            params["blocks"][u] = blk
        for i in range(tail):
            blk = dict(params["tail"][i])
            if "mlp" in blk:
                blk["mlp"] = prune_mlp(blk["mlp"], [f"tail.{i}.mlp"])
            if "moe" in blk:
                blk["moe"] = prune_moe(blk["moe"], [f"tail.{i}.moe"])
            if "shared_mlp" in blk:
                blk["shared_mlp"] = prune_mlp(blk["shared_mlp"],
                                              [f"tail.{i}.shared_mlp"])
            if "dense_mlp" in blk:
                blk["dense_mlp"] = prune_mlp(blk["dense_mlp"],
                                             [f"tail.{i}.dense_mlp"])
            params["tail"][i] = blk
    elif fam == "rwkv":
        stackp = params["blocks"][0]
        R = stackp["ln1"]["w"].shape[0]
        cm = dict(stackp["cm"])
        ff = cm["wv"].shape[-2]
        keep_ff = max(8, int(round(keep_frac * ff)) // 8 * 8)
        idx = np.zeros((R, keep_ff), np.int64)
        for r in range(R):
            st = stats.get(f"blocks.0.{r}.cm.wv")
            imp = _channel_importance(st, _np(cm["wv"][r]))
            idx[r] = np.sort(np.argsort(-imp, kind="stable")[:keep_ff])
        cm["wv"] = _take_stacked(cm["wv"], idx, 0)
        cm["wk"] = _take_stacked(cm["wk"], idx, 1)
        for r in range(R):
            key = f"blocks.0.{r}.cm.wv"
            if key in new_stats:
                new_stats[key] = _slice_stats(new_stats[key], idx[r])
        stackp = dict(stackp)
        stackp["cm"] = cm
        params["blocks"] = [stackp]
        new_ff = keep_ff
    elif fam == "hybrid":
        params["shared"] = dict(params["shared"])
        params["shared"]["mlp"] = prune_mlp(params["shared"]["mlp"],
                                            ["shared.mlp"])
        new_ff = params["shared"]["mlp"]["wo"].shape[-2]
    elif fam == "encdec":
        for lst in ("enc_blocks", "dec_blocks"):
            for i in range(len(params[lst])):
                params[lst][i] = dict(params[lst][i])
                params[lst][i]["mlp"] = prune_mlp(
                    params[lst][i]["mlp"], [f"{lst}.{i}.mlp"], gated=False)
                new_ff = params[lst][i]["mlp"]["wo"].shape[-2]
    new_cfg = cfg.replace(d_ff=new_ff, moe_d_ff=new_moe_ff)
    return params, new_cfg, CalibStats(new_stats, stats.block_sim,
                                       stats.n_tokens)


# ---------------------------------------------------------------------------
# layer dropping
# ---------------------------------------------------------------------------

def drop_layers(params, cfg, stats: CalibStats, n_drop_units: int):
    """Drop the ``n_drop_units`` most redundant scan repeats (pattern units
    — single layers for uniform stacks; per-layer for unrolled stacks).

    Redundancy score = 1 - cos(block input, block output) averaged over
    the unit, from calibration.  Order of the surviving layers is kept.
    """
    if n_drop_units <= 0:
        return params, cfg, stats
    params = jax.tree.map(lambda a: a, params)
    new_stats = dict(stats.weights)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        from repro.models.transformer import pattern_unit
        unit, R, tail = pattern_unit(cfg)
        keep_n = max(1, R - n_drop_units)
        score = np.zeros(R)
        for r in range(R):
            sims = [stats.block_sim.get(f"blocks.{u}.{r}", 0.0)
                    for u in range(len(unit))]
            score[r] = 1.0 - float(np.mean(sims))
        kept = np.sort(np.argsort(-score, kind="stable")[:keep_n])
        for u in range(len(unit)):
            params["blocks"][u] = jax.tree.map(
                lambda a: jnp.take(a, jnp.asarray(kept), axis=0),
                params["blocks"][u])
            # re-key stats blocks.u.{old} -> blocks.u.{new}
            moved = {}
            for new_i, old_i in enumerate(kept.tolist()):
                pre_old, pre_new = f"blocks.{u}.{old_i}.", f"blocks.{u}.{new_i}."
                for k in list(new_stats):
                    if k.startswith(pre_old):
                        moved[pre_new + k[len(pre_old):]] = new_stats.pop(k)
            # purge dropped
            for k in list(new_stats):
                if k.startswith(f"blocks.{u}.") and k not in moved:
                    drop_r = int(k.split(".")[2])
                    if drop_r >= keep_n and k not in moved:
                        new_stats.pop(k)
            new_stats.update(moved)
        new_layers = len(unit) * keep_n + tail
        pat = cfg.pattern()
        new_pat = unit * keep_n + pat[len(unit) * R:]
        new_cfg = cfg.replace(n_layers=new_layers,
                              attn_pattern=new_pat
                              if cfg.attn_pattern is not None else None)
    elif fam == "rwkv":
        R = cfg.n_layers
        keep_n = max(1, R - n_drop_units)
        score = np.array([1.0 - stats.block_sim.get(f"blocks.0.{r}", 0.0)
                          for r in range(R)])
        kept = np.sort(np.argsort(-score, kind="stable")[:keep_n])
        params["blocks"] = [jax.tree.map(
            lambda a: jnp.take(a, jnp.asarray(kept), axis=0),
            params["blocks"][0])]
        moved = {}
        for new_i, old_i in enumerate(kept.tolist()):
            pre_old, pre_new = f"blocks.0.{old_i}.", f"blocks.0.{new_i}."
            for k in list(new_stats):
                if k.startswith(pre_old):
                    moved[pre_new + k[len(pre_old):]] = new_stats.pop(k)
        for k in list(new_stats):
            if (k.startswith("blocks.0.") and k not in moved
                    and int(k.split(".")[2]) >= keep_n):
                new_stats.pop(k)
        new_stats.update(moved)
        new_cfg = cfg.replace(n_layers=keep_n)
    elif fam == "hybrid":
        from repro.models.hybrid import layout
        G, K, tail, _ = layout(cfg)
        keep_n = max(1, G - n_drop_units)
        score = np.zeros(G)
        for g in range(G):
            sims = [stats.block_sim.get(f"mamba_groups.{g}.{k}", 0.0)
                    for k in range(K)]
            score[g] = 1.0 - float(np.mean(sims))
        kept = np.sort(np.argsort(-score, kind="stable")[:keep_n])
        params["mamba_groups"] = jax.tree.map(
            lambda a: jnp.take(a, jnp.asarray(kept), axis=0),
            params["mamba_groups"])
        moved = {}
        for new_i, old_i in enumerate(kept.tolist()):
            pre_old, pre_new = f"mamba_groups.{old_i}.", f"mamba_groups.{new_i}."
            for k in list(new_stats):
                if k.startswith(pre_old):
                    moved[pre_new + k[len(pre_old):]] = new_stats.pop(k)
        for k in list(new_stats):
            if (k.startswith("mamba_groups.") and k not in moved
                    and int(k.split(".")[1]) >= keep_n):
                new_stats.pop(k)
        new_stats.update(moved)
        new_cfg = cfg.replace(n_layers=keep_n * (K + 1) + tail)
    elif fam == "encdec":
        ne, nd = cfg.n_enc_layers, cfg.n_dec_layers
        scores = []
        for i in range(ne):
            scores.append((1.0 - stats.block_sim.get(f"enc_blocks.{i}", 0.0),
                           "enc_blocks", i))
        for i in range(nd):
            scores.append((1.0 - stats.block_sim.get(f"dec_blocks.{i}", 0.0),
                           "dec_blocks", i))
        scores.sort()
        drop_set = {"enc_blocks": set(), "dec_blocks": set()}
        for _score, lst, i in scores:
            if len(drop_set["enc_blocks"]) + len(drop_set["dec_blocks"]) \
                    >= n_drop_units:
                break
            if len(params[lst]) - len(drop_set[lst]) > 1:
                drop_set[lst].add(i)
        for lst in ("enc_blocks", "dec_blocks"):
            kept = [i for i in range(len(params[lst]))
                    if i not in drop_set[lst]]
            params[lst] = [params[lst][i] for i in kept]
            moved = {}
            for new_i, old_i in enumerate(kept):
                pre_old, pre_new = f"{lst}.{old_i}.", f"{lst}.{new_i}."
                for k in list(new_stats):
                    if k.startswith(pre_old):
                        moved[pre_new + k[len(pre_old):]] = new_stats.pop(k)
            for k in list(new_stats):
                if (k.startswith(f"{lst}.") and k not in moved
                        and int(k.split(".")[1]) >= len(kept)):
                    new_stats.pop(k)
            new_stats.update(moved)
        new_cfg = cfg.replace(
            n_enc_layers=cfg.n_enc_layers - len(drop_set["enc_blocks"]),
            n_dec_layers=cfg.n_dec_layers - len(drop_set["dec_blocks"]))
    else:
        return params, cfg, stats
    return params, new_cfg, CalibStats(new_stats, stats.block_sim,
                                       stats.n_tokens)


# ---------------------------------------------------------------------------
# expert pruning (MoE instance-optimization)
# ---------------------------------------------------------------------------

def prune_experts(params, cfg, stats: CalibStats, keep_e: int):
    """Keep the ``keep_e`` most-routed experts per layer — the MoE analogue
    of the paper's structural pruning, driven by *this query's* routing
    distribution from calibration."""
    if cfg.family != "moe" or keep_e >= cfg.n_experts:
        return params, cfg, stats
    assert keep_e >= cfg.top_k, (keep_e, cfg.top_k)
    params = jax.tree.map(lambda a: a, params)
    new_stats = dict(stats.weights)
    E = cfg.n_experts

    def prune_one(moe: Dict, paths: List[str]) -> Dict:
        stacked = moe["router"].ndim == 3
        R = moe["router"].shape[0] if stacked else 1
        idx = np.zeros((R, keep_e), np.int64)
        for r in range(R):
            st = stats.get(paths[r] + ".router")
            if st is not None and st.route_count is not None:
                imp = st.route_count.astype(np.float64)
                if st.route_prob is not None:
                    imp = imp + 1e-3 * st.route_prob
            else:
                w = _np(moe["router"][r] if stacked else moe["router"])
                imp = (w ** 2).sum(0)
            idx[r] = np.sort(np.argsort(-imp, kind="stable")[:keep_e])
        out = dict(moe)
        if stacked:
            out["router"] = _take_stacked(moe["router"], idx, 1)
            out["wi"] = _take_stacked(moe["wi"], idx, 0)
            out["wg"] = _take_stacked(moe["wg"], idx, 0)
            out["wo"] = _take_stacked(moe["wo"], idx, 0)
        else:
            i0 = jnp.asarray(idx[0])
            out["router"] = jnp.take(moe["router"], i0, 1)
            out["wi"] = jnp.take(moe["wi"], i0, 0)
            out["wg"] = jnp.take(moe["wg"], i0, 0)
            out["wo"] = jnp.take(moe["wo"], i0, 0)
        for r in range(R):
            for nm in ("wi", "wg", "wo"):
                key = paths[r] + "." + nm
                st = new_stats.get(key)
                if st is not None and st.sqnorm is not None:
                    new_stats[key] = WeightStats(
                        shape=(keep_e,) + tuple(st.shape[1:]),
                        count=st.count,
                        H=None if st.H is None else st.H[idx[r]],
                        sqnorm=st.sqnorm[idx[r]],
                        amax=st.amax[idx[r]],
                    )
            key = paths[r] + ".router"
            st = new_stats.get(key)
            if st is not None and st.route_count is not None:
                st.route_count = st.route_count[idx[r]]
                if st.route_prob is not None:
                    st.route_prob = st.route_prob[idx[r]]
        return out

    from repro.models.transformer import pattern_unit
    unit, R, tail = pattern_unit(cfg)
    for u in range(len(unit)):
        params["blocks"][u] = dict(params["blocks"][u])
        params["blocks"][u]["moe"] = prune_one(
            params["blocks"][u]["moe"],
            [f"blocks.{u}.{r}.moe" for r in range(R)])
    for i in range(tail):
        params["tail"][i] = dict(params["tail"][i])
        params["tail"][i]["moe"] = prune_one(params["tail"][i]["moe"],
                                             [f"tail.{i}.moe"])
    new_cfg = cfg.replace(n_experts=keep_e, top_k=min(cfg.top_k, keep_e))
    return params, new_cfg, CalibStats(new_stats, stats.block_sim,
                                       stats.n_tokens)
