"""Sparsification: Wanda and SparseGPT one-shot pruning + TPU block sparsity.

Layout convention: weights are ``[d_in, d_out]`` — the reduction
(input) dimension is axis 0, so N:M patterns group along axis 0 and
comparison groups for per-output pruning run down columns.

TPU adaptation: fine-grained 2:4 sparsity has no MXU
support, so N:M/unstructured masks buy *model-size* reduction (they
compose with int8/int4 storage), while ``block_sparse_mask`` prunes whole
128-aligned blocks that the Pallas ``block_sparse_matmul`` kernel
actually skips — that is where the FLOP/bandwidth savings come from.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.compressed import BlockSparseTensor


def wanda_mask(w: np.ndarray, act_norm: np.ndarray, *,
               sparsity: float = 0.0, n: int = 0, m: int = 0) -> np.ndarray:
    """Wanda importance |W| * ||x||: bool keep-mask [d_in, d_out].

    ``n, m``: N:M structured (keep n of every m along the input dim);
    otherwise unstructured at ``sparsity`` per output column.
    """
    w = np.asarray(w, np.float32)
    score = np.abs(w) * np.asarray(act_norm, np.float32)[:, None]
    d_in, d_out = w.shape
    if m:
        assert d_in % m == 0, (d_in, m)
        sg = score.reshape(d_in // m, m, d_out)
        # rank within each m-group (ascending); keep the top n
        rank = np.argsort(np.argsort(sg, axis=1), axis=1)
        return (rank >= m - n).reshape(d_in, d_out)
    k = int(round(sparsity * d_in))
    if k <= 0:
        return np.ones_like(w, bool)
    # per-output-column threshold
    kth = np.partition(score, k - 1, axis=0)[k - 1]
    return score > kth[None, :]


def sparsegpt_prune(w: np.ndarray, H: np.ndarray, *, sparsity: float = 0.0,
                    n: int = 0, m: int = 0, percdamp: float = 0.01,
                    blocksize: int = 128) -> Tuple[np.ndarray, np.ndarray]:
    """SparseGPT one-shot pruning with error propagation.

    Returns (pruned dense weight, keep-mask).  Importance within each
    column block is  w^2 / diag(cholesky(H^-1))^2 ; pruned entries' error
    is pushed onto not-yet-processed input dims exactly like GPTQ.
    """
    w = np.asarray(w, np.float64).copy()
    H = np.asarray(H, np.float64).copy()
    d_in, d_out = w.shape
    dead = np.diag(H) <= 0
    H[dead, dead] = 1.0
    w[dead] = 0.0
    H[np.arange(d_in), np.arange(d_in)] += percdamp * np.mean(np.diag(H))
    U = np.linalg.cholesky(np.linalg.inv(H)).T

    mask = np.ones((d_in, d_out), bool)
    if m:
        blocksize = max(blocksize - blocksize % m, m)
    for bs in range(0, d_in, blocksize):
        be = min(bs + blocksize, d_in)
        diag = np.diag(U)[bs:be]
        score = (w[bs:be] ** 2) / (diag[:, None] ** 2)
        if m:
            nb = (be - bs) // m
            sg = score[: nb * m].reshape(nb, m, d_out)
            rank = np.argsort(np.argsort(sg, axis=1), axis=1)
            mask[bs:bs + nb * m] = (rank >= m - n).reshape(nb * m, d_out)
        else:
            k = int(round(sparsity * (be - bs)))
            if k > 0:
                kth = np.partition(score, k - 1, axis=0)[k - 1]
                mask[bs:be] = score > kth[None, :]
        Werr = np.zeros((be - bs, d_out))
        for j in range(bs, be):
            keep = mask[j]
            wj = np.where(keep, w[j], 0.0)
            err = (w[j] - wj) / U[j, j]
            w[j] = wj
            w[j + 1:be] -= np.outer(U[j, j + 1:be], err)
            Werr[j - bs] = err
        if be < d_in:
            w[be:] -= U[bs:be, be:].T @ Werr
    return w.astype(np.float32), mask


def block_scores(w: np.ndarray, act_norm: Optional[np.ndarray],
                 bs: int) -> np.ndarray:
    """Importance of each bs x bs block: sum |W| * ||x|| within block."""
    w = np.asarray(w, np.float32)
    d_in, d_out = w.shape
    s = np.abs(w)
    if act_norm is not None:
        s = s * np.asarray(act_norm, np.float32)[:, None]
    nb_i, nb_o = d_in // bs, d_out // bs
    return s[: nb_i * bs, : nb_o * bs].reshape(nb_i, bs, nb_o, bs).sum((1, 3))


def block_sparse_mask(w: np.ndarray, *, bs: int, density: float,
                      act_norm: Optional[np.ndarray] = None) -> np.ndarray:
    """Keep-mask over blocks [d_in/bs, d_out/bs] at the target density,
    chosen per block-column so every output tile keeps the same number of
    input blocks (the Pallas kernel then has a uniform gather length)."""
    sc = block_scores(w, act_norm, bs)
    nb_i, nb_o = sc.shape
    keep = max(1, int(round(density * nb_i)))
    kth = np.partition(-sc, keep - 1, axis=0)[keep - 1]
    mask = (-sc) <= kth[None, :]
    # enforce exactly `keep` per column (ties)
    for c in np.nonzero(mask.sum(0) != keep)[0]:
        order = np.argsort(-sc[:, c], kind="stable")
        mask[:, c] = False
        mask[order[:keep], c] = True
    return mask


def apply_block_mask(w, mask: np.ndarray, bs: int) -> BlockSparseTensor:
    """Zero the pruned blocks and wrap as BlockSparseTensor (with the
    per-output-block-column gather indices the Pallas kernel consumes)."""
    w = np.asarray(w, np.float32)
    big = np.kron(mask.astype(np.float32), np.ones((bs, bs), np.float32))
    wz = (w * big[: w.shape[0], : w.shape[1]]).astype(np.float32)
    keep = int(mask[:, 0].sum())
    assert (mask.sum(0) == keep).all(), "non-uniform block column density"
    idx = np.stack([np.nonzero(mask[:, c])[0] for c in range(mask.shape[1])])
    return BlockSparseTensor(jnp.asarray(wz, jnp.bfloat16),
                             jnp.asarray(mask.astype(np.float32)), bs,
                             jnp.asarray(idx.astype(np.int32)))


def density(mask: np.ndarray) -> float:
    return float(np.mean(mask))
