#!/usr/bin/env python
"""Static-analysis driver: plan verifier + jitted hot-path audit.

  python tools/analyze.py --all                     # text report
  python tools/analyze.py --all --format=json --out DIAG.json
  python tools/analyze.py --jit --update-baseline   # accept current debt

Two layers behind one diagnostics stream (src/repro/analysis/):

``--plan``  runs the independent plan verifier over a representative
workload suite — every optimizer rewrite is re-proved inside
``optimize(verify=True)`` and both the built and the optimized plans
are checked structurally.  A clean tree reports zero PLAN diagnostics;
any finding means a rule shipped an unprovable rewrite.

``--jit``   builds a tiny engine on CPU and runs the full hot-path
audit (analysis/jit_audit.py): scripted workload through ``generate``,
then callback / donation / weak-type / retrace / budget checks over
every jitted target.

The exit code gates on the **baseline** (tools/analysis_baseline.json):
only findings absent from it — new debt — fail the run, so CI is
monotone.  ``--update-baseline`` rewrites the file from the current
findings (review the diff like code).
"""
from __future__ import annotations

import argparse
import os
import sys

# deterministic, device-independent analysis: force the CPU platform
# (and the multi-device topology tests use) before jax can initialize
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))

DEFAULT_BASELINE = os.path.join(ROOT, "tools", "analysis_baseline.json")


def plan_workloads():
    """Representative plan suite: one workload per optimizer rule plus
    mixed chains — the shapes the test suite and the paper's query
    workloads exercise."""
    from repro.olap import plan as P
    from repro.olap.table import Table

    t = Table({"category": ["a", "b", "a", "a", "c", "b", "a", "c"],
               "status": ["ok", "bad", "ok", "bad", "ok", "ok",
                          "bad", "ok"]})
    right = Table({"name": ["alpha", "beta"]})
    scan = P.Scan(t)

    def m(inp, col="category", prompt="label: ", out="label", new=8):
        return P.LLMMap(input=inp, col=col, prompt=prompt, out_col=out,
                        max_new=new)

    plans = {
        "pushdown": P.Filter(
            input=m(scan), pred=lambda r: r["status"] == "ok",
            columns=("status",)),
        "fusion": m(m(scan), out="label2"),
        "dedup": m(scan),
        "filter_chain": P.Filter(
            input=P.LLMFilter(input=m(scan), col="status",
                              prompt="keep? ", max_new=2),
            pred=lambda r: r["status"] == "ok", columns=("status",)),
        "correct_select": P.Select(
            input=P.LLMCorrect(input=scan, col="status",
                               prompt="fix: ", out_col="status_fixed",
                               max_new=8),
            cols=("category", "status_fixed")),
        "join": P.LLMJoin(input=scan, right=right,
                          on=("category", "name"), prompt="match? ",
                          max_new=2),
    }
    return plans


def run_plan_layer():
    from repro.olap import analysis as ANA
    from repro.olap import optimizer as OPT

    diags, detail = [], {}
    for name, plan in plan_workloads().items():
        diags.extend(ANA.verify_plan(plan))
        try:
            optimized, firings = OPT.optimize(plan, verify=True)
        except ANA.PlanVerificationError as e:
            diags.extend(e.diagnostics)
            detail[name] = {"error": str(e)}
            continue
        diags.extend(ANA.verify_plan(optimized))
        detail[name] = {"rules": [f.rule for f in firings],
                        "verified": all(f.verified for f in firings)}
    return diags, {"plan_workloads": detail}


def run_jit_layer():
    import jax

    from repro.analysis import jit_audit as JA
    from repro.configs.base import ModelConfig
    from repro.models import api
    from repro.serving.engine import Engine

    cfg = ModelConfig(name="audit", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=260, max_seq=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg)
    report = JA.audit_engine(engine)
    return report.diagnostics, {"jit_cache_stats": report.cache_stats,
                                "jit_budget": report.budget}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--plan", action="store_true",
                    help="run the plan-verifier layer")
    ap.add_argument("--jit", action="store_true",
                    help="run the jitted hot-path audit")
    ap.add_argument("--all", action="store_true",
                    help="run every layer (default when none given)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file to gate against "
                         "('' disables gating)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--out", default="",
                    help="also write the report to this path")
    ap.add_argument("--assert-no-callbacks", action="store_true",
                    help="fail on ANY JIT001 (host callback on the "
                         "jitted hot path), baseline or not — CI runs "
                         "this so the paged decode step stays free of "
                         "device->host round trips")
    args = ap.parse_args(argv)
    if args.all or not (args.plan or args.jit):
        args.plan = args.jit = True

    from repro.analysis import diagnostics as D

    diags, extra = [], {}
    if args.plan:
        d, x = run_plan_layer()
        diags.extend(d)
        extra.update(x)
    if args.jit:
        d, x = run_jit_layer()
        diags.extend(d)
        extra.update(x)

    if args.update_baseline:
        D.save_baseline(args.baseline, diags)
        print(f"baseline updated: {args.baseline} "
              f"({len(diags)} finding(s) recorded)")
        return 0

    report = (D.render_json(diags, extra=extra)
              if args.format == "json" else D.render_text(diags))
    print(report)
    if args.out:
        with open(args.out, "w") as f:
            f.write(D.render_json(diags, extra=extra) + "\n")

    if args.assert_no_callbacks:
        cbs = [d for d in diags if d.code == "JIT001"]
        if cbs:
            print(f"\n--assert-no-callbacks: {len(cbs)} host callback(s) "
                  "on the jitted hot path:", file=sys.stderr)
            print(D.render_text(cbs), file=sys.stderr)
            return 1

    if args.baseline and os.path.exists(args.baseline):
        base = D.load_baseline(args.baseline)
    else:
        base = D.Baseline()
    new = base.new_findings(diags)
    if new:
        print(f"\n{len(new)} NEW finding(s) not in baseline "
              f"({args.baseline or 'none'}):", file=sys.stderr)
        print(D.render_text(new), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
