#!/usr/bin/env python
"""Docs-consistency check: every file path referenced in the
architecture doc and the module READMEs must exist in the tree.

  python tools/check_docs.py          # exit 1 + listing on dead refs

A "path reference" is a backticked token or relative markdown-link
target that looks like a file path (contains a slash, ends in a known
extension).  ``path:line`` anchors are checked by path only — line
numbers drift with edits and the named symbols are the stable part.
Candidates are resolved against the repo root and the ``src/`` /
``src/repro/`` prefixes (module READMEs refer to siblings that way).
Runtime artifacts under ``results/`` (gitignored), globs, and URLs are
exempt.
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

DOCS = [
    "docs/ARCHITECTURE.md",
    "README.md",
    "src/repro/serving/README.md",
    "src/repro/kernels/README.md",
    "src/repro/core/README.md",
    "src/repro/distributed/README.md",
    "src/repro/olap/README.md",
    "src/repro/analysis/README.md",
    "src/repro/service/README.md",
    "ROADMAP.md",
    "CHANGES.md",
]

PREFIXES = ("", "src/", "src/repro/")
EXTS = (".py", ".md", ".yml", ".yaml", ".toml", ".txt", ".json", ".sh")
PATHISH = re.compile(r"^[\w./-]+$")
CODE = re.compile(r"`([^`]+)`")
LINK = re.compile(r"\]\(([^)#\s]+)")


def candidates(text):
    for m in CODE.finditer(text):
        tok = m.group(1).strip().split()[0] if m.group(1).strip() else ""
        tok = tok.split(":")[0]          # drop :line anchors
        if ("/" in tok and tok.endswith(EXTS) and "*" not in tok
                and PATHISH.match(tok)):
            yield tok
    for m in LINK.finditer(text):
        tok = m.group(1).strip().strip("`")
        if tok and not tok.startswith(("http://", "https://", "../",
                                       "mailto:")):
            yield tok


def resolves(tok: str) -> bool:
    if tok.startswith("results/"):       # runtime artifacts, gitignored
        return True
    return any(os.path.exists(os.path.join(ROOT, pre, tok))
               for pre in PREFIXES)


def main() -> int:
    dead = []
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            dead.append((doc, "<the doc itself is missing>"))
            continue
        with open(path) as f:
            text = f.read()
        for tok in sorted(set(candidates(text))):
            if not resolves(tok):
                dead.append((doc, tok))
    if dead:
        print("docs-consistency check FAILED — dead file references:")
        for doc, tok in dead:
            print(f"  {doc}: {tok}")
        return 1
    print(f"docs-consistency check OK ({len(DOCS)} docs scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
