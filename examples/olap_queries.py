"""End-to-end OLAP driver: LLM operators inside queries, instance-optimized.

    PYTHONPATH=src python examples/olap_queries.py [--no-optimize]

Loads (or trains) the OLAP-task model, builds tables, and runs the
paper's three workloads through the Query pipeline:

  Q1  SELECT review, LLM('summarize: ' || review) FROM reviews
  Q2  SELECT lang,  LLM('fix: ' || lang)          FROM commits
  Q3  SELECT * FROM vendors a FUZZY JOIN suppliers b ON LLM(a.name, b.name)
  Q4  SELECT lang, LLM(...) FROM commits WHERE status = 'ok'
      -- EXPLAINed first: the semantic optimizer pushes the status
      -- filter below the LLM op and dedups distinct inputs, so the
      -- model runs once per unique surviving value

With optimization ON, each query triggers the IOLM-DB workflow first
(calibrate on its own rows -> recipe search -> compressed engine); the
session log shows what was picked.  ``--no-plan-rules`` disables the
plan optimizer (for a fixed model the outputs are byte-identical
either way; see src/repro/olap/README.md for the calibration caveat
under instance optimization).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import load_model
from repro.olap.query import IOLMSession, Query
from repro.olap.table import Table
from repro.training.data import PROMPTS, workload_rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-optimize", action="store_true")
    ap.add_argument("--no-plan-rules", action="store_true",
                    help="disable the semantic plan optimizer")
    ap.add_argument("--rows", type=int, default=16)
    args = ap.parse_args()

    cfg, params, tok = load_model()
    session = IOLMSession(params, cfg, tokenizer=tok, objective="perf",
                          acc_floor=0.85,
                          engine_kw=dict(slots=8, max_len=160,
                                         buckets=(48, 96, 128)))
    optimize = not args.no_optimize

    # Q1: summarization
    reviews = Table({"review": [r.text for r in
                                workload_rows("summarize", args.rows)]})
    t0 = time.time()
    out1 = Query(reviews, session, optimize=optimize) \
        .llm_map("review", prompt=PROMPTS["summarize"], out_col="summary") \
        .run()
    print(f"\nQ1 summarize ({time.time() - t0:.1f}s):")
    print(out1.select(["summary"]).head(4))

    # Q2: data correction
    commits = Table({"lang": [r.text for r in
                              workload_rows("correct", args.rows)]})
    t0 = time.time()
    out2 = Query(commits, session, optimize=optimize) \
        .llm_correct("lang", prompt=PROMPTS["correct"]).run()
    print(f"\nQ2 correct ({time.time() - t0:.1f}s):")
    print(out2.head(4))

    # Q3: fuzzy join
    pairs = workload_rows("join", args.rows)
    left = Table({"name": [p.text.split(" | ")[0] for p in pairs]})
    right = Table({"name": [p.text.split(" | ")[1] for p in pairs]})
    t0 = time.time()
    out3 = Query(left, session, optimize=optimize) \
        .llm_join(right, ("name", "name"), prompt=PROMPTS["join"]).run()
    print(f"\nQ3 fuzzy join ({time.time() - t0:.1f}s): "
          f"{len(out3)} matched pairs")
    print(out3.head(4))

    # Q4: the semantic optimizer at work — EXPLAIN, then run.  The
    # status filter declares its read set, so it pushes below the LLM
    # op; the duplicated lang values dedup to one invocation each.
    commits4 = Table({
        "lang": [commits["lang"][i % max(1, args.rows // 2)]
                 for i in range(args.rows)],
        "status": ["ok" if i % 2 == 0 else "wip"
                   for i in range(args.rows)]})
    q4 = Query(commits4, session, optimize=optimize,
               optimize_plan=not args.no_plan_rules) \
        .llm_correct("lang", prompt=PROMPTS["correct"], max_new=8) \
        .filter(lambda r: r["status"] == "ok", columns=["status"])
    print("\nQ4 EXPLAIN:")
    print(q4.explain())
    t0 = time.time()
    out4 = q4.run()
    n_inv = sum(s.invocations for s in q4.last_run_stats)
    print(f"\nQ4 correct+filter ({time.time() - t0:.1f}s): "
          f"{len(out4)} rows, {n_inv} LLM invocations "
          f"for {len(commits4)} input rows")
    print(out4.head(4))

    print("\nsession log:")
    for line in session.log:
        print(" ", line)


if __name__ == "__main__":
    main()
