"""Train an LM on the OLAP-task mixture, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --dim 768 \
        --layers 12            # ~100M params (hours on CPU; sized for TPU)

Kill it mid-run and re-invoke: it resumes from the last atomic
checkpoint (the fault-tolerance drill).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig
from repro.training import optimizer as OPT
from repro.training import train_loop as TL


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="results/train_lm_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(name="train-lm", family="dense",
                      n_layers=args.layers, d_model=args.dim,
                      n_heads=max(4, args.dim // 64),
                      n_kv_heads=max(2, args.dim // 128),
                      d_ff=args.dim * 3, vocab_size=260, max_seq=1024)
    print(f"model: {cfg.param_count() / 1e6:.1f} M params")
    tcfg = TL.TrainConfig(steps=args.steps, batch=args.batch,
                          seq_len=args.seq,
                          microbatches=args.microbatches,
                          ckpt_dir=args.ckpt, ckpt_every=100, log_every=20)
    out = TL.train(cfg, tcfg,
                   OPT.adamw(lr=2e-3, warmup=30, total_steps=args.steps))
    print(f"done; final loss {out['losses'][-1][1]:.4f}; "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
