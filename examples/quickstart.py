"""Quickstart: instance-optimize a model for a query in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

Builds a small LM, calibrates it on a sample of query prompts, applies
one compression recipe, and shows the size/agreement trade-off — the
IOLM-DB workflow in miniature.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.compressed import param_bytes
from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.core import policy as POL
from repro.models import api
from repro.training.data import ByteTokenizer, PROMPTS, workload_rows


def main() -> None:
    cfg = ModelConfig(name="quickstart", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                      vocab_size=260, max_seq=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    print(f"base model: {cfg.param_count() / 1e6:.2f} M params, "
          f"{param_bytes(params) / 1e6:.2f} MB")

    # 1. calibration sample — the query's own rows, prompt-formatted
    rows = workload_rows("correct", 16)
    prompts = [PROMPTS["correct"] + r.text for r in rows]
    toks, lens = tok.pad_batch([tok.encode(p, bos=True) for p in prompts],
                               seq_len=64)
    opt = InstanceOptimizer(params, cfg)
    opt.run_calibration({"tokens": jnp.asarray(toks)})
    print(f"calibrated on {len(prompts)} rows "
          f"({len(opt.stats.weights)} weight matrices observed)")

    # 2. compress
    for recipe in (Recipe(name="w8-gptq", wbits=8),
                   Recipe(name="w8+2:4", wbits=8, nm=(2, 4)),
                   Recipe(name="w4+ffn75", wbits=4, group=32,
                          ffn_keep_frac=0.75)):
        p2, c2, rep = opt.apply(recipe)
        # 3. score agreement with the uncompressed baseline
        eval_fn = POL.make_agreement_eval(params, cfg, jnp.asarray(toks),
                                          max_new=8,
                                          lengths=jnp.asarray(lens))
        res = eval_fn(p2, c2)
        print(f"  {rep.summary()}  token-agreement={res.token_agreement:.2f}")


if __name__ == "__main__":
    main()
