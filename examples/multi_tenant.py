"""Multi-tenant quickstart: N tenants' OLAP queries on one shared pool.

    PYTHONPATH=src python examples/multi_tenant.py [--rows 8] [--budget-mb N]

Three tenants each run a different LLM query (summarize / correct /
fuzzy-join) against their own table.  Instead of each query owning a
private engine, the session holds a byte-budgeted ``ModelPool``: every
query's instance-optimized model is admitted under one budget (LRU
eviction when it fills), and a fair-share ``Scheduler`` interleaves all
tenants' operators tick-by-tick — every tenant makes progress
simultaneously on the same hardware, which is the paper's parallelism
argument in miniature.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import load_model
from repro.core.compressed import param_bytes
from repro.core.pipeline import Recipe
from repro.olap.query import IOLMSession, Query
from repro.olap.table import Table
from repro.serving.scheduler import Scheduler
from repro.training.data import PROMPTS, workload_rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="pool byte budget (default: 3x the base model)")
    args = ap.parse_args()

    cfg, params, tok = load_model()
    budget = int((args.budget_mb * 1e6) if args.budget_mb
                 else 3 * param_bytes(params) + (64 << 20))
    session = IOLMSession(
        params, cfg, tokenizer=tok, acc_floor=0.85,
        recipes=[Recipe(name="w8", wbits=8, quant_method="absmax")],
        engine_kw=dict(slots=4, max_len=160, buckets=(48, 96, 128)),
        pool_budget=budget)

    # three tenants, three different queries
    reviews = Table({"review": [r.text for r in
                                workload_rows("summarize", args.rows)]})
    commits = Table({"lang": [r.text for r in
                              workload_rows("correct", args.rows)]})
    pairs = workload_rows("join", args.rows)
    left = Table({"name": [p.text.split(" | ")[0] for p in pairs]})
    right = Table({"name": [p.text.split(" | ")[1] for p in pairs]})

    queries = {
        "tenant-a": Query(reviews, session)
            .llm_map("review", prompt=PROMPTS["summarize"],
                     out_col="summary"),
        "tenant-b": Query(commits, session)
            .llm_correct("lang", prompt=PROMPTS["correct"]),
        "tenant-c": Query(left, session)
            .llm_join(right, ("name", "name"), prompt=PROMPTS["join"]),
    }

    sched = Scheduler(session.pool, share=4)
    t0 = time.time()
    results = sched.run_queries(queries)
    dt = time.time() - t0

    print(f"\n{len(queries)} tenants in {dt:.1f}s "
          f"({sched.stats.rows} rows, {sched.stats.ticks} ticks)")
    print("tenant-a summaries:", results["tenant-a"]["summary"][:2])
    print("tenant-b fixes:    ", results["tenant-b"]["lang_fixed"][:2])
    print("tenant-c matches:  ", len(results["tenant-c"]), "pairs")

    pool = session.pool
    print(f"\npool: {len(pool)} resident models, "
          f"{pool.resident_bytes / 1e6:.1f} / {budget / 1e6:.1f} MB, "
          f"{pool.stats.evictions} evictions")
    for v in pool.resident_versions:
        print("  resident:", v)
    print("\nsession log:")
    for line in session.log:
        print(" ", line)


if __name__ == "__main__":
    main()
