"""Serve a workload with Baseline vs IOLM-DB-Perf vs IOLM-DB-Acc.

    PYTHONPATH=src python examples/serve_compressed.py --task correct

Runs the full policy search for the chosen workload and serves the same
batch of rows through all three models, printing the Table-1-style
trade-off live.  ``--temperature/--top-k`` exercise the sampler fused
into the engine's jitted decode step (0 = greedy, the default).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import load_model, make_engine, task_accuracy
from benchmarks.table1 import MAX_NEW, optimize_for
from repro.core.compressed import param_bytes
from repro.serving.sampler import SamplingConfig
from repro.training import data as D


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="correct",
                    choices=("summarize", "correct", "join"))
    ap.add_argument("--rows", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=args.top_k, seed=args.seed)

    cfg, params, tok = load_model()
    rows = D.eval_rows(args.task, args.rows)
    prompts = [D.PROMPTS[args.task] + r.text for r in rows]

    outcome = optimize_for(args.task, cfg, params, tok)
    print(outcome.table())

    models = {"Baseline": (params, cfg, param_bytes(params))}
    for nm, cand in (("IOLM-DB-Perf", outcome.perf),
                     ("IOLM-DB-Acc", outcome.acc)):
        if cand:
            models[nm] = (cand.params, cand.cfg, cand.result.bytes)

    print(f"\nserving {len(prompts)} rows of '{args.task}':")
    base_rps = None
    for nm, (p, c, nbytes) in models.items():
        eng = make_engine(p, c, tok, sampling=sampling)
        t0 = time.time()
        outs = eng.generate(prompts, max_new=MAX_NEW[args.task])
        rps = len(prompts) / (time.time() - t0)
        base_rps = base_rps or rps
        acc = task_accuracy(outs, rows)
        print(f"  {nm:14s} {nbytes / 1e6:7.2f} MB  acc={acc:.2f}  "
              f"{rps:6.2f} rows/s ({rps / base_rps:.2f}x)  "
              f"e.g. {outs[0]!r}")


if __name__ == "__main__":
    main()
