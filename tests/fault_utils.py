"""Fault-injection helpers for the serving/service tests.

``FlakyEngine`` wraps any engine and raises an injected exception on
the Nth ``step()`` call or the Nth ``submit()`` — the two places a real
engine can die mid-tick (device OOM, kernel failure, a poisoned jit
cache).  It deliberately does NOT forward ``step_begin``/
``step_finish``: the scheduler then drives it through the whole-step
fallback path, whose ``step()`` is the exact composition of the split
protocol, so the quarantine behavior under test is the same one a real
mid-decode fault would hit.

``flaky_pool`` builds the FakeSession/ModelPool pair from
tests/test_scheduler.py but lets the caller plant faults per model
version.  Only the FIRST engine built for a version is flaky — a
rebuild after quarantine is the recovered replacement — which mirrors
the transient-fault story the scheduler's retry path exists for.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.serving.scheduler import ModelPool

from test_scheduler import FakeEngine, FakeSession


class FlakyEngine:
    """Engine wrapper raising on the Nth step()/submit() (1-based)."""

    def __init__(self, inner, *, fail_on_step: Optional[int] = None,
                 fail_on_submit: Optional[int] = None,
                 exc_type=RuntimeError):
        self.inner = inner
        self.version = inner.version
        self.fail_on_step = fail_on_step
        self.fail_on_submit = fail_on_submit
        self.exc_type = exc_type
        self.steps = 0
        self.submits = 0
        self.fired = False

    def submit(self, text, *, max_new=8, prefix=None):
        self.submits += 1
        if self.submits == self.fail_on_submit:
            self.fired = True
            raise self.exc_type(
                f"injected fault: submit #{self.submits} on "
                f"{self.version}")
        return self.inner.submit(text, max_new=max_new, prefix=prefix)

    def has_work(self):
        return self.inner.has_work()

    def step(self):
        self.steps += 1
        if self.steps == self.fail_on_step:
            self.fired = True
            raise self.exc_type(
                f"injected fault: step #{self.steps} on {self.version}")
        return self.inner.step()


def flaky_pool(sizes: Dict[str, int], budget: int, *, slots: int = 2,
               faults: Optional[Dict[str, Dict]] = None):
    """(session, pool, engines-by-version) with planted faults.

    ``faults`` maps version -> FlakyEngine kwargs (``fail_on_step=`` /
    ``fail_on_submit=``); e.g. ``{"q1": {"fail_on_step": 2}}`` makes
    the first engine built for model ``q1`` die on its second decode
    tick.  ``sizes`` must include every version the test will admit
    (including ``"base"`` when quarantine retries are expected).
    """
    sess = FakeSession(sizes)
    built: Dict[str, List] = {}

    def factory(m):
        e = FakeEngine(m.version, slots=slots)
        kw = (faults or {}).get(m.version)
        if kw and m.version not in built:
            e = FlakyEngine(e, **kw)
        built.setdefault(m.version, []).append(e)
        return e

    pool = ModelPool(sess, budget, engine_factory=factory,
                     entry_bytes=lambda m: sizes[m.version])
    return sess, pool, built
