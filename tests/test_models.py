"""Per-architecture smoke tests (deliverable f) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FAST_ARCHS, make_batch
from repro.configs import registry
from repro.configs.base import SHAPES, input_specs, shape_supported
from repro.models import api


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch, reduced_models):
    """One forward + one loss/grad step on the reduced config: correct
    shapes, no NaNs (the assigned-architecture smoke requirement)."""
    cfg, params = reduced_models[arch]
    batch = make_batch(cfg)
    logits, aux = api.forward(params, cfg, batch)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1] + (cfg.n_img_tokens
                                    if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_prefill_decode_matches_forward(arch, reduced_models):
    """prefill + token-by-token decode == full forward logits."""
    cfg, params = reduced_models[arch]
    B, S, T = 2, 24, 32
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    full = {"tokens": tokens}
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.PRNGKey(4),
                                (B, cfg.enc_ctx, cfg.d_model), cfg.dtype)
        batch = {"tokens": tokens[:, :S], "enc_inputs": enc}
        full["enc_inputs"] = enc
    else:
        batch = {"tokens": tokens[:, :S]}
    fl, _ = api.forward(params, cfg, full)
    _, cache = api.prefill(params, cfg, batch, max_len=T,
                           compact_local=False)
    errs = []
    for t in range(S, T):
        lg, cache = api.decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                    jnp.full((B,), t, jnp.int32), max_len=T)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - fl[:, t].astype(jnp.float32)))))
    assert max(errs) < 0.15, errs


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    """Every supported (arch x shape) cell has well-formed input specs."""
    cfg = registry.get_config(arch)
    for shape in SHAPES:
        ok, reason = shape_supported(cfg, shape)
        if not ok:
            assert reason
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        for sds in jax.tree.leaves(specs):
            assert all(d > 0 for d in sds.shape)


def test_long_context_assignment():
    """long_500k runs exactly for the sub-quadratic/hybrid/local archs."""
    runs = {a for a in registry.ARCH_IDS
            if shape_supported(registry.get_config(a), "long_500k")[0]}
    assert runs == {"gemma3-1b", "rwkv6-3b", "zamba2-7b"}


def test_gemma2_softcap_and_pattern():
    cfg = registry.get_config("gemma2-2b")
    assert cfg.attn_softcap > 0 and cfg.final_softcap > 0
    assert set(cfg.pattern()) == {"L", "G"} and len(cfg.pattern()) == 26


def test_param_counts_match_published_scale():
    """Analytic param counts are in the right ballpark for the configs."""
    expect = {"mistral-nemo-12b": (11e9, 14e9),
              "granite-20b": (18e9, 22e9),
              "gemma2-2b": (2.0e9, 3.3e9),
              "gemma3-1b": (0.7e9, 1.3e9),
              "arctic-480b": (430e9, 520e9),
              "qwen2-moe-a2.7b": (12e9, 16e9),
              "rwkv6-3b": (2.5e9, 3.5e9),
              "zamba2-7b": (5.5e9, 9e9)}
    for arch, (lo, hi) in expect.items():
        n = registry.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_much_smaller():
    cfg = registry.get_config("arctic-480b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()


def test_rwkv_chunked_matches_sequential():
    from repro.models import rwkv as RW
    B, T, H, N = 2, 33, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N)) - 1.0)
    u = jax.random.normal(jax.random.PRNGKey(9), (H, N)) * 0.3
    S0 = jnp.zeros((B, H, N, N))
    o1, s1 = RW.wkv6_sequential(r, k, v, w, u, S0)
    o2, s2 = RW.wkv6_chunked(r, k, v, w, u, S0, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_matches_sequential():
    from repro.models import mamba as M
    B, T, H, P, N = 2, 37, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    a = jnp.exp(-dt * jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3))
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    D = jnp.ones((H,))
    h0 = jnp.zeros((B, H, P, N))
    y1, h1 = M.ssd_sequential(x, dt, a, Bm, Cm, D, h0)
    y2, h2 = M.ssd_chunked(x, dt, a, Bm, Cm, D, h0, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


def test_local_block_attention_matches_masked_full():
    from repro.models import layers as L
    B, S, H, K, Dh, W = 1, 64, 4, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, Dh), jnp.float32)
    got = L.local_block_attention(q, k, v, window=W)
    want = L.full_attention(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
