"""Property tests (hypothesis): the verifier vs random plan chains.

Positive direction: every optimizer output over ANY well-formed chain
proves clean.  Negative direction (mutation testing): a random illegal
annotation seeded into a legal plan is always caught.  Split from
test_plan_verifier.py so the module-level importorskip cannot take the
deterministic tests down with it.
"""
import dataclasses

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping, not aborting collection")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.olap import analysis as ANA
from repro.olap import optimizer as OPT
from repro.olap import plan as P
from repro.olap.table import Table

SETTINGS = dict(max_examples=30, deadline=None)

_PROMPTS = ("label: ", "fix: ", "keep? ")


def table():
    return Table({"category": ["a", "b", "a", "a", "c", "b", "a", "c"],
                  "status": ["ok", "bad", "ok", "bad", "ok", "ok",
                             "bad", "ok"]})


@st.composite
def plan_chains(draw):
    """Random well-formed chains over the demo table: maps/corrects
    (random prompt/col), declared filters, llm_filters."""
    t = table()
    node = P.Scan(t)
    fresh = 0
    schema = list(t.columns)
    for _ in range(draw(st.integers(1, 5))):
        op = draw(st.sampled_from(("map", "correct", "filter",
                                   "llm_filter")))
        col = draw(st.sampled_from(schema))
        prompt = draw(st.sampled_from(_PROMPTS))
        if op == "map":
            out = f"out{fresh}"
            fresh += 1
            node = P.LLMMap(input=node, col=col, prompt=prompt,
                            out_col=out, max_new=4)
            schema.append(out)
        elif op == "correct":
            out = f"fix{fresh}"
            fresh += 1
            node = P.LLMCorrect(input=node, col=col, prompt=prompt,
                                out_col=out, max_new=4)
            schema.append(out)
        elif op == "llm_filter":
            node = P.LLMFilter(input=node, col=col, prompt=prompt,
                               max_new=2)
        else:
            node = P.Filter(input=node, pred=lambda r: True,
                            columns=frozenset({col}))
    return node


@given(plan=plan_chains())
@settings(**SETTINGS)
def test_verifier_accepts_every_optimizer_output(plan):
    """For ANY well-formed chain: the plan verifies, the optimizer's
    output verifies, and every firing was proved (optimize would have
    raised otherwise)."""
    assert [d for d in ANA.verify_plan(plan) if d.severity == "error"] == []
    optimized, firings = OPT.optimize(plan, verify=True)
    assert [d for d in ANA.verify_plan(optimized)
            if d.severity == "error"] == []
    assert all(f.verified for f in firings)
    # rewrites preserve the output schema
    assert ANA.output_schema(plan) == ANA.output_schema(optimized)


@given(plan=plan_chains(), data=st.data())
@settings(**SETTINGS)
def test_verifier_rejects_seeded_mutations(plan, data):
    """Mutation-test the verifier: seed a random illegal annotation
    into an otherwise-legal optimized plan and it must be caught."""
    optimized, _ = OPT.optimize(plan, verify=True)
    nodes = P.chain(optimized)
    mutation = data.draw(st.sampled_from(("dedup_derived", "fused_dep",
                                          "missing_read")))
    if mutation == "dedup_derived":
        # dedup over a column written below it (or absent from Scan)
        idx = [i for i, n in enumerate(nodes)
               if n.kind in P.ROWWISE_LLM_KINDS]
        if not idx:
            return
        i = data.draw(st.sampled_from(idx))
        derived = sorted({c for below in nodes[i + 1:]
                          for c in P.added_cols(below)})
        if not derived:
            return
        bad = dataclasses.replace(nodes[i], dedup=True,
                                  col=data.draw(st.sampled_from(derived)))
        mutated = P.rebuild(nodes[:i] + [bad] + nodes[i + 1:])
        expect = {"PLAN021"}
    elif mutation == "fused_dep":
        scan = P.chain(optimized)[-1]
        mutated = P.LLMFused(input=scan, col="out0", prompt="p: ",
                             outs=("out0", "x"), max_new=4,
                             src_kind="map")
        expect = {"PLAN033", "PLAN004"}
    else:
        scan = P.chain(optimized)[-1]
        mutated = P.Filter(input=scan, pred=lambda r: True,
                           columns=frozenset({"never_written"}))
        expect = {"PLAN004"}
    got = {d.code for d in ANA.verify_plan(mutated)}
    assert got & expect, (got, expect)
