"""The always-on service layer (src/repro/service): reservoir metrics,
SLO admission, fault quarantine-and-retry, the plan wire format, the
HTTP end-to-end contract, and warm restart in a fresh process.

The headline assertions mirror the subsystem's contracts:

  * HTTP-path rows are byte-identical to ``Scheduler.run_queries``
    for the same plan spec;
  * per-tenant in-flight rows never exceed the SLO cap under random
    admission/release interleavings (deterministic here; the
    hypothesis variant lives in tests/test_service_props.py);
  * an engine fault mid-run quarantines, retries on the base engine,
    and yields the SAME rows as a clean run, with the degradation
    recorded in stats;
  * a killed-and-restarted "server" (fresh subprocess, warm-state
    restore) answers a previously seen query with ZERO recalibrations
    and identical recipes.
"""
import dataclasses
import json
import os
import random
import statistics
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import pytest

from repro.core.pipeline import Recipe
from repro.olap import plan as PLAN
from repro.olap.query import IOLMSession, Query, query_from_spec
from repro.olap.table import Table
from repro.serving.metrics import Reservoir, render_stats
from repro.serving.scheduler import Scheduler
from repro.service import (SemanticQueryService, ServiceClient, TenantSLO,
                           save_warm_state, serve)
from repro.service.client import QueryError, ShedError
from repro.service.core import table_rows
from repro.service.slo import AdmissionController

from fault_utils import flaky_pool
from test_scheduler import W8

ENGINE_KW = dict(slots=2, max_len=64, buckets=(16, 48))


def make_session(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("recipes", [W8])
    kw.setdefault("calib_rows", 4)
    kw.setdefault("eval_rows", 2)
    kw.setdefault("engine_kw", dict(ENGINE_KW))
    return IOLMSession(params, cfg, **kw)


# ---------------------------------------------------------------------------
# reservoir percentile estimator
# ---------------------------------------------------------------------------

class TestReservoir:
    def test_exact_below_capacity(self):
        """Un-overflowed reservoir == statistics.quantiles exactly."""
        rng = random.Random(7)
        data = [rng.uniform(0, 50) for _ in range(101)]
        r = Reservoir(capacity=512)
        for x in data:
            r.add(x)
        assert r.quantile(0.5) == pytest.approx(
            statistics.quantiles(data, n=2, method="inclusive")[0])
        assert r.quantile(0.95) == pytest.approx(
            statistics.quantiles(data, n=20, method="inclusive")[18])
        assert r.quantile(0.99) == pytest.approx(
            statistics.quantiles(data, n=100, method="inclusive")[98])
        assert r.count == 101
        assert r.vmin == min(data) and r.vmax == max(data)

    def test_deterministic_beyond_capacity(self):
        """Same stream -> same sample: the sampler owns its RNG."""
        r1, r2 = Reservoir(capacity=64), Reservoir(capacity=64)
        for i in range(2000):
            x = float(i * 37 % 1000)
            r1.add(x)
            r2.add(x)
        assert r1.sample == r2.sample
        assert r1.count == r2.count == 2000

    def test_overflow_estimate_within_tolerance(self):
        """256-sample reservoir over a 10k uniform stream: the p50
        estimate stays within a few std-errors of the true median."""
        rng = random.Random(3)
        data = [rng.uniform(0, 1000) for _ in range(10000)]
        r = Reservoir(capacity=256)
        for x in data:
            r.add(x)
        exact = statistics.quantiles(data, n=10, method="inclusive")
        # rank tolerance: the estimate must land between the exact
        # p30 and p70 (±0.2 rank ≈ ±6 sigma for a 256 sample)
        assert exact[2] <= r.quantile(0.5) <= exact[6]
        assert r.count == 10000

    def test_empty_and_tiny(self):
        r = Reservoir()
        assert r.quantile(0.5) is None
        assert r.as_dict()["p95"] is None
        r.add(4.0)
        assert r.quantile(0.5) == r.quantile(0.99) == 4.0
        with pytest.raises(ValueError):
            Reservoir(capacity=0)


# ---------------------------------------------------------------------------
# SLO admission control
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_inflight_rows_never_exceed_cap(self):
        """Random admit/release interleavings: the cap is an invariant,
        and the controller's ledger matches an independent one."""
        rng = random.Random(0)
        cap = 10
        ac = AdmissionController(
            {"t": TenantSLO(max_inflight_rows=cap, max_queries=10 ** 6)})
        live = []
        admitted = shed = 0
        for _ in range(800):
            if live and rng.random() < 0.45:
                ac.release("t", live.pop(rng.randrange(len(live))))
            else:
                rows = rng.randint(1, 6)
                if ac.try_admit("t", rows, 0.0) is None:
                    live.append(rows)
                    admitted += 1
                else:
                    shed += 1
            cur = ac.inflight_rows("t")
            assert cur == sum(live)
            assert cur <= cap
        snap = ac.snapshot()["t"]
        assert snap["admitted"] == admitted and snap["shed"] == shed

    def test_token_bucket_refills_on_injected_clock(self):
        now = [0.0]
        ac = AdmissionController(
            {"t": TenantSLO(max_inflight_rows=100, max_queries=100,
                            token_budget=10.0, refill_per_s=5.0)},
            clock=lambda: now[0])
        assert ac.try_admit("t", 1, 8.0) is None        # 10 -> 2
        shed = ac.try_admit("t", 1, 8.0)                # 2 < 8: shed
        assert shed is not None and shed.reason == "token_budget"
        assert shed.retry_after_s == pytest.approx(6.0 / 5.0)
        now[0] += 2.0                                   # +10, cap at 10
        assert ac.try_admit("t", 1, 8.0) is None

    def test_max_queries_cap(self):
        ac = AdmissionController(
            {"t": TenantSLO(max_inflight_rows=100, max_queries=1)})
        assert ac.try_admit("t", 1, 0.0) is None
        shed = ac.try_admit("t", 1, 0.0)
        assert shed is not None and shed.reason == "max_queries"
        ac.release("t", 1)
        assert ac.try_admit("t", 1, 0.0) is None

    def test_shed_charges_nothing(self):
        ac = AdmissionController(
            {"t": TenantSLO(max_inflight_rows=5, max_queries=10)})
        assert ac.try_admit("t", 4, 0.0) is None
        assert ac.try_admit("t", 4, 0.0) is not None    # would exceed
        assert ac.inflight_rows("t") == 4               # nothing charged


# ---------------------------------------------------------------------------
# fault injection: quarantine-and-retry degradation
# ---------------------------------------------------------------------------

class TestQuarantine:
    PROMPTS = ["alpha", "br", "charlie", "dx", "echo!"]

    def _clean_rows(self):
        sess, pool, _ = flaky_pool({"q": 20, "base": 20}, budget=100)
        sched = Scheduler(pool, share=4)
        s = sched.submit("t", list(self.PROMPTS), qsig="q")
        sched.run()
        return s.results()

    def test_step_fault_retries_to_clean_rows(self):
        clean = self._clean_rows()
        sess, pool, built = flaky_pool(
            {"q": 20, "base": 20}, budget=100,
            faults={"q": {"fail_on_step": 2}})
        sched = Scheduler(pool, share=4)
        s = sched.submit("t", list(self.PROMPTS), qsig="q")
        sched.run()
        assert s.done and s.error is None
        assert s.results() == clean
        assert built["q"][0].fired          # the fault really happened
        # ...and it is observable, not silent
        assert sched.stats.degradations == 1
        ev = sched.stats.events[0]
        assert ev["action"] == "retry_base" and ev["tenant"] == "t"
        assert "injected fault" in ev["error"]
        assert sched.stats.tenants["t"].degradations == 1
        assert "q" not in pool.resident_versions    # quarantined out

    def test_submit_fault_retries_to_clean_rows(self):
        clean = self._clean_rows()
        sess, pool, built = flaky_pool(
            {"q": 20, "base": 20}, budget=100,
            faults={"q": {"fail_on_submit": 2}})
        sched = Scheduler(pool, share=4)
        s = sched.submit("t", list(self.PROMPTS), qsig="q")
        sched.run()
        assert s.done and s.error is None
        assert s.results() == clean
        assert sched.stats.degradations == 1

    def test_retry_budget_exhaustion_is_terminal(self):
        """Replacement engine faulting too: bounded retries, then the
        submission fails alone with the error surfaced."""
        sess, pool, _ = flaky_pool(
            {"q": 20, "base": 20}, budget=100,
            faults={"q": {"fail_on_step": 1},
                    "base": {"fail_on_step": 1}})
        sched = Scheduler(pool, share=4, max_retries=1)
        s = sched.submit("t", list(self.PROMPTS), qsig="q")
        sched.run()                          # must not raise
        assert s.done and s.error is not None
        assert sched.stats.events[-1]["action"] == "failed"
        with pytest.raises(RuntimeError):
            s.results()

    def test_innocent_tenant_unaffected_by_fault(self):
        sess, pool, _ = flaky_pool(
            {"q": 20, "ok": 20, "base": 20}, budget=100,
            faults={"q": {"fail_on_step": 2}})
        sched = Scheduler(pool, share=4)
        s1 = sched.submit("t1", list(self.PROMPTS), qsig="q")
        s2 = sched.submit("t2", ["x", "yy", "zzz"], qsig="ok")
        sched.run()
        assert s1.done and s1.error is None
        assert s2.done and s2.error is None
        assert s2.results() == ["out(x)", "out(yy)", "out(zzz)"]
        assert sched.stats.tenants["t2"].degradations == 0


# ---------------------------------------------------------------------------
# plan <-> JSON wire format
# ---------------------------------------------------------------------------

class TestSpecRoundTrip:
    SESS = SimpleNamespace(pool=None, backend="auto")

    def _query(self):
        t = Table({"city": ["ab", "cdef", "gh"], "pop": [1, 9, 4]})
        return (Query(t, self.SESS, cascade_budget=0.2, cascade="off")
                .filter(PLAN.ColumnPredicate("pop", "ge", 4),
                        columns=["pop"])
                .llm_map("city", prompt="Summarize: ", out_col="s",
                         max_new=6)
                .llm_filter("city", prompt="Keep? ", max_new=4)
                .select(["city", "s"]))

    def test_roundtrip_is_fixpoint(self):
        spec = self._query().to_spec()
        wire = json.loads(json.dumps(spec))      # actual wire trip
        q2 = query_from_spec(wire, self.SESS)
        assert q2.to_spec() == spec
        assert PLAN.render(q2._root) == PLAN.render(self._query()._root)

    def test_join_and_correct_roundtrip(self):
        t = Table({"name": ["aa", "bb"]})
        right = Table({"ref": ["aa!", "zz"]})
        q = (Query(t, self.SESS)
             .llm_correct("name", prompt="Fix: ", max_new=5)
             .llm_join(right, ("name", "ref"), prompt="Same? ",
                       max_new=4, accuracy_budget=0.1))
        spec = json.loads(json.dumps(q.to_spec()))
        assert query_from_spec(spec, self.SESS).to_spec() == q.to_spec()

    def test_opaque_callables_refuse_serialization(self):
        t = Table({"a": ["x"]})
        with pytest.raises(ValueError, match="opaque"):
            Query(t, self.SESS).filter(lambda r: True).to_spec()
        with pytest.raises(ValueError, match="keep"):
            Query(t, self.SESS).llm_filter(
                "a", prompt="p", keep=lambda s: True).to_spec()

    def test_malformed_specs_rejected(self):
        with pytest.raises(ValueError, match="version"):
            query_from_spec({"version": 99, "table": {"columns": {}},
                             "ops": []}, self.SESS)
        with pytest.raises(ValueError, match="unknown query spec op"):
            query_from_spec({"version": 1,
                             "table": {"columns": {"a": ["x"]}},
                             "ops": [{"op": "drop_table"}]}, self.SESS)
        with pytest.raises(ValueError, match="predicate op"):
            PLAN.ColumnPredicate("a", "regex", "x")


# ---------------------------------------------------------------------------
# HTTP end-to-end (real tiny model; one server for the class)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny(tiny_dense):
    return tiny_dense


def demo_spec(rows=4, optimize=True):
    sess = SimpleNamespace(pool=None, backend="auto")
    langs = ["pyton", "javascrpt", "golang", "rst", "kotln",
             "hskell"][:rows]
    return (Query(Table({"lang": langs}), sess, optimize=optimize)
            .llm_correct("lang", max_new=6).to_spec())


@pytest.fixture(scope="module")
def served(tiny):
    sess = make_session(tiny, pool_budget=64 * 1024 * 1024)
    svc = SemanticQueryService(
        sess,
        slos={"capped": TenantSLO(max_inflight_rows=1, max_queries=2)},
        default_slo=TenantSLO(max_inflight_rows=256, max_queries=8))
    server, thread = serve(svc, port=0, block=False)
    host, port = server.server_address[:2]
    try:
        yield svc, ServiceClient(host, port, max_retries=0)
    finally:
        server.shutdown()
        server.server_close()
        svc.stop()


class TestServiceHTTP:
    def test_healthz(self, served):
        svc, client = served
        h = client.healthz()
        assert h["ok"] is True and h["uptime_s"] >= 0

    def test_http_rows_match_run_queries(self, served, tiny):
        """THE acceptance bar: the HTTP path and a direct
        Scheduler.run_queries call produce byte-identical rows for the
        same plan spec."""
        svc, client = served
        spec = demo_spec(rows=4)
        got = client.query("t1", spec)
        ref_sess = make_session(tiny, pool_budget=64 * 1024 * 1024)
        res = Scheduler(ref_sess.pool, share=8).run_queries(
            {"t1": query_from_spec(spec, ref_sess)})
        assert got == table_rows(res["t1"])
        assert len(got) == 4 and "lang_fixed" in got[0]

    def test_streaming_order_and_event_schema(self, served):
        svc, client = served
        events = list(client.iter_query("t2", demo_spec(rows=3)))
        kinds = [e["event"] for e in events]
        assert kinds[-1] == "done"
        ops = [e for e in events if e["event"] == "op"]
        rows = [e for e in events if e["event"] == "row"]
        assert len(ops) >= 1 and {"kind", "qsig", "rows"} <= set(ops[0])
        # rows stream strictly in index order, after every op event
        assert [e["index"] for e in rows] == list(range(len(rows)))
        assert kinds.index("row") > kinds.index("op")
        assert events[-1]["rows"] == len(rows) == 3

    def test_slo_shed_is_429_with_retry_after(self, served):
        svc, client = served
        shed_before = svc.shed
        with pytest.raises(ShedError) as ei:
            client.query("capped", demo_spec(rows=4))   # 4 rows > cap 1
        assert ei.value.verdict["reason"] == "max_inflight_rows"
        assert float(ei.value.verdict["retry_after_s"]) > 0
        assert svc.shed > shed_before
        assert svc.stats_dict()["admission"]["capped"]["shed"] >= 1

    def test_stats_schema_and_percentiles(self, served):
        svc, client = served
        client.query("t1", demo_spec(rows=3))           # ensure traffic
        stats = client.stats()
        assert {"service", "scheduler", "admission", "pool",
                "session"} <= set(stats)
        assert stats["service"]["queries"] >= 1
        t1 = stats["scheduler"]["tenants"]["t1"]
        for hist in (t1["latency"], t1["queue_wait"]):
            assert {"count", "mean", "p50", "p95", "p99"} <= set(hist)
            assert hist["count"] > 0 and hist["p50"] is not None
            assert hist["p50"] <= hist["p95"] <= hist["p99"]
        assert stats["session"]["recalibrations"] >= 1
        text = client.stats_text()
        assert "SERVICE STATS" in text and "tenants:" in text
        assert render_stats(stats) == text

    def test_malformed_spec_is_400(self, served):
        svc, client = served
        with pytest.raises(QueryError, match="HTTP 400"):
            client.query("t1", {"version": 99, "table": {"columns": {}},
                                "ops": []})

    def test_checkpoint_endpoint(self, served, tmp_path):
        svc, client = served
        client.query("t1", demo_spec(rows=3))
        out = client.checkpoint(str(tmp_path / "warm"))
        assert out["ok"] is True
        manifest = json.load(
            open(tmp_path / "warm" / "service_state.json"))
        assert manifest["version"] == 1 and manifest["models"]


# ---------------------------------------------------------------------------
# warm restart in a fresh process namespace
# ---------------------------------------------------------------------------

RESTART_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    from repro.configs.base import ModelConfig
    from repro.core.pipeline import Recipe
    from repro.models import api
    from repro.olap.query import IOLMSession, query_from_spec
    from repro.service.checkpoint import restore_warm_state
    from repro.service.core import table_rows

    payload = json.load(open(sys.argv[1]))
    cfg = ModelConfig(**payload["cfg"])
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    sess = IOLMSession(
        params, cfg,
        recipes=[Recipe(name="w8", wbits=8, quant_method="absmax")],
        calib_rows=4, eval_rows=2, engine_kw=payload["engine_kw"],
        pool_budget=64 * 1024 * 1024)
    restore_warm_state(sess, payload["ckpt"])
    assert sess.recalibrations == 0 and sess.cascade_fits == 0
    recipes = {f"{q}|{d}": m.recipe.name
               for (q, d), m in sess.model_cache._d.items()}
    assert recipes == payload["recipes"], (recipes, payload["recipes"])
    q = query_from_spec(payload["spec"], sess)
    rows = table_rows(q.run())
    assert sess.recalibrations == 0, \\
        f"restart recalibrated: {sess.recalibrations}"
    assert sess.cascade_fits == 0, \\
        f"restart re-fit cascade: {sess.cascade_fits}"
    assert rows == payload["rows"], (rows, payload["rows"])
    print("WARM-RESTART-OK")
""")


class TestWarmRestart:
    def test_restart_answers_seen_query_without_recalibration(
            self, tiny, tmp_path):
        cfg, params = tiny
        sess = make_session(tiny, pool_budget=64 * 1024 * 1024)
        q = (Query(Table({"lang": ["pyton", "javascrpt", "golang"]}),
                   sess, cascade="force")
             .llm_correct("lang", max_new=6, accuracy_budget=0.5))
        spec = q.to_spec()
        rows = table_rows(q.run())
        assert sess.recalibrations >= 1 and sess.cascade_fits >= 1
        ckpt = str(tmp_path / "warm")
        save_warm_state(sess, ckpt)
        payload = {
            "ckpt": ckpt, "spec": spec, "rows": rows,
            "cfg": dataclasses.asdict(cfg),
            "engine_kw": dict(ENGINE_KW),
            "recipes": {f"{k[0]}|{k[1]}": m.recipe.name
                        for k, m in sess.model_cache._d.items()},
        }
        ppath = tmp_path / "payload.json"
        ppath.write_text(json.dumps(payload))
        env = dict(os.environ, JAX_PLATFORMS="cpu",
                   PYTHONPATH=os.path.join(os.path.dirname(__file__),
                                           "..", "src"))
        proc = subprocess.run(
            [sys.executable, "-c", RESTART_SCRIPT, str(ppath)],
            capture_output=True, text=True, timeout=600, env=env)
        assert proc.returncode == 0, proc.stderr[-4000:]
        assert "WARM-RESTART-OK" in proc.stdout
