"""Confidence-calibrated model cascades (ISSUE: cascades as a physical
plan strategy) and the calibration-statistics bugfixes that back them.

Covers the full stack: the pure threshold fit
(``core.calibrate.fit_confidence_threshold``), the planner's
engine="cascade" annotation + cost inequality (olap/physical.py,
olap/optimizer.py), the serial executor's proxy->base escalation
(``Query._run_cascade``), the pooled scheduler's two-phase cascade
(``Scheduler.run_queries``), and the exactness contract: an
accuracy budget of 0 — or any unsatisfiable budget (threshold = inf)
— produces output byte-identical to a base-only run.
"""
import math

import numpy as np
import pytest

from repro.core.calibrate import (CascadeCalibration, Recorder, WeightStats,
                                  fit_confidence_threshold)
from repro.core.pipeline import Recipe
from repro.olap import optimizer as OPT
from repro.olap import physical as PHYS
from repro.olap import plan as PLAN
from repro.olap.query import IOLMSession, Query
from repro.olap.table import Table
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler

W8 = Recipe(name="w8", wbits=8, quant_method="absmax")


@pytest.fixture(scope="module")
def tiny(tiny_dense):
    return tiny_dense


def make_session(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("recipes", [W8])
    kw.setdefault("calib_rows", 4)
    kw.setdefault("eval_rows", 2)
    kw.setdefault("engine_kw", dict(slots=2, max_len=64, buckets=(32,)))
    return IOLMSession(params, cfg, **kw)


VALS = ["pyton", "javascrpt", "golang", "rst", "kotln", "swft"]


def cascade_query(sess, **kw):
    kw.setdefault("cascade_budget", 0.5)
    kw.setdefault("cascade", "force")
    return Query(Table({"lang": list(VALS)}), sess, **kw) \
        .llm_correct("lang", max_new=6)


def base_only_outputs(tiny):
    sess = make_session(tiny)
    q = Query(Table({"lang": list(VALS)}), sess, optimize=False) \
        .llm_correct("lang", max_new=6)
    return q.run()["lang_fixed"]


def proxy_only_outputs(tiny):
    sess = make_session(tiny)
    q = Query(Table({"lang": list(VALS)}), sess, cascade="off") \
        .llm_correct("lang", max_new=6)
    return q.run()["lang_fixed"]


# ---------------------------------------------------------------------------
# the threshold fit (core/calibrate.py)
# ---------------------------------------------------------------------------

class TestFitConfidenceThreshold:
    def test_deterministic(self):
        conf = [0.9, 0.1, 0.5, 0.7, 0.3]
        agree = [True, False, True, True, False]
        a = fit_confidence_threshold(conf, agree, 0.2)
        b = fit_confidence_threshold(list(conf), list(agree), 0.2)
        assert a == b                       # pure function of the sample

    def test_budget_zero_escalates_everything(self):
        cal = fit_confidence_threshold([0.9, 0.8], [True, True], 0.0)
        assert math.isinf(cal.threshold)
        assert cal.expected_escalation == 1.0

    def test_empty_sample_escalates_everything(self):
        cal = fit_confidence_threshold([], [], 0.5)
        assert math.isinf(cal.threshold)
        assert cal.expected_escalation == 1.0
        assert cal.n_fit == 0

    def test_picks_smallest_satisfying_threshold(self):
        conf = [0.1, 0.2, 0.3, 0.4]
        agree = [False, True, True, True]
        # budget 0.25: 1 accepted-but-wrong row out of 4 is allowed, so
        # the lowest confidence already satisfies the constraint
        cal = fit_confidence_threshold(conf, agree, 0.25)
        assert cal.threshold == pytest.approx(0.1)
        assert cal.expected_escalation == 0.0
        # budget 0.1: the disagreeing row must escalate -> the cut sits
        # just above it, and exactly that one row escalates
        cal = fit_confidence_threshold(conf, agree, 0.1)
        assert cal.threshold == pytest.approx(0.2)
        assert cal.expected_escalation == pytest.approx(0.25)

    def test_unsatisfiable_budget_returns_inf(self):
        cal = fit_confidence_threshold([0.9], [False], 0.5)
        assert math.isinf(cal.threshold)
        assert cal.expected_escalation == 1.0

    def test_threshold_monotone_in_budget(self):
        rng = np.random.RandomState(0)
        conf = rng.rand(64)
        agree = conf + rng.rand(64) * 0.5 > 0.6
        thr = [fit_confidence_threshold(conf, agree, b).threshold
               for b in (0.05, 0.1, 0.2, 0.4)]
        # looser budget -> accept more -> threshold can only drop
        assert all(a >= b for a, b in zip(thr, thr[1:]))


# ---------------------------------------------------------------------------
# calibration-statistics bugfixes
# ---------------------------------------------------------------------------

class TestRecorderBlockSim:
    def test_block_sim_is_mean_over_visits(self):
        """record_block over >=3 visits must average 1/n each.  The old
        pairwise running average 0.5*(old+new) weighted the visits
        (1/4, 1/4, 1/2) here, giving 0.75 instead of 2/3."""
        rec = Recorder(hessian=False)
        e1 = np.array([1.0, 0.0], np.float32)
        e2 = np.array([0.0, 1.0], np.float32)
        rec.record_block("blk", e1, e1)     # cos = 1
        rec.record_block("blk", e1, e2)     # cos = 0
        rec.record_block("blk", e1, e1)     # cos = 1
        stats = rec.finish()
        assert stats.block_sim["blk"] == pytest.approx(2.0 / 3.0, abs=1e-6)

    def test_single_visit_unchanged(self):
        rec = Recorder(hessian=False)
        v = np.array([1.0, 2.0], np.float32)
        rec.record_block("blk", v, v)
        assert rec.finish().block_sim["blk"] == pytest.approx(1.0, abs=1e-6)


class TestMergeNormPerExpert:
    def test_stacked_experts_normalize_by_own_count(self):
        """[E, d] sqnorm must divide by each expert's OWN row count;
        the old global-count divide deflated rarely-routed experts."""
        st = WeightStats(shape=(2, 2, 2), count=5,
                         sqnorm=np.full((2, 2), 4.0, np.float32),
                         count_e=np.array([4, 1], np.int64))
        norms = st.merge_norm()
        assert np.allclose(norms[0], 1.0)   # sqrt(4 / 4)
        assert np.allclose(norms[1], 2.0)   # sqrt(4 / 1), NOT sqrt(4/5)

    def test_dense_path_unchanged(self):
        st = WeightStats(shape=(2, 2), count=4,
                         sqnorm=np.full((2,), 16.0, np.float32))
        assert np.allclose(st.merge_norm(), 2.0)

    def test_on_matmul_accumulates_per_expert_counts(self):
        rec = Recorder(hessian=False)
        w = np.zeros((2, 3, 3), np.float32)         # stacked [E, d, d]
        rec._id2path[id(w)] = "moe.w"
        x = np.ones((2, 4, 3), np.float32)          # [E, C, d]
        rec._on_matmul(w, x, valid=np.array([4, 1]))
        rec._on_matmul(w, x, valid=np.array([2, 1]))
        st = rec.stats["moe.w"]
        assert st.count_e.tolist() == [6, 2]
        assert st.count == 8
        # all-ones rows: every expert's per-channel RMS is exactly 1
        # when (and only when) each divides by its own row count
        assert np.allclose(st.merge_norm(), 1.0)


# ---------------------------------------------------------------------------
# planner: engine="cascade" annotation + cost model (no engines needed)
# ---------------------------------------------------------------------------

def one_map_plan(budget=None):
    t = Table({"v": ["alpha", "beta", "gamma"]})
    return PLAN.LLMMap(input=PLAN.Scan(t), col="v", prompt="label: ",
                       out_col="o", max_new=4, accuracy_budget=budget)


def llm_op(pplan):
    ops = pplan.llm_ops
    assert len(ops) == 1
    return ops[0]


class TestPlannerCascade:
    def test_auto_cascades_when_cost_model_wins(self):
        op = llm_op(PHYS.lower(one_map_plan(), cascade_budget=0.2))
        assert op.engine == "cascade"
        assert op.accuracy_budget == 0.2
        assert op.est_escalation == OPT.predicted_escalation(0.2) < 1.0

    def test_no_budget_means_no_cascade(self):
        op = llm_op(PHYS.lower(one_map_plan()))
        assert op.engine == "optimized"
        assert op.accuracy_budget is None
        assert op.est_escalation == 1.0

    def test_auto_declines_uneconomical_budget(self):
        # budget 0.05 -> predicted escalation 1.0 -> cascade can't win
        assert not OPT.cascade_wins(0.05)
        op = llm_op(PHYS.lower(one_map_plan(), cascade_budget=0.05))
        assert op.engine == "optimized"

    def test_force_overrides_cost_model(self):
        op = llm_op(PHYS.lower(one_map_plan(), cascade_budget=0.05,
                               cascade="force"))
        assert op.engine == "cascade"

    def test_off_disables_cascade(self):
        op = llm_op(PHYS.lower(one_map_plan(), cascade_budget=0.2,
                               cascade="off"))
        assert op.engine == "optimized"

    def test_base_engine_never_cascades(self):
        # the proxy IS the instance-optimized model; without it there
        # is nothing to escalate FROM
        op = llm_op(PHYS.lower(one_map_plan(), optimize_models=False,
                               cascade_budget=0.2, cascade="force"))
        assert op.engine == "base"

    def test_node_budget_overrides_query_default(self):
        op = llm_op(PHYS.lower(one_map_plan(budget=0.3),
                               cascade_budget=0.1))
        assert op.engine == "cascade"
        assert op.accuracy_budget == 0.3

    def test_auto_never_cascades_budget_zero(self):
        # a zero budget predicts 100% escalation: the cost inequality
        # can never pick the cascade
        op = llm_op(PHYS.lower(one_map_plan(budget=0.0)))
        assert op.engine == "optimized"

    def test_force_cascades_budget_zero_for_exactness(self):
        # under "force" a budget-0 op still lowers as a cascade — the
        # threshold fits to inf and the op runs base-only (the
        # exactness contract, exercised end-to-end in TestQueryCascade)
        op = llm_op(PHYS.lower(one_map_plan(budget=0.0), cascade="force"))
        assert op.engine == "cascade"
        assert op.accuracy_budget == 0.0
        assert op.est_escalation == 1.0

    def test_invalid_mode_raises(self):
        with pytest.raises(ValueError, match="cascade"):
            PHYS.lower(one_map_plan(), cascade="always")

    def test_cost_model_boundaries(self):
        assert OPT.predicted_escalation(None) == 1.0
        assert OPT.predicted_escalation(0.0) == 1.0
        assert OPT.predicted_escalation(1e9) == pytest.approx(0.05)
        assert OPT.cascade_wins(0.2)
        assert not OPT.cascade_wins(None)


class TestProbeHonorsBound:
    def test_map_probe_bounded(self):
        t = Table({"v": [f"x{i}" for i in range(10)]})
        node = PLAN.LLMMap(input=PLAN.Scan(t), col="v", prompt="p: ",
                           out_col="o", max_new=4)
        assert len(PHYS.build_probe(node, t, 3)) == 3


# ---------------------------------------------------------------------------
# engine: the confidence signal the cascade thresholds on
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def conf_engine(tiny_dense):
    cfg, params = tiny_dense
    return Engine(params, cfg, slots=2, max_len=64, buckets=(32,))


class TestEngineConfidence:
    def test_finished_requests_carry_probability(self, conf_engine):
        reqs = conf_engine.generate_stream(
            ["alpha one", "beta two", "gamma three"], max_new=4,
            return_requests=True)
        for r in reqs:
            # min over per-token answer probabilities: a probability
            assert math.isfinite(r.confidence)
            assert 0.0 < r.confidence <= 1.0

    def test_follower_inherits_leader_confidence(self, conf_engine):
        # identical prompts in one batch: the follower never decodes,
        # it inherits the leader's text AND confidence at retire time
        reqs = conf_engine.generate_stream(["dup prompt", "dup prompt"],
                                           max_new=4, return_requests=True)
        assert reqs[0].text == reqs[1].text
        assert reqs[0].confidence == reqs[1].confidence

    def test_result_cache_hit_preserves_confidence(self, conf_engine):
        [first] = conf_engine.generate_stream(["cached row"], max_new=4,
                                              return_requests=True)
        hits0 = conf_engine.stats.cache_hits
        [second] = conf_engine.generate_stream(["cached row"], max_new=4,
                                               return_requests=True)
        assert conf_engine.stats.cache_hits == hits0 + 1
        assert second.text == first.text
        assert second.confidence == first.confidence

    def test_stats_expose_mean_confidence(self, tiny_dense):
        cfg, params = tiny_dense
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(32,))
        eng.generate(["one", "two", "three"], max_new=4)
        st = eng.stats
        assert st.confidence_rows == 3
        assert 0.0 < st.mean_confidence <= 1.0


# ---------------------------------------------------------------------------
# serial executor: Query._run_cascade
# ---------------------------------------------------------------------------

def pin_threshold(monkeypatch, sess, threshold):
    """Replace the fitted calibration with a fixed acceptance cut so
    the escalation split is deterministic for byte-identity checks."""
    cal = CascadeCalibration(threshold=threshold,
                             expected_escalation=1.0,
                             accuracy_budget=0.5, n_fit=0)
    monkeypatch.setattr(sess, "_cascade",
                        lambda qsig, prompts, budget, **kw: cal)


class TestQueryCascade:
    def test_unfit_threshold_degenerates_to_base_only(self, tiny,
                                                      monkeypatch):
        """threshold = inf (budget 0 / unsatisfiable): the proxy pass
        is skipped and every row is answered by the same greedy base
        decode a base-only run uses — byte-identical output."""
        base = base_only_outputs(tiny)
        sess = make_session(tiny)
        pin_threshold(monkeypatch, sess, float("inf"))
        q = cascade_query(sess)
        out = q.run()["lang_fixed"]
        assert out == base
        (st,) = q.last_run_stats
        assert st.engine == "cascade"
        assert st.escalated == len(VALS)

    def test_budget_zero_is_byte_identical_to_base_only(self, tiny):
        """The exactness contract through the PUBLIC API, no patching:
        accuracy budget 0 -> every row escalates -> output bytes equal
        a base-only run's."""
        base = base_only_outputs(tiny)
        sess = make_session(tiny)
        q = cascade_query(sess, cascade_budget=0.0)
        out = q.run()["lang_fixed"]
        assert out == base
        (st,) = q.last_run_stats
        assert st.engine == "cascade"
        assert st.escalated == len(VALS)
        assert math.isinf(st.threshold)

    def test_accept_all_matches_proxy_only(self, tiny, monkeypatch):
        proxy = proxy_only_outputs(tiny)
        sess = make_session(tiny)
        pin_threshold(monkeypatch, sess, 0.0)   # conf >= 0 always
        q = cascade_query(sess)
        out = q.run()["lang_fixed"]
        assert out == proxy
        (st,) = q.last_run_stats
        assert st.escalated == 0

    def test_every_row_is_proxy_or_base_answer(self, tiny):
        """End-to-end with a REAL fitted threshold: each output row is
        byte-identical to the proxy's answer (accepted) or the base
        model's answer (escalated) — never a third thing."""
        base = base_only_outputs(tiny)
        proxy = proxy_only_outputs(tiny)
        sess = make_session(tiny)
        q = cascade_query(sess)
        out = q.run()["lang_fixed"]
        (st,) = q.last_run_stats
        assert st.engine == "cascade"
        assert st.threshold is not None
        assert 0 <= st.escalated <= len(VALS)
        for o, p, b in zip(out, proxy, base):
            assert o in (p, b)
            if o != p:                      # escalated row
                assert o == b               # ... is byte-identical base
        assert any("[cascade]" in line for line in sess.log)

    def test_calibration_is_memoized(self, tiny):
        sess = make_session(tiny)
        prompts = [f"fix: categ{i}" for i in range(8)]
        a = sess._cascade("q1", prompts, 0.5, max_new=4)
        n_log = len(sess.log)
        b = sess._cascade("q1", prompts, 0.5, max_new=4)
        assert b is a
        assert len(sess.log) == n_log       # no second fit logged
        assert a.accuracy_budget == 0.5

    def test_budget_zero_fit_is_degenerate(self, tiny):
        sess = make_session(tiny)
        cal = sess._cascade("q0", ["fix: a", "fix: b"], 0.0, max_new=4)
        assert math.isinf(cal.threshold)
        assert cal.expected_escalation == 1.0

    def test_explain_renders_cascade_annotations(self, tiny):
        sess = make_session(tiny)
        q = cascade_query(sess, cascade_budget=0.2)
        txt = q.explain()
        assert "engine=cascade" in txt
        assert "budget=0.2" in txt
        assert "est_escalation=" in txt
        assert "threshold=unfit" in txt     # nothing calibrated yet
        q.run()
        txt = q.explain()
        assert "threshold=unfit" not in txt # the fitted cut now renders
        assert "threshold=" in txt


# ---------------------------------------------------------------------------
# pooled scheduler: two-phase cascade submissions
# ---------------------------------------------------------------------------

class TestSchedulerCascade:
    def test_run_queries_matches_serial_cascade(self, tiny):
        pooled = make_session(tiny, pool_budget=64 * 1024 * 1024)
        q = cascade_query(pooled)
        res = Scheduler(pooled.pool, share=2).run_queries({"a": q})
        serial = cascade_query(make_session(tiny))
        assert res["a"]["lang_fixed"] == serial.run()["lang_fixed"]

    def test_run_queries_unfit_threshold_is_base_only(self, tiny,
                                                      monkeypatch):
        base = base_only_outputs(tiny)
        pooled = make_session(tiny, pool_budget=64 * 1024 * 1024)
        pin_threshold(monkeypatch, pooled, float("inf"))
        q = cascade_query(pooled)
        res = Scheduler(pooled.pool, share=2).run_queries({"a": q})
        assert res["a"]["lang_fixed"] == base
