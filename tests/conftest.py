import os

# keep tests on 1 CPU device (the dry-run sets its own 512-device flag in a
# subprocess); cap compilation parallelism for the single-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.models import api  # noqa: E402


FAST_ARCHS = ("mistral-nemo-12b", "gemma2-2b", "qwen2-moe-a2.7b",
              "rwkv6-3b", "zamba2-7b", "whisper-base")


@pytest.fixture(scope="session")
def tiny_dense():
    """Shared 2-layer dense test model, built once for the whole run
    (test_scheduler and test_iolm_session both optimize/serve it)."""
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="session")
def reduced_models():
    """One initialized reduced model per arch (shared across tests)."""
    out = {}
    key = jax.random.PRNGKey(0)
    for arch in registry.ARCH_IDS:
        cfg = registry.get_reduced(arch)
        out[arch] = (cfg, api.init_params(key, cfg))
    return out


def make_batch(cfg, B=2, S=64, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, S),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(key + 1),
                                          (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_inputs"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.enc_ctx, cfg.d_model),
            cfg.dtype)
    if cfg.family == "vlm":
        n = cfg.n_img_tokens
        batch["img_embs"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, n, cfg.d_model), cfg.dtype) * 0.1
        batch["tokens"] = batch["tokens"][:, : S - n]
        batch["labels"] = batch["labels"][:, : S - n]
    return batch
