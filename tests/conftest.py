import os

# CPU platform, forced to 4 host devices so the device-parallel serving
# tests (test_device_parallel.py) exercise real multi-device placement
# in-process.  Everything else still runs on device 0 by default, and
# the dry-run subprocesses (test_distributed.py) override XLA_FLAGS
# with their own 8/512-device values.  An operator-set XLA_FLAGS that
# already forces a device count wins; the quad_devices fixture below
# then skips (not fails) when fewer than 4 devices came up.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.models import api  # noqa: E402


FAST_ARCHS = ("mistral-nemo-12b", "gemma2-2b", "qwen2-moe-a2.7b",
              "rwkv6-3b", "zamba2-7b", "whisper-base")


@pytest.fixture(scope="session")
def quad_devices():
    """The first 4 CPU devices of the forced multi-device platform;
    skip-not-fail when the platform came up with fewer (e.g. an
    operator-set XLA_FLAGS overrode the conftest default)."""
    devs = jax.devices()
    if len(devs) < 4:
        pytest.skip("needs >= 4 jax devices (run with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    return devs[:4]


@pytest.fixture(scope="session")
def tiny_dense():
    """Shared 2-layer dense test model, built once for the whole run
    (test_scheduler and test_iolm_session both optimize/serve it)."""
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="session")
def reduced_models():
    """One initialized reduced model per arch (shared across tests)."""
    out = {}
    key = jax.random.PRNGKey(0)
    for arch in registry.ARCH_IDS:
        cfg = registry.get_reduced(arch)
        out[arch] = (cfg, api.init_params(key, cfg))
    return out


def make_batch(cfg, B=2, S=64, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, S),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(key + 1),
                                          (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_inputs"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.enc_ctx, cfg.d_model),
            cfg.dtype)
    if cfg.family == "vlm":
        n = cfg.n_img_tokens
        batch["img_embs"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, n, cfg.d_model), cfg.dtype) * 0.1
        batch["tokens"] = batch["tokens"][:, : S - n]
        batch["labels"] = batch["labels"][:, : S - n]
    return batch
