"""Structural pruning: shapes, config updates, stats re-slicing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import registry
from repro.core import prune as P
from repro.core.calibrate import calibrate
from repro.models import api


def _calib(arch, reduced_models, B=2, S=48):
    cfg, params = reduced_models[arch]
    batch = make_batch(cfg, B=B, S=S)
    return cfg, params, batch, calibrate(params, cfg, batch)


def test_kv_group_prune(reduced_models):
    cfg, params, batch, stats = _calib("mistral-nemo-12b", reduced_models)
    p2, c2, st2 = P.prune_kv_groups(params, cfg, stats, keep=2)
    assert c2.n_kv_heads == 2
    assert c2.n_heads == 2 * (cfg.n_heads // cfg.n_kv_heads)
    # head_dim must be pinned (n_heads change would alter d_model//n_heads)
    assert c2.resolved_head_dim == cfg.resolved_head_dim
    logits, _ = api.forward(p2, c2, batch)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    # stats for wo re-sliced to the kept channels
    k = sorted(k for k in st2.weights if k.endswith("attn.wo"))[0]
    hd = c2.resolved_head_dim
    assert st2.weights[k].H.shape[0] == c2.n_heads * hd


def test_ffn_prune_all_families(reduced_models):
    for arch in ("mistral-nemo-12b", "qwen2-moe-a2.7b", "rwkv6-3b",
                 "zamba2-7b", "whisper-base"):
        cfg, params, batch, stats = _calib(arch, reduced_models)
        p2, c2, _ = P.prune_ffn(params, cfg, stats, keep_frac=0.75)
        logits, _ = api.forward(p2, c2, batch)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32)))), arch


def test_layer_drop_scores_pick_most_redundant(reduced_models):
    cfg, params, batch, stats = _calib("mistral-nemo-12b", reduced_models)
    R = cfg.n_layers
    p2, c2, _ = P.drop_layers(params, cfg, stats, 1)
    assert c2.n_layers == R - 1
    logits, _ = api.forward(p2, c2, batch)
    assert logits.shape[-1] == cfg.vocab_size


def test_expert_prune_uses_routing_stats(reduced_models):
    cfg, params, batch, stats = _calib("qwen2-moe-a2.7b", reduced_models)
    key = sorted(k for k in stats.weights if k.endswith("moe.router"))[0]
    assert stats.weights[key].route_count is not None
    p2, c2, _ = P.prune_experts(params, cfg, stats, keep_e=4)
    assert c2.n_experts == 4
    logits, _ = api.forward(p2, c2, batch)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


def test_expert_prune_keeps_most_routed(reduced_models):
    """Experts kept must be the top-routed ones from calibration."""
    cfg, params, batch, stats = _calib("qwen2-moe-a2.7b", reduced_models)
    key = sorted(k for k in stats.weights if k.endswith("moe.router"))[0]
    counts = stats.weights[key].route_count.copy()
    p2, c2, st2 = P.prune_experts(params, cfg, stats, keep_e=3)
    kept_counts = st2.weights[key].route_count
    # kept experts are the 3 largest original counts
    assert set(np.sort(kept_counts)) <= set(np.sort(counts)[-4:])


def test_prune_composes_with_decode(reduced_models):
    cfg, params, batch, stats = _calib("gemma2-2b", reduced_models)
    p2, c2, st2 = P.drop_layers(params, cfg, stats, 2)
    p2, c2, st2 = P.prune_ffn(p2, c2, st2, 0.5)
    cache = api.init_cache(c2, 2, 64)
    lg, cache = api.decode_step(p2, c2, cache, batch["tokens"][:, :1],
                                jnp.zeros((2,), jnp.int32), max_len=64)
    assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32))))
    assert len(c2.pattern()) == c2.n_layers


def test_rwkv_head_prune_is_noop(reduced_models):
    """Attention-head pruning is inapplicable to rwkv — must be an
    identity, not an error."""
    cfg, params, batch, stats = _calib("rwkv6-3b", reduced_models)
    p2, c2, _ = P.prune_kv_groups(params, cfg, stats, keep=1)
    assert c2.n_kv_heads == cfg.n_kv_heads
    assert jax.tree.structure(p2) == jax.tree.structure(params)
