"""Device-parallel serving: per-device pool budgets, placement policy,
sharded (TP) admission, and the scheduler tick fan-out.

Pool mechanics run on fake engines/devices (no model compute); the
byte-identity and TP tests build real engines on the forced 4-device
CPU platform (conftest) and skip-not-fail when it is unavailable.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serving.scheduler import ModelPool, PoolBudgetError, Scheduler

from test_scheduler import FakeEngine, FakeSession


# ---------------------------------------------------------------------------
# fakes: device-aware pool mechanics without jax devices
# ---------------------------------------------------------------------------

class PlacedFakeEngine(FakeEngine):
    def __init__(self, version, slots=2, device=None, mesh=None):
        super().__init__(version, slots=slots)
        self.device = device
        self.mesh = mesh


def fake_mesh(n):
    """Duck-typed Mesh: the pool only reads ``.devices.flat``."""
    return SimpleNamespace(devices=np.array([f"dev{i}" for i in range(n)],
                                            dtype=object))


def placed_pool(sizes, budget, *, ndev=3, mesh=False, slots=2,
                placement="least_loaded"):
    sess = FakeSession(sizes)
    kw = dict(
        engine_factory=lambda m, device=None, mesh=None: PlacedFakeEngine(
            m.version, slots=slots, device=device, mesh=mesh),
        entry_bytes=lambda m: sizes[m.version],
        placement=placement)
    if mesh:
        pool = ModelPool(sess, budget, mesh=fake_mesh(ndev), **kw)
    else:
        pool = ModelPool(sess, budget,
                         devices=[f"dev{i}" for i in range(ndev)], **kw)
    return sess, pool


class TestPerDeviceBudget:
    def test_budget_is_per_device_hard_invariant(self):
        """Any admission sequence keeps every device's charged bytes
        within the per-device budget."""
        sizes = {f"m{i}": 30 + 7 * (i % 3) for i in range(12)}
        _, pool = placed_pool(sizes, budget=100, ndev=3)
        for i in range(12):
            try:
                pool.engine_for(f"m{i}")
            except PoolBudgetError:
                pass
            for d in range(3):
                assert pool.device_bytes(d) <= pool.byte_budget

    def test_capacity_scales_with_device_count(self):
        sizes = {f"m{i}": 40 for i in range(8)}
        _, pool1 = placed_pool(sizes, budget=100, ndev=1)
        _, pool4 = placed_pool(sizes, budget=100, ndev=4)
        for i in range(8):
            pool1.engine_for(f"m{i}")
            pool4.engine_for(f"m{i}")
        assert len(pool1) == 2          # 2 x 40 <= 100
        assert len(pool4) == 8          # 2 per device x 4 devices

    def test_least_loaded_placement_spreads_and_is_deterministic(self):
        sizes = {f"m{i}": 40 for i in range(6)}
        placements = []
        for _ in range(2):
            _, pool = placed_pool(sizes, budget=100, ndev=3)
            for i in range(6):
                pool.engine_for(f"m{i}")
            placements.append([pool.placement_of(f"m{i}")[0]
                               for i in range(6)])
        # identical replay -> identical placement (lowest index ties)
        assert placements[0] == placements[1]
        # least-loaded spreads before stacking: first 3 land on 3
        # distinct devices, next 3 fill them up again in the same order
        assert placements[0] == [0, 1, 2, 0, 1, 2]

    def test_eviction_is_per_device_lru(self):
        """Filling device 0 twice over evicts only ITS resident; other
        devices' warm engines survive."""
        sizes = {"a": 80, "b": 80, "c": 80, "d": 80}
        _, pool = placed_pool(sizes, budget=100, ndev=3)
        for v in ("a", "b", "c"):       # one per device
            pool.engine_for(v)
        pool.engine_for("d")            # least-loaded tie -> device 0
        assert pool.eviction_log == ["a"]
        assert pool.placement_of("d") == (0,)
        assert pool.resident_versions == ["b", "c", "d"]

    def test_pinned_devices_block_retryable(self):
        sizes = {"a": 80, "b": 80}
        _, pool = placed_pool(sizes, budget=100, ndev=1)
        pool.engine_for("a")
        pool.pin("a")
        with pytest.raises(PoolBudgetError) as ei:
            pool.engine_for("b")
        assert ei.value.retryable
        pool.unpin("a")
        pool.engine_for("b")
        assert pool.eviction_log == ["a"]


class TestAffinityPlacement:
    def test_readmission_returns_home(self):
        """Affinity: an evicted version re-admits to its previous
        device, so same-placement caches stay reusable."""
        sizes = {"a": 80, "b": 80, "c": 80, "d": 80}
        _, pool = placed_pool(sizes, budget=100, ndev=2,
                              placement="affinity")
        pool.engine_for("a")            # dev 0
        pool.engine_for("b")            # dev 1
        home_a = pool.placement_of("a")[0]
        pool.engine_for("c")            # evicts a (LRU on its device)
        assert "a" not in pool.resident_versions
        pool.engine_for("a")            # back home, evicting c
        assert pool.placement_of("a") == (home_a,)

    def test_affinity_falls_back_when_home_pinned(self):
        sizes = {"a": 80, "b": 80, "c": 80}
        _, pool = placed_pool(sizes, budget=100, ndev=2,
                              placement="affinity")
        pool.engine_for("a")
        pool.engine_for("c")            # dev 1 (least loaded)
        pool.pin("a")                   # dev 0 fully pinned
        pool.engine_for("b")            # must land on dev 1, evicting c
        assert pool.placement_of("b") == (1,)
        assert "c" not in pool.resident_versions
        pool.engine_for("c")            # re-admits to its home, dev 1
        assert pool.placement_of("c") == (1,)


class TestShardedAdmission:
    def test_oversize_without_mesh_is_unretryable(self):
        _, pool = placed_pool({"big": 250}, budget=100, ndev=3)
        with pytest.raises(PoolBudgetError) as ei:
            pool.engine_for("big")
        assert not ei.value.retryable

    def test_oversize_with_mesh_shards_across_all_devices(self):
        sizes = {"big": 250, "small": 10}
        _, pool = placed_pool(sizes, budget=100, ndev=3, mesh=True)
        eng = pool.engine_for("big")
        assert eng.mesh is not None and eng.device is None
        assert pool.placement_of("big") == (0, 1, 2)
        assert pool.stats.sharded_admissions == 1
        # ceil(250/3) = 84 charged per device
        for d in range(3):
            assert pool.device_bytes(d) == 84
        # a replica still places beside the sharded entry (84+10 <= 100)
        small = pool.engine_for("small")
        assert small.device is not None and small.mesh is None
        assert len(pool.placement_of("small")) == 1
        assert "big" in pool.resident_versions

    def test_sharded_beyond_mesh_is_unretryable(self):
        _, pool = placed_pool({"huge": 1000}, budget=100, ndev=3,
                              mesh=True)
        with pytest.raises(PoolBudgetError) as ei:
            pool.engine_for("huge")     # ceil(1000/3) > 100
        assert not ei.value.retryable

    def test_sharded_eviction_frees_every_device(self):
        sizes = {"big": 250, "a": 90, "b": 90, "c": 90}
        _, pool = placed_pool(sizes, budget=100, ndev=3, mesh=True)
        pool.engine_for("big")
        for v in ("a", "b", "c"):       # each needs 90: big must go
            pool.engine_for(v)
        assert "big" not in pool.resident_versions
        assert pool.eviction_log[0] == "big"
        assert {pool.placement_of(v)[0] for v in "abc"} == {0, 1, 2}


class TestSchedulerFanOut:
    def test_fake_engines_without_split_still_work(self):
        """Engines lacking step_begin/step_finish (fakes, remote
        backends) fall back to whole step() inside the fan-out tick —
        and, running serially, never count as concurrent devices."""
        sizes = {"a": 40, "b": 40}
        _, pool = placed_pool(sizes, budget=100, ndev=2)
        sched = Scheduler(pool, share=2)
        sa = sched.submit("ta", ["x", "yy"], qsig="a")
        sb = sched.submit("tb", ["zzz"], qsig="b")
        sched.run()
        assert sa.results() == ["out(x)", "out(yy)"]
        assert sb.results() == ["out(zzz)"]
        assert sched.stats.peak_concurrent_devices == 1


# ---------------------------------------------------------------------------
# real engines on the forced 4-device platform
# ---------------------------------------------------------------------------

ENGINE_KW = dict(slots=2, max_len=64, buckets=(24,))


class _SameParamsSession:
    """Duck-typed session: every qsig resolves to the SAME params under
    a distinct version, so the pool builds real engines per tenant
    without paying a compression search."""

    def __init__(self, params, cfg, tok):
        self.params, self.cfg, self.tok = params, cfg, tok

    def _optimize(self, qsig, probe):
        return SimpleNamespace(params=self.params, cfg=self.cfg,
                               version=qsig)


@pytest.fixture(scope="module")
def quad_pool_env(tiny_dense):
    from repro.training.data import ByteTokenizer
    cfg, params = tiny_dense
    tok = ByteTokenizer(max(cfg.vocab_size, 260))
    return cfg, params, tok


def test_fanout_outputs_byte_identical_to_serial(quad_pool_env,
                                                 quad_devices):
    """Tenants placed on 4 distinct devices, stepped with the
    dispatch-all-then-collect fan-out, produce exactly the tokens each
    would get on a private single-device engine run serially."""
    from repro.core.compressed import param_bytes
    from repro.serving.engine import Engine
    from repro.serving.scheduler import slot_state_bytes
    cfg, params, tok = quad_pool_env
    entry = (param_bytes(params)
             + ENGINE_KW["slots"] * slot_state_bytes(cfg,
                                                     ENGINE_KW["max_len"]))
    sess = _SameParamsSession(params, cfg, tok)
    pool = ModelPool(sess, int(1.5 * entry), engine_kw=ENGINE_KW,
                     devices=quad_devices)
    sched = Scheduler(pool, share=2)
    prompts = {f"t{i}": [f"tenant {i} row {j}" for j in range(3)]
               for i in range(4)}
    subs = [sched.submit(t, ps, qsig=t, max_new=8)
            for t, ps in prompts.items()]
    sched.run()
    # all 4 tenants resident, one per device, stepped concurrently
    assert len(pool) == 4
    assert sorted(pool.placement_of(f"t{i}")[0] for i in range(4)) \
        == [0, 1, 2, 3]
    assert sched.stats.peak_concurrent_devices == 4
    for sub in subs:
        ref = Engine(params, cfg, tokenizer=tok, version=sub.qsig,
                     **ENGINE_KW).generate(prompts[sub.tenant], max_new=8)
        assert sub.results() == ref


def test_tp_engine_coexists_and_matches_serial_mesh_run(quad_pool_env,
                                                        quad_devices):
    """A tensor-parallel (mesh-sharded) engine admitted beside
    single-device replicas: scheduler outputs equal a private engine
    with the SAME placement run serially (the byte-identity contract
    is about scheduling, not numerics-across-placements)."""
    import jax
    from repro.serving.engine import Engine
    cfg, params, tok = quad_pool_env
    mesh = jax.make_mesh((1, 4), ("data", "model"), devices=quad_devices)
    sess = _SameParamsSession(params, cfg, tok)
    # big shards at ceil(300/4)=75 per device, leaving 25: the smalls
    # (20) coexist beside it instead of queueing behind its pins
    sizes = {"big": 300, "small0": 20, "small1": 20}
    pool = ModelPool(sess, 100, engine_kw=ENGINE_KW, mesh=mesh,
                     entry_bytes=lambda m: sizes[m.version])
    sched = Scheduler(pool, share=2)
    prompts = {"big": ["alpha row", "beta row"],
               "small0": ["gamma row"], "small1": ["delta row"]}
    subs = [sched.submit(v, ps, qsig=v, max_new=8)
            for v, ps in prompts.items()]
    sched.run()
    assert pool.stats.sharded_admissions == 1
    assert pool.placement_of("big") == (0, 1, 2, 3)
    for sub in subs:
        kw = dict(ENGINE_KW)
        if sub.qsig == "big":
            kw["mesh"] = jax.make_mesh((1, 4), ("data", "model"),
                                       devices=quad_devices)
        ref = Engine(params, cfg, tokenizer=tok, version=sub.qsig,
                     **kw).generate(prompts[sub.tenant], max_new=8)
        assert sub.results() == ref


def test_tp_greedy_decode_matches_single_device(quad_pool_env,
                                                quad_devices):
    """Greedy decode through the TP-sharded engine reproduces the
    single-device token stream on this pinned jax version (tiny dims
    divide the model axis; GSPMD psum order is stable on CPU)."""
    import jax
    from repro.serving.engine import Engine
    cfg, params, tok = quad_pool_env
    mesh = jax.make_mesh((1, 4), ("data", "model"), devices=quad_devices)
    texts = ["hello tensor parallel", "another row"]
    tp = Engine(params, cfg, tokenizer=tok, mesh=mesh,
                **ENGINE_KW).generate(texts, max_new=8)
    single = Engine(params, cfg, tokenizer=tok,
                    **ENGINE_KW).generate(texts, max_new=8)
    assert tp == single


def test_prefix_cache_keys_isolated_per_placement(quad_pool_env,
                                                  quad_devices):
    """One shared PrefixCache across engines on different devices must
    never hand device-A state to a device-B engine: placement is part
    of the key, so each placement prefills its own entry."""
    from repro.serving.cache import PrefixCache
    from repro.serving.engine import Engine
    cfg, params, tok = quad_pool_env
    shared = PrefixCache(capacity=8)
    tmpl = "fix this value: "
    outs = []
    for d in quad_devices[:2]:
        eng = Engine(params, cfg, tokenizer=tok, device=d,
                     prefix_cache=shared, **ENGINE_KW)
        outs.append(eng.generate([tmpl + "pyton", tmpl + "jva"],
                                 max_new=6, prefix=tmpl))
    assert outs[0] == outs[1]           # same params, same tokens
    assert len(shared) == 2             # one entry per placement, no mix
