"""Static plan verifier + diagnostics framework.

The verifier (olap/analysis.py) must re-prove every rewrite the
optimizer ships (zero diagnostics on real workloads) AND reject seeded
illegal rewrites with stable codes — the second half is a mutation
test of the first: a verifier that accepts everything would pass the
positive tests trivially.
"""
import dataclasses

import pytest

from repro.analysis import diagnostics as D
from repro.olap import analysis as ANA
from repro.olap import optimizer as OPT
from repro.olap import physical as PHYS
from repro.olap import plan as P
from repro.olap.table import Table


def table():
    return Table({"category": ["a", "b", "a", "a", "c", "b", "a", "c"],
                  "status": ["ok", "bad", "ok", "bad", "ok", "ok",
                             "bad", "ok"]})


def unique_table():
    return Table({"category": [f"u{i}" for i in range(8)]})


def llm_map(inp, *, prompt="label: ", out="label", col="category",
            new=8, dedup=False):
    return P.LLMMap(input=inp, col=col, prompt=prompt, out_col=out,
                    max_new=new, dedup=dedup)


def codes(diags):
    return sorted(d.code for d in diags)


# ---------------------------------------------------------------------------
# positive: every optimizer output proves clean
# ---------------------------------------------------------------------------

class TestVerifierAcceptsOptimizer:
    def _workloads(self):
        t, scan = table(), P.Scan(table())
        return {
            "pushdown": P.Filter(input=llm_map(P.Scan(t)),
                                 pred=lambda r: r["status"] == "ok",
                                 columns=frozenset({"status"})),
            "fusion": llm_map(llm_map(scan), out="label2"),
            "dedup": llm_map(P.Scan(t)),
            "mixed": P.Filter(
                input=P.LLMFilter(input=llm_map(P.Scan(t)), col="status",
                                  prompt="keep? ", max_new=2),
                pred=lambda r: r["status"] == "ok",
                columns=frozenset({"status"})),
        }

    @pytest.mark.parametrize("name", ["pushdown", "fusion", "dedup",
                                      "mixed"])
    def test_zero_diagnostics_on_real_workloads(self, name):
        plan = self._workloads()[name]
        assert ANA.verify_plan(plan) == []
        optimized, firings = OPT.optimize(plan, verify=True)
        assert firings, f"workload {name!r} should fire at least one rule"
        assert all(f.verified for f in firings)
        assert ANA.verify_plan(optimized) == []

    def test_lower_runs_both_verify_passes(self):
        plan = self._workloads()["pushdown"]
        pplan = PHYS.lower(plan)
        assert all(f.verified for f in pplan.firings)

    def test_every_rewrite_reproved_per_firing(self):
        """Each intermediate rewrite is individually proved — not just
        the final plan — by replaying the firing sequence."""
        plan = self._workloads()["mixed"]
        optimized, firings = OPT.optimize(plan, verify=True)
        assert len(firings) >= 2   # multi-step: dedup + pushdown at least


# ---------------------------------------------------------------------------
# negative: seeded illegal rewrites are rejected with stable codes
# ---------------------------------------------------------------------------

class TestVerifierRejectsIllegalRewrites:
    def test_pushdown_past_consumed_column_PLAN012(self):
        scan = P.Scan(table())
        m = llm_map(scan)                       # writes "label"
        filt = P.Filter(input=m, pred=lambda r: r["label"] == "x",
                        columns=frozenset({"label"}))   # reads it!
        illegal = P.with_child(m, P.with_child(filt, scan))
        diags = ANA.verify_rewrite(filt, illegal, "pushdown")
        assert "PLAN012" in codes(diags)
        # below the map the filter's read set no longer resolves
        assert "PLAN004" in codes(diags)

    def test_pushdown_across_join_PLAN011(self):
        scan = P.Scan(table())
        join = P.LLMJoin(input=scan, right=Table({"name": ["a", "b"]}),
                         on=("category", "name"), prompt="match? ",
                         max_new=2)
        filt = P.Filter(input=join, pred=lambda r: True,
                        columns=frozenset({"l_status"}))
        illegal = P.with_child(join, P.with_child(
            dataclasses.replace(filt, columns=frozenset({"status"})),
            scan))
        diags = ANA.verify_rewrite(filt, illegal, "pushdown")
        # the filter's columns changed, so the window is not a pure
        # swap — shape violation is the loud failure here
        assert set(codes(diags)) & {"PLAN010", "PLAN011"}

    def test_opaque_filter_pushdown_PLAN013(self):
        scan = P.Scan(table())
        m = llm_map(scan)
        filt = P.Filter(input=m, pred=lambda r: True, columns=None)
        illegal = P.with_child(m, P.with_child(filt, scan))
        diags = ANA.verify_rewrite(filt, illegal, "pushdown")
        assert "PLAN013" in codes(diags)

    def test_fusion_across_differing_templates_PLAN031(self):
        scan = P.Scan(table())
        lower = llm_map(scan, prompt="a: ", out="l1")
        upper = llm_map(lower, prompt="b: ", out="l2")
        fused = P.LLMFused(input=scan, col="category", prompt="b: ",
                           outs=("l1", "l2"), max_new=8, src_kind="map")
        diags = ANA.verify_rewrite(upper, fused, "fusion")
        assert "PLAN031" in codes(diags)

    def test_fusion_across_data_dependency_PLAN033(self):
        scan = P.Scan(table())
        lower = llm_map(scan, prompt="p: ", out="label")
        upper = llm_map(lower, prompt="p: ", col="label", out="l2")
        fused = P.LLMFused(input=scan, col="label", prompt="p: ",
                           outs=("label", "l2"), max_new=8,
                           src_kind="map")
        diags = ANA.verify_rewrite(upper, fused, "fusion")
        assert "PLAN033" in codes(diags)

    def test_fusion_wrong_outs_order_PLAN032(self):
        scan = P.Scan(table())
        lower = llm_map(scan, out="l1")
        upper = llm_map(lower, out="l2")
        fused = P.LLMFused(input=scan, col="category", prompt="label: ",
                           outs=("l2", "l1"),    # reversed!
                           max_new=8, src_kind="map")
        diags = ANA.verify_rewrite(upper, fused, "fusion")
        assert "PLAN032" in codes(diags)

    def test_dedup_without_duplicates_PLAN022(self):
        before = llm_map(P.Scan(unique_table()))
        after = dataclasses.replace(before, dedup=True)
        diags = ANA.verify_rewrite(before, after, "dedup")
        assert "PLAN022" in codes(diags)

    def test_dedup_on_derived_column_PLAN021(self):
        scan = P.Scan(table())
        lower = llm_map(scan, out="label")
        upper = llm_map(lower, col="label", out="l2")
        annotated = P.with_child(
            dataclasses.replace(upper, dedup=True), lower)
        diags = ANA.verify_rewrite(upper, annotated, "dedup")
        assert "PLAN021" in codes(diags)

    def test_dedup_window_smuggling_PLAN020(self):
        """A 'dedup' rewrite that also changes the prompt is rejected:
        the window differs by more than the annotation."""
        before = llm_map(P.Scan(table()))
        after = dataclasses.replace(before, dedup=True, prompt="other: ")
        diags = ANA.verify_rewrite(before, after, "dedup")
        assert "PLAN020" in codes(diags)

    def test_unknown_rule_PLAN099(self):
        plan = llm_map(P.Scan(table()))
        diags = ANA.verify_rewrite(plan, plan, "hoist")
        assert "PLAN099" in codes(diags)

    def test_schema_change_PLAN001(self):
        scan = P.Scan(table())
        before = llm_map(scan)
        diags = ANA.verify_rewrite(before, scan, "pushdown")
        assert "PLAN001" in codes(diags)


class TestVerifierWiring:
    def test_buggy_rule_raises_at_optimize_time(self, monkeypatch):
        """A rule whose rewrite is illegal can never ship a plan: the
        always-on verify mode raises with the structured proof."""
        def bogus(plan, stats):
            # claims to be dedup but swaps the prompt too
            nodes = P.chain(plan)
            bad = dataclasses.replace(nodes[0], dedup=True, prompt="!!")
            return [("bogus", P.rebuild([bad] + nodes[1:]))]
        monkeypatch.setattr(OPT, "RULES", (("dedup", bogus),))
        with pytest.raises(ANA.PlanVerificationError) as ei:
            OPT.optimize(llm_map(P.Scan(table())), verify=True)
        assert any(d.code in ("PLAN020", "PLAN001")
                   for d in ei.value.diagnostics)

    def test_lower_rejects_hand_mutated_plan(self):
        """A hand-annotated illegal plan is stopped by the pre-verify
        pass in physical.lower, before any engine runs."""
        illegal = llm_map(P.Scan(unique_table()), dedup=True)
        with pytest.raises(ANA.PlanVerificationError) as ei:
            PHYS.lower(illegal, use_optimizer=False)
        assert any(d.code == "PLAN022" for d in ei.value.diagnostics)

    def test_verify_off_lets_illegal_plan_through(self):
        """verify=False exists for the verifier's own tests; it must
        actually bypass the check."""
        illegal = llm_map(P.Scan(unique_table()), dedup=True)
        pplan = PHYS.lower(illegal, use_optimizer=False, verify=False)
        assert pplan.llm_ops[0].dedup


# ---------------------------------------------------------------------------
# diagnostics framework
# ---------------------------------------------------------------------------

class TestDiagnostics:
    def test_unknown_code_rejected(self):
        with pytest.raises(ValueError, match="unknown diagnostic code"):
            D.Diagnostic("PLAN999", "m", "loc")

    def test_bad_severity_rejected(self):
        with pytest.raises(ValueError, match="severity"):
            D.Diagnostic("PLAN001", "m", "loc", severity="fatal")

    def test_fingerprint_stable_and_content_addressed(self):
        a = D.Diagnostic("PLAN001", "m", "loc")
        b = D.Diagnostic("PLAN001", "m", "loc", hint="different hint")
        c = D.Diagnostic("PLAN001", "m", "other")
        assert a.fingerprint() == b.fingerprint()   # hint not hashed
        assert a.fingerprint() != c.fingerprint()

    def test_render_text_lists_code_and_hint(self):
        txt = D.render_text([D.Diagnostic("PLAN022", "no dups",
                                          "optimizer.dedup", hint="drop")])
        assert "PLAN022" in txt and "hint: drop" in txt
        assert "1 error(s)" in txt

    def test_baseline_gates_only_new_findings(self, tmp_path):
        old = D.Diagnostic("PLAN022", "old", "a")
        new = D.Diagnostic("PLAN022", "new", "b")
        info = D.Diagnostic("JIT004", "weak", "c", severity="info")
        path = str(tmp_path / "base.json")
        D.save_baseline(path, [old])
        base = D.load_baseline(path)
        assert base.is_known(old) and not base.is_known(new)
        assert base.new_findings([old, new, info]) == [new]

    def test_baseline_code_suppression(self, tmp_path):
        path = str(tmp_path / "base.json")
        D.save_baseline(path, [], suppress_codes=["JIT008"],
                        suppress_reasons={"JIT008": "cpu cost model"})
        base = D.load_baseline(path)
        d = D.Diagnostic("JIT008", "anything", "anywhere",
                         severity="warning")
        assert base.is_known(d)
        assert base.new_findings([d]) == []
