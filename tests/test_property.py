"""Property-based tests (hypothesis) on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping, not aborting collection")
from hypothesis import given, settings, strategies as st

from repro.core import quantize as Q
from repro.core import sparsify as S
from repro.core.compressed import pack_int4, QTensor
from repro.serving.cache import ResultCache
from repro.serving.batcher import Batcher, Request, bucket_len
from repro.training.data import ByteTokenizer

SETTINGS = dict(max_examples=25, deadline=None)


@given(k=st.integers(1, 8), n=st.integers(1, 8),
       g=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_absmax_quant_error_bounded(k, n, g, seed):
    """|W - dequant(quant(W))| <= scale/2 element-wise, any shape/group."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k * g, n * 8)).astype(np.float32) * rng.uniform(
        0.1, 10)
    qt = Q.absmax_quantize(w, bits=8, group=g)
    wd = np.asarray(qt.dequantize(), np.float32)
    bound = np.asarray(qt.scale).repeat(g, 0) * 0.5 + 0.02 * np.abs(w) + 1e-4
    assert np.all(np.abs(w - wd) <= bound)


@given(rows=st.integers(1, 16), cols=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_int4_pack_unpack_roundtrip(rows, cols, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(rows * 2, cols)).astype(np.int8)
    qt = QTensor(pack_int4(jnp.asarray(codes)),
                 jnp.ones((1, cols), jnp.float32), 4, rows * 2,
                 (rows * 2, cols))
    np.testing.assert_array_equal(np.asarray(qt.unpack()), codes)


@given(n=st.integers(1, 3), m=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_nm_mask_exact_structure(n, m, seed):
    if n > m:
        return
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m * 8, 16)).astype(np.float32)
    act = np.abs(rng.normal(size=m * 8)).astype(np.float32) + 0.1
    mask = S.wanda_mask(w, act, n=n, m=m)
    groups = mask.reshape(-1, m, 16).sum(1)
    assert (groups == n).all()


@given(dens=st.floats(0.1, 1.0), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_block_mask_uniform_per_column(dens, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(128, 64)).astype(np.float32)
    mask = S.block_sparse_mask(w, bs=16, density=dens)
    counts = mask.sum(0)
    assert (counts == counts[0]).all()
    assert 1 <= counts[0] <= 8


@given(text=st.text(max_size=64))
@settings(**SETTINGS)
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


@given(keys=st.lists(st.text(min_size=1, max_size=8), min_size=1,
                     max_size=40))
@settings(**SETTINGS)
def test_result_cache_lru_bounded(keys):
    c = ResultCache(capacity=8)
    for k in keys:
        kk = c.key(k, 4)
        if c.get(kk) is None:
            c.put(kk, "v" + k)
    assert len(c._d) <= 8
    # most recent key always retrievable
    kk = c.key(keys[-1], 4)
    assert c.get(kk) == "v" + keys[-1]


@given(lens=st.lists(st.integers(1, 300), min_size=1, max_size=20))
@settings(**SETTINGS)
def test_batcher_buckets_and_fifo(lens):
    b = Batcher(buckets=(32, 64, 128, 256))
    for i, ln in enumerate(lens):
        b.add(Request(rid=i, prompt_ids=list(range(ln)), max_new=4))
    head = b.queue[0]
    got = b.take(4)
    assert got and got[0].rid == head.rid          # FIFO head served
    bk = bucket_len(len(head.prompt_ids), b.buckets)
    assert all(bucket_len(len(r.prompt_ids), b.buckets) == bk for r in got)
    assert len(got) + len(b) == len(lens)


@given(seed=st.integers(0, 2**31 - 1), sparsity=st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_sparsegpt_respects_target_sparsity(seed, sparsity):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    X = rng.normal(size=(256, 64))
    H = X.T @ X
    _, mask = S.sparsegpt_prune(w, H, sparsity=sparsity, blocksize=32)
    assert abs((~mask).mean() - sparsity) < 0.1
