"""Property-based tests (hypothesis) on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping, not aborting collection")
from hypothesis import given, settings, strategies as st

from repro.core import quantize as Q
from repro.core import sparsify as S
from repro.core.compressed import pack_int4, QTensor
from repro.serving.cache import ResultCache
from repro.serving.batcher import Batcher, Request, bucket_len
from repro.training.data import ByteTokenizer

SETTINGS = dict(max_examples=25, deadline=None)


@given(k=st.integers(1, 8), n=st.integers(1, 8),
       g=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_absmax_quant_error_bounded(k, n, g, seed):
    """|W - dequant(quant(W))| <= scale/2 element-wise, any shape/group."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k * g, n * 8)).astype(np.float32) * rng.uniform(
        0.1, 10)
    qt = Q.absmax_quantize(w, bits=8, group=g)
    wd = np.asarray(qt.dequantize(), np.float32)
    bound = np.asarray(qt.scale).repeat(g, 0) * 0.5 + 0.02 * np.abs(w) + 1e-4
    assert np.all(np.abs(w - wd) <= bound)


@given(rows=st.integers(1, 16), cols=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_int4_pack_unpack_roundtrip(rows, cols, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(rows * 2, cols)).astype(np.int8)
    qt = QTensor(pack_int4(jnp.asarray(codes)),
                 jnp.ones((1, cols), jnp.float32), 4, rows * 2,
                 (rows * 2, cols))
    np.testing.assert_array_equal(np.asarray(qt.unpack()), codes)


@given(n=st.integers(1, 3), m=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_nm_mask_exact_structure(n, m, seed):
    if n > m:
        return
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m * 8, 16)).astype(np.float32)
    act = np.abs(rng.normal(size=m * 8)).astype(np.float32) + 0.1
    mask = S.wanda_mask(w, act, n=n, m=m)
    groups = mask.reshape(-1, m, 16).sum(1)
    assert (groups == n).all()


@given(dens=st.floats(0.1, 1.0), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_block_mask_uniform_per_column(dens, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(128, 64)).astype(np.float32)
    mask = S.block_sparse_mask(w, bs=16, density=dens)
    counts = mask.sum(0)
    assert (counts == counts[0]).all()
    assert 1 <= counts[0] <= 8


@given(text=st.text(max_size=64))
@settings(**SETTINGS)
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


@given(keys=st.lists(st.text(min_size=1, max_size=8), min_size=1,
                     max_size=40))
@settings(**SETTINGS)
def test_result_cache_lru_bounded(keys):
    c = ResultCache(capacity=8)
    for k in keys:
        kk = c.key(k, 4)
        if c.get(kk) is None:
            c.put(kk, "v" + k)
    assert len(c._d) <= 8
    # most recent key always retrievable
    kk = c.key(keys[-1], 4)
    assert c.get(kk) == "v" + keys[-1]


@given(lens=st.lists(st.integers(1, 300), min_size=1, max_size=20))
@settings(**SETTINGS)
def test_batcher_buckets_and_fifo(lens):
    b = Batcher(buckets=(32, 64, 128, 256))
    for i, ln in enumerate(lens):
        b.add(Request(rid=i, prompt_ids=list(range(ln)), max_new=4))
    head = b.queue[0]
    got = b.take(4)
    assert got and got[0].rid == head.rid          # FIFO head served
    bk = bucket_len(len(head.prompt_ids), b.buckets)
    assert all(bucket_len(len(r.prompt_ids), b.buckets) == bk for r in got)
    assert len(got) + len(b) == len(lens)


@given(seed=st.integers(0, 2**31 - 1), sparsity=st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_sparsegpt_respects_target_sparsity(seed, sparsity):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    X = rng.normal(size=(256, 64))
    H = X.T @ X
    _, mask = S.sparsegpt_prune(w, H, sparsity=sparsity, blocksize=32)
    assert abs((~mask).mean() - sparsity) < 0.1


# ---------------------------------------------------------------------------
# multi-tenant scheduler/pool invariants (serving/scheduler.py)
# ---------------------------------------------------------------------------

from repro.serving.scheduler import PoolBudgetError, Scheduler  # noqa: E402
from test_scheduler import fake_pool  # noqa: E402


@given(sizes=st.lists(st.integers(1, 50), min_size=1, max_size=8),
       budget=st.integers(20, 120),
       accesses=st.lists(st.integers(0, 7), min_size=1, max_size=30))
@settings(**SETTINGS)
def test_pool_budget_never_exceeded(sizes, budget, accesses):
    """Residency is a hard invariant across any acquire sequence:
    either the entry fits (post-eviction) or the pool refuses."""
    table = {f"q{i}": sz for i, sz in enumerate(sizes)}
    _, pool = fake_pool(table, budget=budget)
    for a in accesses:
        q = f"q{a % len(sizes)}"
        try:
            pool.engine_for(q)
        except PoolBudgetError as e:
            assert not e.retryable and table[q] > budget
        assert pool.resident_bytes <= pool.byte_budget


@given(sizes=st.lists(st.integers(1, 50), min_size=1, max_size=6),
       budget=st.integers(50, 120),
       accesses=st.lists(st.integers(0, 5), min_size=1, max_size=25))
@settings(**SETTINGS)
def test_pool_eviction_order_deterministic(sizes, budget, accesses):
    """Replaying an identical acquire sequence yields an identical
    eviction log (pure LRU, no hidden state)."""
    table = {f"q{i}": sz for i, sz in enumerate(sizes)}
    logs = []
    for _ in range(2):
        _, pool = fake_pool(table, budget=budget)
        for a in accesses:
            try:
                pool.engine_for(f"q{a % len(sizes)}")
            except PoolBudgetError:
                pass
        logs.append(list(pool.eviction_log))
    assert logs[0] == logs[1]


@given(n_tenants=st.integers(2, 4), rows=st.integers(2, 6),
       share=st.integers(1, 3), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_scheduler_no_tenant_starvation(n_tenants, rows, share, seed):
    """Fair-share admission: every tenant gets its full ``share`` of
    in-flight rows (never more), and with equal-cost rows no tenant's
    first completion waits on another tenant finishing."""
    rng = np.random.default_rng(seed)
    per_tenant = {f"t{i}": [f"{'x' * 3}{j}" for j in range(rows)]
                  for i in range(n_tenants)}       # equal-duration rows
    sizes = {f"t{i}": 1 for i in range(n_tenants)}  # one model per tenant
    _, pool = fake_pool(sizes, budget=10 * n_tenants, slots=4)
    sched = Scheduler(pool, share=share)
    subs = [sched.submit(t, prompts, qsig=t)
            for t, prompts in per_tenant.items()]
    # submission order shuffled independently of tenant ids
    rng.shuffle(subs)
    sched.run()
    firsts = [s.first_done_tick for s in subs]
    for s in subs:
        assert s.done and len(s.results()) == rows
        assert s.peak_inflight == min(share, rows)   # full share, no more
    assert max(firsts) - min(firsts) <= 1            # simultaneous progress


# --- interleaved decode == serial decode (real engine, persistent jit) ----

_SERIAL = {}


def _tiny_serving():
    """Lazy module-level model + persistent engines so hypothesis
    examples after the first pay no recompilation."""
    if not _SERIAL:
        import jax
        from repro.configs.base import ModelConfig
        from repro.models import api
        from repro.serving.engine import Engine
        cfg = ModelConfig(name="p", family="dense", n_layers=1, d_model=32,
                          n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=260,
                          max_seq=128)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        kw = dict(slots=2, max_len=48, buckets=(16,))
        _SERIAL["shared"] = Engine(params, cfg, version="base", **kw)
        _SERIAL["serial"] = Engine(params, cfg, version="base", **kw)
    return _SERIAL


@given(p1=st.lists(st.text(alphabet="ab ", max_size=6), min_size=1,
                   max_size=4),
       p2=st.lists(st.text(alphabet="ab ", max_size=6), min_size=1,
                   max_size=4))
@settings(max_examples=8, deadline=None)
def test_scheduler_byte_identical_to_serial(p1, p2):
    """Interleaving two tenants' greedy streams through one shared
    engine produces exactly the tokens each would get decoding alone:
    the schedule changes, the outputs must not."""
    from test_scheduler import FakeSession
    from repro.serving.scheduler import ModelPool, Scheduler
    env = _tiny_serving()
    pool = ModelPool(FakeSession({}), byte_budget=1,
                     engine_factory=None, entry_bytes=lambda m: 1)
    pool._entries.clear()
    # park the persistent shared engine as the resident "base" entry
    from repro.serving.scheduler import PoolEntry
    pool._entries["base"] = PoolEntry(engine=env["shared"], nbytes=1)
    sched = Scheduler(pool, share=2)
    s1 = sched.submit("t1", list(p1), qsig="base", optimize=False,
                      max_new=4)
    s2 = sched.submit("t2", list(p2), qsig="base", optimize=False,
                      max_new=4)
    sched.run()
    ref1 = env["serial"].generate(list(p1), max_new=4)
    ref2 = env["serial"].generate(list(p2), max_new=4)
    assert s1.results() == ref1
    assert s2.results() == ref2
