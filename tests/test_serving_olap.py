"""Serving engine + OLAP operators + training substrate integration."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import api
from repro.olap import operators as OPS
from repro.olap.table import Table
from repro.serving.engine import Engine
from repro.training import checkpoint as CK
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training import train_loop as TL


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestEngine:
    def test_generate_shapes_and_stats(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=4, max_len=64, buckets=(16, 32))
        outs = eng.generate(["hello", "world", "abcdef", "x", "y"],
                            max_new=4)
        assert len(outs) == 5
        assert eng.stats.rows == 5
        assert eng.stats.decode_steps > 0

    def test_result_cache_dedup(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,))
        outs1 = eng.generate(["same", "same", "same"], max_new=4)
        assert outs1[0] == outs1[1] == outs1[2]
        assert eng.result_cache.hits >= 2
        d0 = eng.stats.decode_steps
        eng.generate(["same"], max_new=4)       # pure cache hit
        assert eng.stats.decode_steps == d0

    def test_continuous_batching_more_rows_than_slots(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        outs = eng.generate([f"req{i}" for i in range(7)], max_new=3)
        assert len(outs) == 7

    def test_engine_matches_unbatched_decode(self, tiny):
        """Slot-vmapped decode == direct api greedy decode."""
        from repro.core.policy import greedy_decode
        cfg, params = tiny
        tok = D.ByteTokenizer(260)
        text = "check me"
        ids = tok.encode(text, bos=True) + [tok.SEP]
        toks = np.zeros((1, 16), np.int32)
        toks[0, :len(ids)] = ids
        ref = greedy_decode(params, cfg, jnp.asarray(toks), 6,
                            lengths=jnp.asarray([len(ids)]))
        eng = Engine(params, cfg, slots=1, max_len=64, buckets=(16,),
                     use_result_cache=False)
        out = eng.generate([text], max_new=6)[0]
        want = tok.decode([t for t in np.asarray(ref)[0]
                           if t != tok.EOS])
        assert out == want


class FakeEngine:
    """Deterministic 'LLM' for operator plumbing tests."""
    def __init__(self, fn):
        self.fn = fn

    def generate(self, prompts, max_new=8):
        return [self.fn(p) for p in prompts]


class TestOlapOperators:
    def test_llm_map_adds_column(self):
        t = Table({"review": ["good mouse", "bad lamp"]})
        eng = FakeEngine(lambda p: p.split()[-2])
        t2 = OPS.llm_map(t, "review", eng, out_col="s")
        assert t2["s"] == ["good", "bad"]

    def test_llm_join_blocking_prunes_pairs(self):
        left = Table({"name": ["Acme Corp", "Globex"]})
        right = Table({"name": ["Acme Corp Inc.", "Initech", "acme corp"]})
        seen = []
        def fn(p):
            seen.append(p)
            body = p.split(":", 1)[1]
            a, b = [s.strip().lower().replace(",", "").replace(" inc.", "")
                    for s in body.split("|")]
            return "same" if a == b else "different"
        out = OPS.llm_join(left, right, ("name", "name"), FakeEngine(fn))
        # blocking: Globex never compared against Acme* (different first char)
        assert all("globex" not in p.lower() or "initech" not in p.lower()
                   for p in seen)
        assert len(out) == 2     # Acme matches both variants

    def test_table_ops(self):
        t = Table.from_rows([{"a": 1, "b": "x"}, {"a": 2, "b": "y"}])
        assert len(t.filter(lambda r: r["a"] > 1)) == 1
        assert t.select(["a"]).columns.keys() == {"a"}
        t2 = t.with_column("c", [10, 20])
        assert t2.row(1) == {"a": 2, "b": "y", "c": 20}


class TestTraining:
    def test_loss_decreases(self):
        cfg = ModelConfig(name="t2", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                          max_seq=256)
        out = TL.train(cfg, TL.TrainConfig(steps=25, batch=8, seq_len=64,
                                           log_every=24),
                       OPT.adamw(lr=3e-3, warmup=5, total_steps=25),
                       log=lambda *_: None)
        assert out["losses"][-1][1] < out["losses"][0][1] * 0.7

    def test_checkpoint_roundtrip_with_compressed_leaves(self, tiny):
        from repro.core.pipeline import InstanceOptimizer, Recipe
        cfg, params = tiny
        opt = InstanceOptimizer(params, cfg)
        p2, c2, _ = opt.apply(Recipe(name="w8", wbits=8,
                                     quant_method="absmax"))
        d = tempfile.mkdtemp()
        CK.save(d, 7, p2)
        restored, step, _ = CK.restore(d, p2)
        assert step == 7
        l1, _ = api.forward(p2, c2, {"tokens": jnp.ones((1, 8), jnp.int32)})
        l2, _ = api.forward(restored, c2,
                            {"tokens": jnp.ones((1, 8), jnp.int32)})
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32))

    def test_checkpoint_detects_corruption(self, tiny):
        cfg, params = tiny
        d = tempfile.mkdtemp()
        CK.save(d, 1, {"w": jnp.ones((4,))})
        npz = os.path.join(d, "step_00000001", "arrays.npz")
        with open(npz, "r+b") as f:
            f.seek(60)
            f.write(b"\xde\xad")
        with pytest.raises(IOError):
            CK.restore(d, {"w": jnp.ones((4,))})

    def test_checkpoint_gc_keeps_latest(self):
        d = tempfile.mkdtemp()
        for s in (1, 2, 3, 4, 5):
            CK.save(d, s, {"w": jnp.ones((2,))}, keep=2)
        assert CK.latest_step(d) == 5
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2

    def test_deterministic_batches_restart_safe(self):
        tok = D.ByteTokenizer()
        b1 = D.train_batch(17, batch=4, seq_len=32, tok=tok, seed=3)
        b2 = D.train_batch(17, batch=4, seq_len=32, tok=tok, seed=3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = D.train_batch(18, batch=4, seq_len=32, tok=tok, seed=3)
        assert not np.array_equal(b1["tokens"], b3["tokens"])
