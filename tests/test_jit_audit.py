"""Jitted hot-path auditor (analysis/jit_audit.py).

The load-bearing pair: the audit runs CLEAN on the real engine (the CI
gate), and FIRES when a regression is deliberately injected — a host
sync inside the jitted decode step, a call site that leaks a donated
buffer, a value-driven retrace.  A checker that can't fail proves
nothing, so every code the clean run relies on has an injection test.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import jit_audit as JA
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def audited(tiny_dense):
    """One full audit of a clean engine, shared by the assertions."""
    cfg, params = tiny_dense
    engine = Engine(params, cfg)
    report = JA.audit_engine(engine)
    return engine, report


class TestCleanEngine:
    def test_zero_diagnostics(self, audited):
        _, report = audited
        assert report.diagnostics == [], [d.to_dict()
                                          for d in report.diagnostics]

    def test_one_compile_per_bucket_shape(self, audited):
        """The retrace invariant, stated positively: every jitted
        target compiled exactly once per distinct input signature the
        scripted workload produced."""
        _, report = audited
        assert report.cache_stats           # workload hit every target
        for name, s in report.cache_stats.items():
            assert s["compiles"] == s["signatures"], (name, s)
            assert s["calls"] >= s["signatures"]

    def test_workload_covers_prefill_ladder(self, audited):
        engine, report = audited
        prefills = [n for n in report.cache_stats if "_prefill[" in n]
        assert len(prefills) >= 2           # short + long bucket
        assert any("_prefill_from[" in n for n in report.cache_stats)
        assert "_decode" in report.cache_stats
        assert "_insert" in report.cache_stats

    def test_budget_extracted(self, audited):
        _, report = audited
        assert report.budget is not None
        assert report.budget["flops"] > 0
        assert report.budget["coll_bytes"] == 0   # single-device engine

    def test_audit_restores_engine_targets(self, audited):
        """The proxies must not outlive the audit: the engine's jitted
        attributes are the original callables again."""
        engine, _ = audited
        for name, fn in engine.jit_targets().items():
            assert not isinstance(fn, JA.JitCallRecorder), name

    def test_confidence_emission_is_callback_free(self, audited):
        """The cascade confidence (serving/sampler.token_confidence) is
        computed inside the jitted decode/prefill steps from arrays
        already live there; emitting it must introduce no host callback
        (JIT001) and keep the donation rebinding intact (JIT003)."""
        engine, report = audited
        assert not any(d.code in ("JIT001", "JIT003")
                       for d in report.diagnostics)
        # and the signal actually reaches the finished requests
        reqs = engine.generate_stream(["confidence probe"], max_new=4,
                                      return_requests=True)
        assert 0.0 < reqs[0].confidence <= 1.0


class TestInjectedRegressions:
    def test_host_sync_in_decode_fires_JIT001(self, tiny_dense):
        """A debug print (= host callback) smuggled into the jitted
        decode step must be flagged."""
        cfg, params = tiny_dense
        engine = Engine(params, cfg)
        orig = engine._decode

        # *rest keeps the wrapper layout-agnostic: the paged decode
        # signature carries block tables between state and tokens
        def synced(params, state, *rest):
            jax.debug.print("tick {}", rest[-1])  # the injected host sync
            return orig(params, state, *rest)

        engine._decode = jax.jit(synced, donate_argnums=(1,))
        report = JA.audit_engine(engine, prompts=["a", "b", "c"])
        hits = [d for d in report.diagnostics if d.code == "JIT001"]
        assert hits and hits[0].location == "engine._decode"

    def test_donated_arg_not_rebound_fires_JIT003(self):
        src = ("leaked = self._decode(self.params, self._slot_state,"
               " toks, pos, ctr)\n"
               "self._slot_state = leaked[1]\n")
        diags = JA.audit_donation_sites(src, JA.ENGINE_DONATIONS, "x.py")
        assert [d.code for d in diags] == ["JIT003"]
        assert "self._slot_state" in diags[0].message

    def test_rebinding_call_sites_pass(self):
        src = ("self._slot_state = self._insert(self._slot_state, rows,"
               " idxs)\n"
               "nxt, self._slot_state = self._decode(self.params,"
               " self._slot_state, toks, pos, ctr)\n")
        assert JA.audit_donation_sites(src, JA.ENGINE_DONATIONS,
                                       "x.py") == []

    def test_value_driven_retrace_fires_JIT006(self):
        """A static argnum that changes per call compiles per VALUE
        while the shape signature stays constant — exactly the hazard
        JIT006 exists for."""
        f = jax.jit(lambda x, n: x + n, static_argnums=(1,))
        rec = JA.JitCallRecorder("f", f)
        rec(jnp.ones(3), 1)
        rec(jnp.ones(3), 2)
        diags = JA.audit_retrace(rec)
        assert [d.code for d in diags] == ["JIT006"]

    def test_shape_driven_recompile_is_not_a_retrace(self):
        f = jax.jit(lambda x: x * 2)
        rec = JA.JitCallRecorder("f", f)
        rec(jnp.ones(3))
        rec(jnp.ones(5))           # legit: new shape, new compile
        assert JA.audit_retrace(rec) == []

    def test_weak_scalar_arg_flagged_JIT004(self):
        f = jax.jit(lambda x, s: x * s)
        closed = jax.make_jaxpr(f)(jnp.ones(3), 0.5)
        diags = JA.audit_weak_args("f", closed)
        assert [d.code for d in diags] == ["JIT004"]
        assert diags[0].severity == "warning"   # float: promotion-active

    def test_committed_dtype_args_pass(self):
        f = jax.jit(lambda x, s: x * s)
        closed = jax.make_jaxpr(f)(jnp.ones(3), jnp.float32(0.5))
        assert JA.audit_weak_args("f", closed) == []


class TestJitTargets:
    def test_names_cover_the_hot_path(self, tiny_dense):
        cfg, params = tiny_dense
        engine = Engine(params, cfg)
        names = set(engine.jit_targets())
        assert {"_insert", "_decode"} <= names
        assert {n for n in names if n.startswith("_prefill[")} == {
            f"_prefill[{b}]" for b in engine.buckets}
        # prefix cache enabled by default -> the seeded ladder exists
        assert any(n.startswith("_prefill_from[") for n in names)
