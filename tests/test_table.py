"""Table invariants: ragged-column validation, empty-table edge cases,
and the columnar filter fast path."""
import pytest

from repro.olap.table import Table


class TestValidation:
    def test_ragged_columns_rejected(self):
        with pytest.raises(ValueError, match="ragged columns"):
            Table({"a": [1, 2, 3], "b": ["x", "y"]})

    def test_error_names_the_lengths(self):
        with pytest.raises(ValueError, match=r"'a': 2.*'b': 1"):
            Table({"a": [1, 2], "b": ["x"]})

    def test_equal_lengths_accepted(self):
        t = Table({"a": [1, 2], "b": ["x", "y"]})
        assert len(t) == 2

    def test_empty_columns_ok(self):
        assert len(Table({"a": [], "b": []})) == 0
        assert len(Table({})) == 0

    def test_with_column_length_mismatch(self):
        t = Table({"a": [1, 2]})
        with pytest.raises(ValueError, match="3 values for 2 rows"):
            t.with_column("b", ["only-one", "x", "y"])

    def test_getitem_unknown_column(self):
        with pytest.raises(KeyError, match="available"):
            Table({"a": [1]})["b"]


class TestFromRows:
    def test_empty_rows_give_empty_table(self):
        t = Table.from_rows([])
        assert len(t) == 0 and t.columns == {}

    def test_schema_mismatch_rejected(self):
        rows = [{"a": 1, "b": 2}, {"a": 3}]
        with pytest.raises(ValueError, match=r"row 1.*missing \['b'\]"):
            Table.from_rows(rows)

    def test_extra_key_rejected(self):
        rows = [{"a": 1}, {"a": 2, "b": 3}]
        with pytest.raises(ValueError, match=r"unexpected \['b'\]"):
            Table.from_rows(rows)

    def test_roundtrip(self):
        rows = [{"a": 1, "b": "x"}, {"a": 2, "b": "y"}]
        assert Table.from_rows(rows).rows() == rows


class TestSelect:
    def test_unknown_column_rejected(self):
        with pytest.raises(KeyError, match=r"\['z'\]"):
            Table({"a": [1]}).select(["a", "z"])

    def test_empty_selection_rejected(self):
        with pytest.raises(ValueError, match="at least one column"):
            Table({"a": [1]}).select([])

    def test_select_keeps_order_and_rows(self):
        t = Table({"a": [1, 2], "b": ["x", "y"], "c": [True, False]})
        s = t.select(["c", "a"])
        assert list(s.columns) == ["c", "a"] and len(s) == 2


class TestFilter:
    def t(self):
        return Table({"a": list(range(10)),
                      "b": [f"s{i % 3}" for i in range(10)]})

    def test_semantics_match_row_loop(self):
        t = self.t()
        for pred in (lambda r: r["a"] % 2 == 0,
                     lambda r: r["b"] == "s1" and r["a"] > 3,
                     lambda r: set(r) == {"a", "b"},       # key iteration
                     lambda r: len(r.items()) == 2,        # dict protocol
                     lambda r: False,
                     lambda r: True):
            want = [t.row(i) for i in range(len(t)) if pred(t.row(i))]
            assert t.filter(pred).rows() == want

    def test_pred_receives_real_dict(self):
        # the fast path must not change the pred-facing type
        seen = []
        self.t().filter(lambda r: seen.append(type(r)) or True)
        assert set(seen) == {dict}

    def test_row_order_preserved(self):
        t = self.t()
        assert t.filter(lambda r: r["a"] % 2 == 1)["a"] == [1, 3, 5, 7, 9]

    def test_zero_column_and_empty_tables(self):
        assert len(Table({}).filter(lambda r: True)) == 0
        assert Table({"a": []}).filter(lambda r: True).columns == {"a": []}

    def test_take_subsets_rows_in_given_order(self):
        t = self.t()
        s = t.take([3, 0, 3])
        assert s["a"] == [3, 0, 3] and s["b"] == ["s0", "s0", "s0"]
