"""End-to-end coverage of the instance-optimization workflow:
``IOLMSession._optimize`` recipe search, identity-model fallback, and
the ``ModelCache`` hit/miss/eviction + data-signature paths."""
import numpy as np
import pytest

from repro.core import policy as POL
from repro.core.pipeline import Recipe
from repro.models import api
from repro.olap.query import IOLMSession, ModelCache, OptimizedModel


W8 = Recipe(name="w8", wbits=8, quant_method="absmax")


@pytest.fixture(scope="module")
def tiny(tiny_dense):
    return tiny_dense


def make_session(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("recipes", [W8])
    kw.setdefault("calib_rows", 4)
    kw.setdefault("eval_rows", 2)
    kw.setdefault("engine_kw", dict(slots=2, max_len=64, buckets=(32,)))
    return IOLMSession(params, cfg, **kw)


PROMPTS = [f"fix: categ{i}" for i in range(8)]


class TestOptimizeWorkflow:
    def test_optimize_runs_search_and_versions_model(self, tiny):
        sess = make_session(tiny)
        m = sess._optimize("qsig1", PROMPTS)
        assert isinstance(m, OptimizedModel)
        # version ties the model to (query, DATA, recipe): compression
        # is calibration-dependent, so the data signature is part of
        # the identity
        dsig = sess.model_cache.data_signature(PROMPTS)
        assert m.version == f"qsig1:{dsig}:w8"
        assert m.recipe.name == "w8"
        assert m.report is not None and m.report.compression > 1.0
        assert any("picked w8" in line for line in sess.log)
        # the compressed params actually run
        logits, _ = api.forward(m.params, m.cfg,
                                {"tokens": np.ones((1, 8), np.int32)})
        assert logits.shape[-1] == m.cfg.vocab_size

    def test_model_cache_hit_skips_reoptimization(self, tiny):
        sess = make_session(tiny)
        m1 = sess._optimize("qsig1", PROMPTS)
        n_log = len(sess.log)
        m2 = sess._optimize("qsig1", PROMPTS)
        assert m2 is m1                          # memoized, not re-searched
        assert sess.model_cache.hits == 1
        assert any("model cache hit" in line for line in sess.log[n_log:])

    def test_distinct_data_resolves_to_distinct_models(self, tiny):
        sess = make_session(tiny)
        m1 = sess._optimize("qsig1", PROMPTS)
        m2 = sess._optimize("qsig1", [p + "x" for p in PROMPTS])
        assert sess.model_cache.hits == 0
        assert len(sess.model_cache) == 2
        # same query over different data must NOT share a model version:
        # a pool keyed on version would otherwise serve tenant B through
        # tenant A's data-calibrated params
        assert m1.version != m2.version

    def test_identity_fallback_when_no_recipe_survives(self, tiny,
                                                       monkeypatch):
        """Empty search outcome (every recipe inapplicable / below the
        acc floor with no candidates at all) -> the session falls back
        to the uncompressed identity model instead of failing."""
        cfg, params = tiny
        sess = make_session(tiny)

        def empty_search(opt, eval_fn, recipes, *, acc_floor, keep_params):
            base = eval_fn(opt.params, opt.cfg)
            return POL.SearchOutcome(baseline=base, candidates=[],
                                     perf=None, acc=None)

        monkeypatch.setattr("repro.olap.query.POL.search", empty_search)
        m = sess._optimize("qsig1", PROMPTS)
        assert m.version == "base"
        assert m.recipe.name == "identity"
        assert m.params is params                # the base model, unchanged
        # the fallback is cached like any other outcome
        assert sess._optimize("qsig1", PROMPTS) is m

    def test_acc_objective_picks_acc_variant(self, tiny):
        sess = make_session(tiny, objective="acc",
                            recipes=[W8, Recipe(name="w4", wbits=4,
                                                group=32)])
        m = sess._optimize("qsig1", PROMPTS)
        assert m.version.startswith("qsig1:")
        # acc objective maximizes agreement; w8 dominates w4 here
        assert m.recipe.name == "w8"


class TestModelCache:
    def _m(self, tag):
        return OptimizedModel(None, None, None, Recipe(name=tag), tag)

    def test_signature_sees_past_first_64_values(self):
        head = [f"v{i}" for i in range(64)]
        a = head + ["tail-a"]
        b = head + ["tail-b"]
        assert ModelCache.data_signature(a) != ModelCache.data_signature(b)

    def test_signature_sees_value_count(self):
        vals = [f"v{i}" for i in range(70)]
        assert (ModelCache.data_signature(vals)
                != ModelCache.data_signature(vals + [vals[-1]]))

    def test_signature_separates_long_values_with_common_prefix(self):
        base = "x" * 300
        assert (ModelCache.data_signature([base + "a"])
                != ModelCache.data_signature([base + "ab"]))

    def test_signature_deterministic(self):
        vals = [f"row{i}" for i in range(100)]
        assert (ModelCache.data_signature(vals)
                == ModelCache.data_signature(list(vals)))

    def test_capacity_cap_evicts_lru(self):
        mc = ModelCache(capacity=2)
        mc.put("q1", "d", self._m("m1"))
        mc.put("q2", "d", self._m("m2"))
        assert mc.get("q1", "d") is not None     # refresh q1
        mc.put("q3", "d", self._m("m3"))         # evicts q2, not q1
        assert len(mc) == 2 and mc.evictions == 1
        assert mc.get("q2", "d") is None
        assert mc.get("q1", "d") is not None

    def test_unbounded_tenant_stream_stays_capped(self):
        mc = ModelCache(capacity=8)
        for i in range(100):
            mc.put(f"q{i}", "d", self._m(f"m{i}"))
        assert len(mc) == 8 and mc.evictions == 92

    def test_eviction_follows_recency_order_exactly(self):
        mc = ModelCache(capacity=3)
        for tag in ("a", "b", "c"):
            mc.put(tag, "d", self._m(tag))
        assert mc.get("a", "d") is not None      # order now b, c, a
        mc.put("x", "d", self._m("x"))           # evicts b
        mc.put("y", "d", self._m("y"))           # evicts c
        assert mc.get("b", "d") is None and mc.get("c", "d") is None
        assert all(mc.get(t, "d") is not None for t in ("a", "x", "y"))

    def test_capacity_one_keeps_only_latest(self):
        mc = ModelCache(capacity=1)
        mc.put("q1", "d", self._m("m1"))
        assert len(mc) == 1 and mc.evictions == 0
        mc.put("q2", "d", self._m("m2"))
        assert len(mc) == 1 and mc.evictions == 1
        assert mc.get("q1", "d") is None
        assert mc.get("q2", "d").version == "m2"

    def test_put_existing_key_replaces_without_eviction(self):
        mc = ModelCache(capacity=2)
        mc.put("q1", "d", self._m("old"))
        mc.put("q2", "d", self._m("m2"))
        mc.put("q1", "d", self._m("new"))        # replace, at capacity
        assert len(mc) == 2 and mc.evictions == 0
        assert mc.get("q1", "d").version == "new"
        mc.put("q3", "d", self._m("m3"))         # now q2 is LRU
        assert mc.get("q2", "d") is None and mc.evictions == 1

    def test_hit_and_eviction_accounting_on_repeated_get_put(self):
        mc = ModelCache(capacity=2)
        assert mc.get("q1", "d") is None         # miss: no hit counted
        assert mc.hits == 0
        mc.put("q1", "d", self._m("m1"))
        for _ in range(3):
            assert mc.get("q1", "d") is not None
        assert mc.hits == 3
        # distinct data signature is a distinct entry, not a hit
        assert mc.get("q1", "other-dsig") is None
        assert mc.hits == 3
        for i in range(4):
            mc.put(f"q{i + 2}", "d", self._m(f"m{i}"))
        assert mc.evictions == 3 and len(mc) == 2
        # evicted entries miss; counters are monotone
        assert mc.get("q1", "d") is None and mc.hits == 3
