"""Paged KV cache: block-table allocator semantics + engine byte identity.

The acceptance bar for the paged decode fast path is *byte identity*:
for a greedy workload, the paged layout (reference gather AND the
Pallas paged-attention kernel, interpret-resolved on CPU) must produce
exactly the outputs of the contiguous slot-stacked layout, across
admission waves, slot reuse, and shared-prefix aliasing.
"""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving.engine import Engine
from repro.serving.paged import BlockTableAllocator


# ---------------------------------------------------------------------------
# allocator unit tests (pure host-side numpy)
# ---------------------------------------------------------------------------

class TestBlockTableAllocator:
    def test_id_space_layout(self):
        a = BlockTableAllocator(slots=3, blocks_per_slot=4)
        assert a.num_blocks == 3 * 4 + 8 + 1
        assert a.trash == a.num_blocks - 1
        for s in range(3):
            assert list(a.tables[s]) == list(range(s * 4, (s + 1) * 4))

    def test_seed_alias_refcount_release(self):
        a = BlockTableAllocator(slots=2, blocks_per_slot=4)
        ids = a.seed_blocks("tpl", 2)
        assert ids is not None and len(ids) == 2
        assert a.seed_blocks("tpl", 2) is ids          # idempotent
        n = a.alias(0, "tpl")
        assert n == 2 and list(a.tables[0][:2]) == list(ids)
        # private tail untouched past the aliased span
        assert list(a.tables[0][2:]) == [2, 3]
        a.alias(1, "tpl")
        in_use, shared = a.stats()
        assert shared == 2                              # both ids x 2 slots
        a.release(0)
        a.release(1)
        # entry still holds its reference: blocks not yet free
        assert a.lookup("tpl") is not None
        free0 = len(a._free)
        a.drop_prefix("tpl")
        assert len(a._free) == free0 + 2
        assert a.lookup("tpl") is None

    def test_release_resets_stale_rows_to_private(self):
        a = BlockTableAllocator(slots=2, blocks_per_slot=4)
        a.seed_blocks("tpl", 3)
        a.alias(0, "tpl")
        a.release(0)
        assert list(a.tables[0]) == [0, 1, 2, 3]
        # a released slot re-admitted without a prefix is fully private
        a.occupy(0)
        assert list(a.tables[0]) == [0, 1, 2, 3]

    def test_seed_fails_closed_when_free_list_short(self):
        a = BlockTableAllocator(slots=2, blocks_per_slot=4, extra_blocks=1)
        assert a.seed_blocks("big", 2) is None          # 1 free < 2 wanted
        assert a.seed_blocks("fits", 1) is not None

    def test_drop_prefix_keeps_blocks_pinned_by_live_slots(self):
        a = BlockTableAllocator(slots=2, blocks_per_slot=4)
        ids = a.seed_blocks("tpl", 2)
        a.alias(0, "tpl")
        a.drop_prefix("tpl")                            # cache evicted
        assert all(int(b) not in a._free for b in ids)  # slot still reads
        a.release(0)
        assert all(int(b) in a._free for b in ids)

    def test_stats_counts_entry_only_blocks(self):
        a = BlockTableAllocator(slots=2, blocks_per_slot=4)
        a.seed_blocks("tpl", 2)
        in_use, shared = a.stats()
        assert in_use == 2 and shared == 0


# ---------------------------------------------------------------------------
# engine-level byte identity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_tiny():
    cfg = ModelConfig(name="pg", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


def _family_model(arch):
    cfg = registry.get_reduced(arch).replace(vocab_size=260)
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


PROMPTS = ["fix: pyton", "fix: javascrpt", "fix: golag", "fix: rst",
           "fix: kotln", "fix: hsakell"]


def _serve(cfg, params, prompts, *, kv_layout, backend="reference",
           prefix=None, slots=2, max_len=128):
    eng = Engine(params, cfg, slots=slots, max_len=max_len,
                 buckets=(16, 48, 64), use_result_cache=False,
                 kv_layout=kv_layout, backend=backend)
    outs = eng.generate(prompts, max_new=8, prefix=prefix)
    return eng, outs


class TestPagedByteIdentity:
    @pytest.mark.parametrize("arch", [None, "qwen2-moe-a2.7b", "zamba2-7b"])
    def test_paged_and_pallas_equal_contiguous(self, arch, dense_tiny):
        """dense / moe / hybrid: contiguous-reference == paged-reference
        == paged-pallas, byte for byte, across two admission waves."""
        cfg, params = dense_tiny if arch is None else _family_model(arch)
        _, base = _serve(cfg, params, PROMPTS, kv_layout="contiguous")
        ep, paged = _serve(cfg, params, PROMPTS, kv_layout="paged")
        ek, kern = _serve(cfg, params, PROMPTS, kv_layout="paged",
                          backend="pallas")
        assert ep._paged and ek._paged
        assert paged == base
        assert kern == base

    def test_auto_layout_picks_paged_for_dense(self, dense_tiny):
        cfg, params = dense_tiny
        eng = Engine(params, cfg, max_len=128)
        assert eng._paged and eng._block_size == 32
        assert eng.stats.backend == "reference"         # auto on CPU

    def test_unsupported_family_falls_back_to_contiguous(self):
        cfg, params = _family_model("rwkv6-3b")
        eng = Engine(params, cfg, kv_layout="paged", max_len=64)
        assert not eng._paged                           # no positional KV

    def test_tiny_block_auto_falls_back(self, dense_tiny):
        cfg, params = dense_tiny
        # max_len=36 -> largest pow2 block dividing it is 4 (< 8): auto
        # degrades to contiguous, explicit "paged" still honors it
        eng = Engine(params, cfg, max_len=36)
        assert not eng._paged
        eng2 = Engine(params, cfg, max_len=36, kv_layout="paged")
        assert eng2._paged and eng2._block_size == 4


class TestPagedEdgeCases:
    # 45 chars -> >1 full 32-position block of prefix tokens
    TMPL = "rewrite the category label in lowercase now: "

    def test_prefix_longer_than_one_block_aliases(self, dense_tiny):
        cfg, params = dense_tiny
        prompts = [self.TMPL + s for s in
                   ("Alpha", "BETA", "gamma", "DeLtA")]
        _, base = _serve(cfg, params, prompts, kv_layout="contiguous",
                         prefix=self.TMPL)
        eng, outs = _serve(cfg, params, prompts, kv_layout="paged",
                           prefix=self.TMPL)
        assert outs == base
        # 45+ prefix tokens / 32-position blocks -> 1 full shared block,
        # aliased by both slots of each admission wave
        assert eng.stats.kv_blocks_shared >= 1
        assert eng.stats.prefix_hits > 0

    def test_slot_retire_and_reuse_stays_identical(self, dense_tiny):
        """More requests than slots: every slot is retired and re-used
        with stale table entries reset in between (3+ waves through 2
        slots, ragged lengths so retirement interleaves)."""
        cfg, params = dense_tiny
        prompts = [f"row {i}: " + "v" * (3 + 5 * (i % 3))
                   for i in range(7)]
        _, base = _serve(cfg, params, prompts, kv_layout="contiguous")
        eng, outs = _serve(cfg, params, prompts, kv_layout="paged")
        assert outs == base
        # drained engine: no slot occupies any block
        used, shared = eng._alloc.stats()
        assert shared == 0 and not eng._alloc._occupied

    def test_aliasing_across_slots_counts_shared_blocks(self, dense_tiny):
        cfg, params = dense_tiny
        prompts = [self.TMPL + f"value {i}" for i in range(4)]
        eng = Engine(params, cfg, slots=4, max_len=128,
                     buckets=(16, 48, 64), use_result_cache=False,
                     kv_layout="paged")
        outs = eng.generate(prompts, max_new=6, prefix=self.TMPL)
        assert len(outs) == 4
        # one admission wave of 4 slots all aliasing the same template
        assert eng.stats.kv_blocks_shared >= 1
        # seeded entry survives the drain (pinned by the prefix cache)
        _, pkey = eng._prefix_ids_memo[self.TMPL]
        assert eng._alloc.lookup(pkey) is not None

    def test_prefix_cache_eviction_releases_blocks(self, dense_tiny):
        cfg, params = dense_tiny
        from repro.serving.cache import PrefixCache
        eng = Engine(params, cfg, slots=2, max_len=128,
                     buckets=(16, 48, 64), use_result_cache=False,
                     kv_layout="paged", prefix_cache=PrefixCache(capacity=1))
        t1 = "first shared template prefix padding padding: "
        t2 = "second shared template prefix padding padding: "
        eng.generate([t1 + "a", t1 + "b"], max_new=4, prefix=t1)
        free0 = len(eng._alloc._free)
        eng.generate([t2 + "a", t2 + "b"], max_new=4, prefix=t2)
        # t1's entry was evicted (capacity 1): its shared blocks went
        # back to the free list once its aliasing slots retired
        assert len(eng._alloc._entries) == 1
        assert len(eng._alloc._free) == free0

    def test_engine_stats_carry_paged_fields(self, dense_tiny):
        cfg, params = dense_tiny
        eng, _ = _serve(cfg, params, PROMPTS[:2], kv_layout="paged")
        assert eng.stats.backend == "reference"
        assert eng.stats.kv_blocks_in_use > 0
