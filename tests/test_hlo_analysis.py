"""Unit tests for launch/hlo_analysis.py shape/collective parsing.

These pin the dtype-table edge cases (sub-byte packing rounds UP to
whole bytes, nested tuple shapes parse fully) and the async-pair
accounting ('-start'/'-done' count once, not twice).
"""
from repro.launch import hlo_analysis as H


class TestShapeBytes:
    def test_simple_array(self):
        assert H._shape_bytes("bf16[128,1024]{1,0}") == 128 * 1024 * 2

    def test_scalar_and_empty_dims(self):
        assert H._shape_bytes("f32[]") == 4
        assert H._shape_bytes("pred[]") == 1

    def test_tuple(self):
        assert H._shape_bytes("(f32[2,4], u32[])") == 2 * 4 * 4 + 4

    def test_nested_tuple(self):
        got = H._shape_bytes("(bf16[8], (bf16[8], u32[]))")
        assert got == 16 + 16 + 4

    def test_sub_byte_dtypes_round_up_per_array(self):
        # u4[3] packs 2 values/byte but buffers are whole bytes: 2, not 1.5
        assert H._shape_bytes("u4[3]") == 2
        assert H._shape_bytes("s4[8]") == 4
        # two sub-byte arrays round independently
        assert H._shape_bytes("(u4[3], u4[3])") == 4

    def test_unknown_dtype_ignored(self):
        assert H._shape_bytes("token[]") == 0

    def test_layout_annotation_not_misparsed(self):
        # the {1,0} layout suffix must not read as another shape
        assert H._shape_bytes("f32[4,4]{1,0}") == 64


class TestCollectiveBytes:
    def test_sync_op_counted_once(self):
        hlo = "  %ag = bf16[64,128] all-gather(bf16[8,128] %x), dims={0}\n"
        got = H.collective_bytes(hlo)
        assert got == {"all-gather": 64 * 128 * 2}

    def test_async_pair_counted_once(self):
        # the -start result repeats the payload inside a tuple; only
        # the -done result may contribute
        hlo = (
            "  %s = (bf16[8,128], bf16[64,128]) all-gather-start("
            "bf16[8,128] %x), dims={0}\n"
            "  %d = bf16[64,128] all-gather-done("
            "(bf16[8,128], bf16[64,128]) %s)\n")
        got = H.collective_bytes(hlo)
        assert got == {"all-gather": 64 * 128 * 2}

    def test_kinds_accumulate_independently(self):
        hlo = (
            "  %a = f32[16] all-reduce(f32[16] %x), to_apply=%sum\n"
            "  %b = f32[16] all-reduce(f32[16] %y), to_apply=%sum\n"
            "  %c = f32[4] reduce-scatter(f32[16] %z), dims={0}\n")
        got = H.collective_bytes(hlo)
        assert got == {"all-reduce": 128.0, "reduce-scatter": 16.0}

    def test_non_collective_lines_ignored(self):
        hlo = ("  %m = f32[128,128] dot(f32[128,128] %a, "
               "f32[128,128] %b)\n")
        assert H.collective_bytes(hlo) == {}

    def test_nested_tuple_result(self):
        hlo = ("  %s = (f32[8], (f32[8], u32[])) "
               "collective-permute-start(f32[8] %x)\n"
               "  %d = f32[8] collective-permute-done("
               "(f32[8], (f32[8], u32[])) %s)\n")
        got = H.collective_bytes(hlo)
        assert got == {"collective-permute": 32.0}
