"""serving/cache.py: ResultCache LRU/version/accounting, PrefixCache,
and end-to-end prefix-sharing exactness across model families."""
import jax
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving.batcher import Batcher, Request
from repro.serving.cache import PrefixCache, ResultCache
from repro.serving.engine import Engine


class TestResultCache:
    def test_lru_eviction_at_capacity(self):
        rc = ResultCache(capacity=3)
        for i in range(4):
            rc.put(rc.key(f"p{i}", 8), f"v{i}")
        assert len(rc._d) == 3
        assert rc.peek(rc.key("p0", 8)) is None      # oldest evicted
        assert rc.peek(rc.key("p3", 8)) == "v3"

    def test_get_refreshes_lru_order(self):
        rc = ResultCache(capacity=2)
        rc.put(rc.key("a", 1), "A")
        rc.put(rc.key("b", 1), "B")
        assert rc.get(rc.key("a", 1)) == "A"         # refresh a
        rc.put(rc.key("c", 1), "C")                  # evicts b, not a
        assert rc.peek(rc.key("a", 1)) == "A"
        assert rc.peek(rc.key("b", 1)) is None

    def test_peek_touches_neither_counters_nor_order(self):
        rc = ResultCache(capacity=2)
        rc.put(rc.key("a", 1), "A")
        rc.put(rc.key("b", 1), "B")
        assert rc.peek(rc.key("a", 1)) == "A"
        assert (rc.hits, rc.misses) == (0, 0)
        rc.put(rc.key("c", 1), "C")                  # a was NOT refreshed
        assert rc.peek(rc.key("a", 1)) is None

    def test_record_hit_refreshes_and_counts(self):
        rc = ResultCache(capacity=2)
        rc.put(rc.key("a", 1), "A")
        rc.put(rc.key("b", 1), "B")
        rc.record_hit(rc.key("a", 1))                # dedup-path accounting
        rc.record_miss()
        assert (rc.hits, rc.misses) == (1, 1)
        assert abs(rc.hit_rate - 0.5) < 1e-9
        rc.put(rc.key("c", 1), "C")                  # b evicted, a refreshed
        assert rc.peek(rc.key("a", 1)) == "A"
        assert rc.peek(rc.key("b", 1)) is None

    def test_version_keying_separates_models(self):
        rc = ResultCache()
        rc.put(rc.key("same prompt", 8, "base"), "base out")
        assert rc.peek(rc.key("same prompt", 8, "qsig:w8")) is None
        rc.put(rc.key("same prompt", 8, "qsig:w8"), "w8 out")
        assert rc.peek(rc.key("same prompt", 8, "base")) == "base out"
        assert rc.peek(rc.key("same prompt", 8, "qsig:w8")) == "w8 out"


class TestPrefixCache:
    def test_lru_eviction_at_capacity(self):
        pc = PrefixCache(capacity=2)
        for i in range(3):
            pc.put(pc.key([1, 2, i], "base"), state={"s": i}, prefix_len=3)
        assert len(pc) == 2
        assert pc.key([1, 2, 0], "base") not in pc
        assert pc.key([1, 2, 2], "base") in pc

    def test_get_hit_miss_accounting_and_refresh(self):
        pc = PrefixCache(capacity=2)
        k1 = pc.key([1], "base")
        assert pc.get(k1) is None and pc.misses == 1
        pc.put(k1, state=None, prefix_len=1)
        pc.put(pc.key([2], "base"), state=None, prefix_len=1)
        assert pc.get(k1) is not None and pc.hits == 1   # refreshes k1
        pc.put(pc.key([3], "base"), state=None, prefix_len=1)
        assert k1 in pc                                  # [2] evicted instead
        assert pc.key([2], "base") not in pc

    def test_version_invalidates_recompressed_model(self):
        """The same template under a different model version must MISS:
        a recompressed instance-optimized variant never decodes against
        the base model's stored prefix activations."""
        pc = PrefixCache()
        ids = [1, 70, 71, 72]
        pc.put(pc.key(ids, "base"), state="base-kv", prefix_len=4)
        assert pc.get(pc.key(ids, "qsig:w8")) is None
        e = pc.get(pc.key(ids, "base"))
        assert e is not None and e.state == "base-kv"


class TestBatcherPrefixGrouping:
    def test_take_never_mixes_prefix_groups(self):
        """Admission seeds one shared prefix state per batch, so take()
        must group on (bucket, prefix_key) — the head defines both."""
        b = Batcher(buckets=(8,))
        ka, kb = ((1, 2), "base"), ((3, 4), "base")
        for i, pk in enumerate([ka, ka, kb, ka]):
            r = Request(rid=i, prompt_ids=[5, 6], max_new=4)
            r.prefix_key = pk
            b.add(r)
        first = b.take(4)
        assert [r.rid for r in first] == [0, 1, 3]     # all ka, FIFO
        assert [r.rid for r in b.take(4)] == [2]


@pytest.fixture(scope="module")
def dense_tiny():
    cfg = ModelConfig(name="tp", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


PROMPTS = ["fix: pyton", "fix: javascrpt", "fix: golag", "fix: rst"]


def _family_model(arch):
    cfg = registry.get_reduced(arch).replace(vocab_size=260)
    return cfg, api.init_params(jax.random.PRNGKey(0), cfg)


class TestPrefixSharingExactness:
    def _run(self, cfg, params, *, on):
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(8, 16),
                     use_prefix_cache=on)
        outs = eng.generate(PROMPTS, max_new=8, prefix="fix: ")
        return eng, outs

    def test_dense_outputs_byte_identical(self, dense_tiny):
        cfg, params = dense_tiny
        off, o_off = self._run(cfg, params, on=False)
        on, o_on = self._run(cfg, params, on=True)
        assert o_on == o_off
        assert off.stats.prefix_hits == 0
        assert on.stats.prefix_hits > 0
        assert on.stats.prefill_tokens_saved > 0
        # the whole point: fewer prompt tokens through the trunk
        assert on.stats.prefill_tokens < off.stats.prefill_tokens

    @pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "rwkv6-3b",
                                      "zamba2-7b"])
    def test_family_outputs_byte_identical(self, arch):
        """moe / rwkv / hybrid: prefix seeding (KV scatter, recurrent
        state resume, or both) reproduces full-prefill greedy outputs
        exactly."""
        cfg, params = _family_model(arch)
        _, o_off = self._run(cfg, params, on=False)
        on, o_on = self._run(cfg, params, on=True)
        assert o_on == o_off
        assert on.stats.prefix_hits > 0

    def test_prefix_entry_reused_across_queries(self, dense_tiny):
        cfg, params = dense_tiny
        eng, _ = self._run(cfg, params, on=True)
        pc = eng.prefix_cache
        assert len(pc) == 1 and pc.misses == 1
        eng.generate(["fix: habsjell"], max_new=6, prefix="fix: ")
        assert len(pc) == 1 and pc.misses == 1         # same entry, no rebuild
        # keys carry the engine's model version (invalidation-by-version)
        (ids, version), = list(pc._d.keys())
        assert version == eng.version

    def test_mixed_prefix_and_plain_submissions(self, dense_tiny):
        """Prefix and non-prefix requests interleave in one engine run;
        admission batches never mix the two groups and outputs match a
        prefix-free engine."""
        cfg, params = dense_tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(8, 16),
                     use_prefix_cache=True, use_result_cache=False)
        reqs = [eng.submit("fix: pyton", max_new=6, prefix="fix: "),
                eng.submit("no template here", max_new=6),
                eng.submit("fix: golag", max_new=6, prefix="fix: ")]
        eng.drain()
        ref = Engine(params, cfg, slots=2, max_len=64, buckets=(8, 16),
                     use_prefix_cache=False, use_result_cache=False)
        want = ref.generate(["fix: pyton", "no template here",
                             "fix: golag"], max_new=6)
        assert [r.text for r in reqs] == want

    def test_oversized_suffix_falls_back_to_full_path(self, dense_tiny):
        """A suffix overflowing the ladder keeps the legacy truncation
        semantics: the request takes the full-prompt path."""
        cfg, params = dense_tiny
        eng = Engine(params, cfg, slots=1, max_len=32, buckets=(16,),
                     use_prefix_cache=True, use_result_cache=False)
        req = eng.submit("fix: " + "z" * 200, max_new=2, prefix="fix: ")
        assert req.prefix_key is None
        eng.drain()
        assert req.truncated and eng.stats.truncated == 1
        assert eng.stats.prefix_hits == 0

    def test_full_prompt_exceeding_top_bucket_still_truncates(self,
                                                              dense_tiny):
        """Regression: a LONG template + short suffix whose total
        exceeds the top bucket must fall back (the off-path would clip
        the template head, so splitting would silently change outputs)
        — on and off stay byte-identical, both truncated."""
        cfg, params = dense_tiny
        template = "T" * 40 + ": "              # full prompt > top bucket 16
        text = template + "abc"
        outs = {}
        for on in (False, True):
            eng = Engine(params, cfg, slots=1, max_len=64, buckets=(16,),
                         use_prefix_cache=on, use_result_cache=False)
            req = eng.submit(text, max_new=4, prefix=template)
            assert req.prefix_key is None
            eng.drain()
            assert req.truncated
            outs[on] = req.text
        assert outs[True] == outs[False]

    def test_prefix_disabled_for_unsupported_family(self):
        """encdec/vlm engines must silently take the full-prefill path."""
        assert not api.supports_prefix(registry.get_reduced("whisper-base"))
        assert not api.supports_prefix(registry.get_reduced("paligemma-3b"))
