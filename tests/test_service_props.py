"""Property-based tests (hypothesis) for the service layer invariants:
the per-tenant in-flight SLO cap under arbitrary admission/release
interleavings, and the reservoir percentile estimator against exact
``statistics.quantiles``.  Deterministic spot-check versions of both
run unconditionally in tests/test_service.py; these push the same
invariants through randomized schedules."""
import statistics

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping, not aborting collection")
from hypothesis import given, settings, strategies as st

from repro.serving.metrics import Reservoir
from repro.service.slo import AdmissionController, TenantSLO

SETTINGS = dict(max_examples=50, deadline=None)

lat = st.floats(min_value=0.0, max_value=1e6,
                allow_nan=False, allow_infinity=False)


@given(data=st.lists(lat, min_size=2, max_size=400))
@settings(**SETTINGS)
def test_reservoir_exact_below_capacity(data):
    """While the stream fits the reservoir, every reported percentile
    IS the exact inclusive-method quantile."""
    r = Reservoir(capacity=512)
    for x in data:
        r.add(x)
    assert r.quantile(0.5) == pytest.approx(
        statistics.quantiles(data, n=2, method="inclusive")[0])
    assert r.quantile(0.95) == pytest.approx(
        statistics.quantiles(data, n=20, method="inclusive")[18])
    assert r.quantile(0.99) == pytest.approx(
        statistics.quantiles(data, n=100, method="inclusive")[98])


@given(data=st.lists(lat, min_size=1500, max_size=2500),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_reservoir_overflow_estimate_rank_tolerance(data, seed):
    """Beyond capacity the estimate is a sample quantile: assert rank
    tolerance (the p50 estimate lands between the exact p30 and p70),
    a ±6-sigma band for a 256-element uniform sample."""
    r = Reservoir(capacity=256, seed=seed)
    for x in data:
        r.add(x)
    exact = statistics.quantiles(data, n=10, method="inclusive")
    assert exact[2] <= r.quantile(0.5) <= exact[6]


@given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 8)),
                    max_size=300),
       cap=st.integers(1, 32))
@settings(**SETTINGS)
def test_inflight_rows_never_exceed_cap(ops, cap):
    """Any admit/release interleaving: in-flight rows <= the SLO cap,
    and the controller's ledger matches an independent replay."""
    ac = AdmissionController(
        {"t": TenantSLO(max_inflight_rows=cap, max_queries=10 ** 6)})
    live = []
    for is_release, rows in ops:
        if is_release and live:
            ac.release("t", live.pop(0))
        else:
            if ac.try_admit("t", rows, 0.0) is None:
                live.append(rows)
        cur = ac.inflight_rows("t")
        assert cur == sum(live)
        assert cur <= cap
    # single-row queries can always make progress once drained
    for rows in live:
        ac.release("t", rows)
    assert ac.try_admit("t", min(1, cap), 0.0) is None
