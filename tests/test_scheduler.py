"""serving/scheduler.py: ModelPool residency/eviction/pinning and the
fair-share Scheduler, plus the cross-tenant PrefixCache regression."""
from types import SimpleNamespace

import pytest

from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.olap.query import IOLMSession, Query
from repro.olap.table import Table
from repro.serving.batcher import Request
from repro.serving.cache import PrefixCache
from repro.serving.engine import Engine
from repro.serving.scheduler import (ModelPool, PoolBudgetError, Scheduler,
                                     slot_state_bytes)

W8 = Recipe(name="w8", wbits=8, quant_method="absmax")


# ---------------------------------------------------------------------------
# fakes: pool/scheduler mechanics without model compute
# ---------------------------------------------------------------------------

class FakeEngine:
    """Deterministic async-engine stand-in: FIFO slots, each request
    decodes for ``1 + len(text) % 3`` ticks, then finishes."""

    def __init__(self, version, slots=2):
        self.version = version
        self.slots = slots
        self.queue = []
        self.active = {}
        self._rid = 0

    def submit(self, text, *, max_new=8, prefix=None):
        r = Request(rid=self._rid, prompt_ids=[], max_new=max_new)
        self._rid += 1
        r.ticks_left = 1 + (len(text) % 3)
        r.src = text
        self.queue.append(r)
        return r

    def has_work(self):
        return bool(self.queue or self.active)

    def step(self):
        while self.queue and len(self.active) < self.slots:
            r = self.queue.pop(0)
            self.active[r.rid] = r
        finished = []
        for rid in list(self.active):
            r = self.active[rid]
            r.ticks_left -= 1
            if r.ticks_left <= 0:
                r.done, r.text = True, f"out({r.src})"
                del self.active[rid]
                finished.append(r)
        return finished


class FakeSession:
    """Duck-typed IOLMSession: versions == qsigs, sized per ``sizes``."""

    params = cfg = tok = None

    def __init__(self, sizes):
        self.sizes = sizes
        self.optimize_calls = []

    def _optimize(self, qsig, probe):
        self.optimize_calls.append(qsig)
        return SimpleNamespace(params=None, cfg=None, version=qsig)


def fake_pool(sizes, budget, slots=2):
    sess = FakeSession(sizes)
    pool = ModelPool(sess, budget,
                     engine_factory=lambda m: FakeEngine(m.version,
                                                         slots=slots),
                     entry_bytes=lambda m: sizes[m.version])
    return sess, pool


class TestModelPool:
    def test_lru_eviction_under_budget(self):
        sess, pool = fake_pool({"a": 40, "b": 40, "c": 40}, budget=100)
        ea = pool.engine_for("a")
        pool.engine_for("b")
        pool.engine_for("a")                     # refresh a
        pool.engine_for("c")                     # evicts b (LRU), not a
        assert pool.resident_versions == ["b", "a", "c"][1:]
        assert pool.eviction_log == ["b"]
        assert pool.resident_bytes == 80 <= pool.byte_budget
        assert pool.engine_for("a") is ea        # a survived

    def test_budget_is_hard_invariant(self):
        sess, pool = fake_pool({f"m{i}": 30 for i in range(10)}, budget=100)
        for i in range(10):
            pool.engine_for(f"m{i}")
            assert pool.resident_bytes <= pool.byte_budget
        assert len(pool) == 3                    # 3 * 30 <= 100

    def test_oversize_model_raises_unretryable(self):
        sess, pool = fake_pool({"big": 200}, budget=100)
        with pytest.raises(PoolBudgetError) as ei:
            pool.engine_for("big")
        assert not ei.value.retryable

    def test_pinned_entries_never_evicted(self):
        sess, pool = fake_pool({"a": 60, "b": 60}, budget=100)
        pool.engine_for("a")
        pool.pin("a")
        with pytest.raises(PoolBudgetError) as ei:
            pool.engine_for("b")                 # a pinned: cannot make room
        assert ei.value.retryable
        assert pool.resident_versions == ["a"]
        pool.unpin("a")
        pool.engine_for("b")                     # now a is evictable
        assert pool.eviction_log == ["a"]

    def test_retryable_refusal_evicts_nothing(self):
        """An admission that cannot succeed (pinned residents block the
        room) must not sacrifice warm unpinned engines on the way to
        failing."""
        sess, pool = fake_pool({"a": 60, "b": 30, "c": 50}, budget=100)
        pool.engine_for("a")
        pool.pin("a")
        pool.engine_for("b")                 # resident but idle
        with pytest.raises(PoolBudgetError) as ei:
            pool.engine_for("c")             # 60 pinned + 50 > 100
        assert ei.value.retryable
        assert pool.resident_versions == ["a", "b"]
        assert pool.eviction_log == []

    def test_blocked_submission_optimizes_once(self):
        """A budget-blocked pending submission resolves its model once
        and re-admits the memoized result per retry — no per-tick
        re-optimization, no phantom ModelCache hits."""
        sess, pool = fake_pool({"a": 80, "b": 80}, budget=100)
        sched = Scheduler(pool, share=2)
        sched.submit("t1", ["xxxx", "yyyy"], qsig="a")
        s2 = sched.submit("t2", ["zz"], qsig="b")
        sched.run()
        assert s2.done
        assert sess.optimize_calls.count("b") == 1

    def test_eviction_reoptimizes_on_readmit(self):
        sess, pool = fake_pool({"a": 60, "b": 60}, budget=100)
        pool.engine_for("a")
        pool.engine_for("b")                     # evicts a
        pool.engine_for("a")                     # miss: optimize again
        assert sess.optimize_calls == ["a", "b", "a"]
        assert pool.stats.misses == 3 and pool.stats.evictions == 2

    def test_resident_hit_skips_rebuild(self):
        sess, pool = fake_pool({"a": 10}, budget=100)
        e1 = pool.engine_for("a")
        e2 = pool.engine_for("a")
        assert e1 is e2
        assert pool.stats.hits == 1
        # _optimize still consulted (the session's ModelCache memoizes
        # the search itself); only the ENGINE build is skipped
        assert sess.optimize_calls == ["a", "a"]


class TestSchedulerFairness:
    def test_tenants_interleave_not_serialize(self):
        sizes = {"a": 10, "b": 10}
        sess, pool = fake_pool(sizes, budget=100, slots=4)
        sched = Scheduler(pool, share=2)
        s1 = sched.submit("t1", [f"p{i}" for i in range(6)], qsig="a")
        s2 = sched.submit("t2", [f"q{i}" for i in range(6)], qsig="b")
        sched.run()
        assert s1.done and s2.done
        assert len(s1.results()) == 6 and len(s2.results()) == 6
        # both tenants start finishing before either finishes everything
        assert max(s1.first_done_tick, s2.first_done_tick) \
            <= min(s1.last_done_tick, s2.last_done_tick)
        # the share bound held throughout
        assert s1.peak_inflight <= 2 and s2.peak_inflight <= 2

    def test_share_bounds_admission_per_tenant(self):
        sess, pool = fake_pool({"a": 10}, budget=100, slots=8)
        sched = Scheduler(pool, share=3)
        s = sched.submit("t", [f"p{i}" for i in range(10)], qsig="a")
        sched.run()
        assert s.peak_inflight <= 3

    def test_budget_wait_head_of_line_activation(self):
        """Budget fits one engine: tenant 2 waits pinned-out, then
        activates the moment tenant 1's submission finishes."""
        sess, pool = fake_pool({"a": 80, "b": 80}, budget=100)
        sched = Scheduler(pool, share=2)
        s1 = sched.submit("t1", ["x", "yy"], qsig="a")
        s2 = sched.submit("t2", ["zzz"], qsig="b")
        assert s1.active and not s2.active       # b blocked by pinned a
        sched.run()
        assert s1.done and s2.done
        assert pool.eviction_log == ["a"]        # evicted once unpinned
        assert len(s2.results()) == 1

    def test_oversize_submission_fails_alone(self):
        """A submission whose model can never fit the budget fails at
        activation without aborting other tenants' runs; its error
        surfaces from results(), not from step()/run()."""
        sess, pool = fake_pool({"ok": 40, "big": 200}, budget=100)
        sched = Scheduler(pool, share=2)
        s1 = sched.submit("t1", ["xx", "yy"], qsig="ok")
        s2 = sched.submit("t2", ["zz"], qsig="big")
        sched.run()                              # must not raise
        assert s1.done and len(s1.results()) == 2
        assert s2.done and s2.error is not None
        with pytest.raises(PoolBudgetError):
            s2.results()

    def test_zero_prompt_submission_completes(self):
        sess, pool = fake_pool({"a": 10}, budget=100)
        sched = Scheduler(pool, share=2)
        s = sched.submit("t", [], qsig="a")
        sched.run()
        assert s.done and s.results() == []


# ---------------------------------------------------------------------------
# real-model integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny(tiny_dense):
    return tiny_dense


ENGINE_KW = dict(slots=2, max_len=64, buckets=(16, 48))


def make_session(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("recipes", [W8])
    kw.setdefault("calib_rows", 4)
    kw.setdefault("eval_rows", 2)
    kw.setdefault("engine_kw", dict(ENGINE_KW))
    return IOLMSession(params, cfg, **kw)


class TestSchedulerIntegration:
    def test_concurrent_queries_match_serial_execution(self, tiny):
        langs = ["pyton", "javascrpt", "golang", "rst"]
        reviews = ["good mouse here", "bad lamp sadly", "fine chair ok"]

        def queries(sess):
            q1 = Query(Table({"lang": list(langs)}), sess) \
                .llm_correct("lang", max_new=6)
            q2 = Query(Table({"review": list(reviews)}), sess) \
                .llm_map("review", out_col="s", max_new=6)
            return q1, q2

        # concurrent: one pooled session, both plans interleaved
        pooled = make_session(tiny, pool_budget=64 * 1024 * 1024)
        q1, q2 = queries(pooled)
        res = Scheduler(pooled.pool, share=2).run_queries({"a": q1, "b": q2})
        # serial reference: fresh session, private engines, one at a time
        serial = make_session(tiny)
        r1, r2 = (q.run() for q in queries(serial))
        assert res["a"]["lang_fixed"] == r1["lang_fixed"]
        assert res["b"]["s"] == r2["s"]
        # both optimized models were resident simultaneously
        assert pooled.pool.stats.peak_resident_models >= 2

    def test_cross_tenant_dedup_decodes_once(self, tiny):
        sess = make_session(tiny, pool_budget=64 * 1024 * 1024)
        sched = Scheduler(sess.pool, share=4)
        prompts = [f"fix: val{i}" for i in range(4)]
        s1 = sched.submit("t1", list(prompts), qsig="q", optimize=False,
                          max_new=4)
        s2 = sched.submit("t2", list(prompts), qsig="q", optimize=False,
                          max_new=4)
        sched.run()
        assert s1.results() == s2.results()
        eng = s1.engine
        assert eng is s2.engine                  # same version -> same engine
        # tenant 2's rows all rode the result cache / follower path
        assert eng.stats.cache_hits >= len(prompts)
        assert eng.stats.rows == 2 * len(prompts)

    def test_serial_pooled_query_reuses_resident_engine(self, tiny):
        sess = make_session(tiny, pool_budget=64 * 1024 * 1024)
        t = Table({"lang": ["pyton", "javascrpt"]})
        Query(t, sess).llm_correct("lang", max_new=4).run()
        misses = sess.pool.stats.misses
        Query(t, sess).llm_correct("lang", max_new=4).run()
        assert sess.pool.stats.misses == misses      # engine stayed resident
        assert sess.pool.stats.hits >= 1
        assert sess.model_cache.hits >= 1

    def test_slot_state_bytes_positive_and_scales(self, tiny):
        cfg, _ = tiny
        b64 = slot_state_bytes(cfg, 64)
        b128 = slot_state_bytes(cfg, 128)
        assert 0 < b64 < b128


# ---------------------------------------------------------------------------
# the cross-tenant PrefixCache regression (satellite)
# ---------------------------------------------------------------------------

TEMPLATE = "fix the category value please: "


class TestSharedPrefixCacheIsolation:
    def test_no_prefilled_state_leaks_across_model_versions(self, tiny):
        """Two tenants share one PrefixCache (the pool arrangement) and
        one rendered template, but run different compressed models: the
        version component of the key must keep their prefilled states
        apart — outputs must equal private-cache runs exactly."""
        cfg, params = tiny
        opt = InstanceOptimizer(params, cfg)
        p8, c8, _ = opt.apply(W8)
        kw = dict(slots=2, max_len=96, buckets=(16, 64))
        prompts = [f"{TEMPLATE}val{i}" for i in range(5)]

        shared = PrefixCache(capacity=8)
        e_base = Engine(params, cfg, version="base", prefix_cache=shared,
                        **kw)
        e_int8 = Engine(p8, c8, version="q:w8", prefix_cache=shared, **kw)
        out_base = e_base.generate_stream(iter(prompts), max_new=6,
                                          prefix=TEMPLATE)
        out_int8 = e_int8.generate_stream(iter(prompts), max_new=6,
                                          prefix=TEMPLATE)
        # both engines exercised the prefix path for real
        assert e_base.stats.prefix_hits > 0
        assert e_int8.stats.prefix_hits > 0
        # one entry per model version, same token prefix
        assert len(shared) == 2

        # private-cache references: the ground truth each tenant would
        # have produced with no sharing at all
        r_base = Engine(params, cfg, version="base", **kw) \
            .generate_stream(iter(prompts), max_new=6, prefix=TEMPLATE)
        r_int8 = Engine(p8, c8, version="q:w8", **kw) \
            .generate_stream(iter(prompts), max_new=6, prefix=TEMPLATE)
        assert out_base == r_base
        assert out_int8 == r_int8
        # both entries live under the SAME token prefix, split by version
        versions = sorted(v for _, v in shared._d)
        assert versions == ["base", "q:w8"]
        assert len({ids for ids, _ in shared._d}) == 1
