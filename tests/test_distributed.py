"""Distribution layer: sharding specs (pure), multi-device via subprocess.

The sharding *rules* are pure functions testable on 1 device; real
multi-device behaviour (shard_map collectives, mesh jit) runs in a
subprocess with --xla_force_host_platform_device_count=8 so the main
pytest process keeps its single-device view.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.launch import hlo_analysis as HA

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": SRC}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = u8[64]{0} collective-permute(u8[64]{0} %w)
  %a2a = s8[4,16]{1,0} all-to-all(s8[4,16]{1,0} %v), dimensions={0}
"""
    d = HA.collective_bytes(hlo)
    assert d["all-gather"] == 8 * 128 * 2
    assert d["all-reduce"] == 256 * 4
    assert d["reduce-scatter"] == 32 * 4
    assert d["collective-permute"] == 64
    assert d["all-to-all"] == 4 * 16


def test_roofline_terms_and_bound():
    r = HA.Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                    coll_bytes=50e9 * 0.5, chips=256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bound == "memory"


def test_param_specs_megatron_pattern():
    """Column/row-parallel assignment + divisibility guards (pure)."""
    out = run_py("""
        import jax, json
        from repro.configs import registry
        from repro.distributed import sharding as SH
        from repro.models import api
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = registry.get_config("mistral-nemo-12b")
        sds = jax.eval_shape(lambda: api.init_params(
            jax.random.PRNGKey(0), cfg))
        sh = SH.param_shardings(cfg, sds, mesh)
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        specs = {".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path): s.spec for path, s in flat}
        get = lambda sfx: [str(v) for k, v in specs.items()
                           if k.endswith(sfx)][0]
        print(json.dumps({
            "wq": get("attn.wq"), "wo": get("attn.wo"),
            "wi": get("mlp.wi"), "embed": get("embed"),
            "ln": get("ln1.w")}))
    """)
    specs = json.loads(out.strip().splitlines()[-1])
    # column-parallel: model axis on the LAST dim; row-parallel: earlier
    assert specs["wq"].rstrip(")").endswith("'model'")
    assert "'model'" in specs["wo"] and not specs["wo"].rstrip(")").endswith(
        "'model'")
    assert specs["wi"].rstrip(")").endswith("'model'")
    assert "model" not in specs["ln"]        # norms replicated
    assert "'model'" in specs["embed"]


def test_compressed_allreduce_multidevice():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.training import grad_compress as GC
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)}
        res = GC.init_residual(g)
        g2, r2 = GC.compressed_allreduce(g, res, axis="pod", mesh=mesh)
        rel = float(jnp.max(jnp.abs(g2["a"] - g["a"]))
                    / jnp.max(jnp.abs(g["a"])))
        txt = jax.jit(lambda g, r: GC.compressed_allreduce(
            g, r, axis="pod", mesh=mesh)).lower(g, res).compile().as_text()
        print("REL", rel)
        print("WIRE_INT8", ("s8" in txt and "all-to-all" in txt))
    """)
    assert "WIRE_INT8 True" in out
    rel = float([l for l in out.splitlines() if l.startswith("REL")][0]
                .split()[1])
    assert rel < 0.03


def test_small_mesh_train_step_lowers_with_collectives():
    """A sharded train step on 8 host devices compiles and all-reduces."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.distributed import sharding as SH
        from repro.models import api
        from repro.training import optimizer as OPT
        from repro.training.train_loop import make_train_step
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = registry.get_reduced("mistral-nemo-12b")
        sds = jax.eval_shape(lambda: api.init_params(
            jax.random.PRNGKey(0), cfg))
        psh = SH.param_shardings(cfg, sds, mesh)
        opt = OPT.adamw()
        osh = SH.opt_state_shardings(psh, mesh, "adamw")
        osds = jax.eval_shape(opt.init, sds)
        bsh = SH.batch_shardings(cfg, {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}, mesh)
        bsds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        step = make_train_step(cfg, opt)
        with mesh:
            c = jax.jit(step, in_shardings=(psh, osh, bsh,
                        NamedSharding(mesh, P()))).lower(
                sds, osds, bsds, jax.ShapeDtypeStruct((), jnp.int32)
                ).compile()
        txt = c.as_text()
        print("HAS_AR", "all-reduce" in txt)
        ca = c.cost_analysis()
        if isinstance(ca, list):   # newer JAX: one dict per partition
            ca = ca[0]
        print("FLOPS_OK", float(ca.get("flops", 0.0)) > 0)
    """)
    assert "HAS_AR True" in out
    assert "FLOPS_OK True" in out


def test_policy_search_selects_variants():
    """Recipe search returns Perf/Acc with the paper's normalization."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import registry
        from repro.core.pipeline import InstanceOptimizer, Recipe
        from repro.core import policy as POL
        from repro.models import api
        cfg = registry.get_reduced("mistral-nemo-12b")
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 200)
        opt = InstanceOptimizer(params, cfg)
        opt.run_calibration({"tokens": prompts})
        eval_fn = POL.make_agreement_eval(params, cfg, prompts, max_new=4)
        outcome = POL.search(opt, eval_fn,
                             [Recipe(name="w8", wbits=8),
                              Recipe(name="w4", wbits=4, group=32)],
                             acc_floor=0.5)
        print("BASE_ACC", outcome.baseline.accuracy)
        print("N", len(outcome.candidates))
        print("PERF", outcome.perf.recipe.name if outcome.perf else None)
        print("ACC", outcome.acc.recipe.name if outcome.acc else None)
        print("SMALLER", all(c.result.bytes < outcome.baseline.bytes
                             for c in outcome.candidates))
    """, devices=1)
    assert "BASE_ACC 1.0" in out          # baseline agrees with itself
    assert "N 2" in out
    assert "SMALLER True" in out


def test_pipeline_parallel_matches_sequential():
    """GPipe stage scan == sequential layer application (4 stages)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.training.pipeline import pipeline_forward, split_stages
        mesh = jax.make_mesh((4,), ("stage",))
        L, d = 8, 16
        ks = jax.random.split(jax.random.PRNGKey(0), L)
        layers = {"w": jnp.stack([jax.random.normal(k, (d, d)) * 0.2
                                  for k in ks])}
        def stage_fn(p, x):
            def body(xc, w):
                return jnp.tanh(xc @ w), None
            y, _ = jax.lax.scan(body, x, p["w"])
            return y
        stages = split_stages(layers, 4)
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 3, d))  # M=6 mbs
        got = pipeline_forward(stage_fn, stages, x, mesh=mesh)
        # sequential reference
        def seq(xm):
            def body(xc, w):
                return jnp.tanh(xc @ w), None
            y, _ = jax.lax.scan(body, xm, layers["w"])
            return y
        want = jax.vmap(seq)(x)
        print("ERR", float(jnp.max(jnp.abs(got - want))))
    """, devices=4)
    err = float([l for l in out.splitlines() if l.startswith("ERR")][0]
                .split()[1])
    assert err < 1e-5
