"""Async serving core: submit/step/drain, batched insert, dedup, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import api
from repro.olap import operators as OPS
from repro.olap.table import Table
from repro.serving.engine import Engine
from repro.serving.sampler import SamplingConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="ta", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=260,
                      max_seq=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestAsyncCore:
    def test_interleaved_submit_during_decode(self, tiny):
        """submit() mid-flight lands in a free slot and matches the
        output of a fresh all-at-once run (greedy is deterministic)."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        r1 = eng.submit("alpha", max_new=6)
        r2 = eng.submit("beta", max_new=6)
        eng.step()                      # both admitted, decode in flight
        assert not r1.done and not r2.done
        r3 = eng.submit("gamma", max_new=6)     # streams in mid-decode
        eng.drain()
        assert all(r.done for r in (r1, r2, r3))
        ref = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        assert ref.generate(["alpha", "beta", "gamma"], max_new=6) \
            == [r1.text, r2.text, r3.text]

    def test_follower_attaches_to_inflight_leader(self, tiny):
        """A duplicate of a request that is ALREADY decoding rides on it:
        no second prefill, no slot, identical output."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=1, max_len=64, buckets=(16,))
        r1 = eng.submit("twin prompt", max_new=6)
        eng.step()                      # r1 now active in the only slot
        assert not r1.done
        r2 = eng.submit("twin prompt", max_new=6)
        eng.drain()
        assert r2.done and r2.text == r1.text
        assert eng.stats.prefills == 1
        assert eng.stats.cache_hits == 1

    def test_batched_admission_single_insert_call(self, tiny):
        """An N-row admission batch scatters into slots with exactly one
        jitted insert call (no per-row scatter loop)."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=4, max_len=64, buckets=(16,),
                     use_result_cache=False)
        calls = []
        orig = eng._insert

        def counting_insert(*a, **kw):
            calls.append(1)
            return orig(*a, **kw)

        eng._insert = counting_insert
        outs = eng.generate(["a1", "b22", "c333", "d4444"], max_new=3)
        assert len(outs) == 4
        assert len(calls) == 1

    def test_drain_empty_engine_is_noop(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,))
        assert eng.drain() == []
        assert eng.step() == []


class TestSampling:
    def test_temperature_zero_bitwise_matches_greedy_default(self, tiny):
        """Explicit temperature=0 config lowers to the same greedy decode
        as the default engine — bitwise-identical outputs."""
        cfg, params = tiny
        texts = ["check me", "and me too", "third row"]
        base = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                      use_result_cache=False)
        t0 = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                    use_result_cache=False,
                    sampling=SamplingConfig(temperature=0.0, seed=123))
        assert base.generate(texts, max_new=8) == t0.generate(texts,
                                                              max_new=8)

    def test_greedy_matches_reference_decode(self, tiny):
        """Slot-vmapped sampled decode (temp=0) == direct api greedy."""
        from repro.core.policy import greedy_decode
        from repro.training import data as D
        cfg, params = tiny
        tok = D.ByteTokenizer(260)
        text = "check me"
        ids = tok.encode(text, bos=True) + [tok.SEP]
        toks = np.zeros((1, 16), np.int32)
        toks[0, :len(ids)] = ids
        ref = greedy_decode(params, cfg, jnp.asarray(toks), 6,
                            lengths=jnp.asarray([len(ids)]))
        eng = Engine(params, cfg, slots=1, max_len=64, buckets=(16,),
                     use_result_cache=False,
                     sampling=SamplingConfig(temperature=0.0))
        out = eng.generate([text], max_new=6)[0]
        want = tok.decode([t for t in np.asarray(ref)[0] if t != tok.EOS])
        assert out == want

    def test_admission_waves_sample_independently(self, tiny):
        """Regression: successive admission waves must not reuse one
        PRNG key (identical prompts drew identical first tokens)."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False,
                     sampling=SamplingConfig(temperature=1.5, seed=0))
        reqs = [eng.submit("same prompt", max_new=2) for _ in range(8)]
        eng.drain()                     # 4 admission waves of 2 slots
        waves = [tuple(r.out_ids[0] for r in reqs[i:i + 2])
                 for i in range(0, 8, 2)]
        assert len(set(waves)) > 1

    def test_max_new_budget_exact(self, tiny):
        """Regression: max_new=1 must yield exactly one token (the
        prefill-sampled token), not burn a decode step for a second."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        r = eng.submit("hello", max_new=1)
        eng.drain()
        assert r.done and len(r.out_ids) == 1
        assert eng.stats.decode_steps == 0

    def test_eos_at_prefill_retires_without_decoding(self, tiny):
        """Regression: a first (prefill-sampled) token == EOS must end
        the row — no slot occupancy, no post-EOS junk in the text."""
        from repro.serving import engine as E
        cfg, params = tiny
        eng = Engine(params, cfg, slots=1, max_len=64, buckets=(16,),
                     use_result_cache=False)
        # force the admission sample to EOS regardless of the model
        orig = E.sample
        E.sample = lambda logits, key, **kw: jnp.full(
            logits.shape[:-1], eng.tok.EOS, jnp.int32)
        try:
            r = eng.submit("ends at once", max_new=8)
            eng.drain()
        finally:
            E.sample = orig
        assert r.done and r.text == ""
        assert r.out_ids == [eng.tok.EOS]
        assert eng.stats.decode_steps == 0

    def test_sampled_decode_deterministic_per_seed(self, tiny):
        cfg, params = tiny
        mk = lambda s: Engine(params, cfg, slots=2, max_len=64,
                              buckets=(16,), use_result_cache=False,
                              sampling=SamplingConfig(temperature=0.9,
                                                      top_k=8, seed=s))
        texts = ["sample a", "sample b"]
        assert mk(7).generate(texts, max_new=6) \
            == mk(7).generate(texts, max_new=6)


class TestBucketsAndStats:
    def test_bucket_ladder_never_empty(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=1, max_len=32, buckets=(64, 128))
        assert eng.buckets and max(eng.buckets) < 32
        assert len(eng.generate(["hello"], max_new=2)) == 1

    def test_long_prompt_truncation_surfaced(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=1, max_len=32, buckets=(16,),
                     use_result_cache=False)
        req = eng.submit("z" * 200, max_new=2)
        eng.drain()
        assert req.truncated
        assert eng.stats.truncated == 1

    def test_cache_accounting_consistent(self, tiny):
        """Regression: follower dedup counts exactly ONE hit (the old
        path recorded a miss in get() then manually bumped hits)."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,))
        eng.generate(["same", "same", "same"], max_new=4)
        rc = eng.result_cache
        assert (rc.hits, rc.misses) == (2, 1)
        assert eng.stats.cache_hits == rc.hits
        assert abs(rc.hit_rate - 2 / 3) < 1e-9
        eng.generate(["same"], max_new=4)        # stored-result hit
        assert (rc.hits, rc.misses) == (3, 1)
        assert eng.stats.cache_hits == rc.hits

    def test_slot_utilization_tracked(self, tiny):
        cfg, params = tiny
        eng = Engine(params, cfg, slots=4, max_len=64, buckets=(16,),
                     use_result_cache=False)
        eng.generate(["only one row"], max_new=4)
        assert 0.0 < eng.stats.slot_utilization <= 0.25 + 1e-9


class TestStreamingOperators:
    def test_llm_join_residency_bounded_by_chunk(self, tiny):
        """O(n·k) join candidates stream through the engine: peak
        resident requests track the chunk bound, not the pair count."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(32,),
                     use_result_cache=False)
        n = 12
        left = Table({"name": [f"acme{i}" for i in range(n)]})
        right = Table({"name": [f"acme{i}x" for i in range(n)]})
        chunk = 4
        OPS.llm_join(left, right, ("name", "name"), eng, max_new=2,
                     chunk=chunk)
        pairs = n * n          # single block: every left x every right
        assert eng.stats.rows == pairs
        assert eng.stats.peak_inflight <= chunk + eng.slots
        assert eng.stats.peak_inflight < pairs

    def test_streamed_map_matches_generate(self, tiny):
        cfg, params = tiny
        vals = [f"row {i}" for i in range(9)]
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(32,),
                     use_result_cache=False)
        t = OPS.llm_map(Table({"c": vals}), "c", eng, prompt="sum: ",
                        out_col="o", max_new=4, chunk=3)
        ref = Engine(params, cfg, slots=2, max_len=64, buckets=(32,),
                     use_result_cache=False)
        assert t["o"] == ref.generate(["sum: " + v for v in vals],
                                      max_new=4)

    def test_generator_prompts_freed_after_completion(self, tiny):
        """Finished requests drop their prompt ids (residency bound)."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        outs = OPS._invoke(eng, (f"p{i}" for i in range(6)), max_new=2,
                           chunk=2)
        assert len(outs) == 6 and all(isinstance(o, str) for o in outs)

    def test_stream_throttle_ignores_foreign_completions(self, tiny):
        """Regression: requests submitted outside generate_stream must
        not loosen its chunk bound when they finish mid-stream."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        foreign = eng.submit("foreign row", max_new=2)
        outs = eng.generate_stream((f"s{i}" for i in range(6)), max_new=2,
                                   chunk=2)
        assert foreign.done                     # drained alongside
        assert len(outs) == 6
        # bound: chunk of this call + slots + the one foreign request
        assert eng.stats.peak_inflight <= 2 + eng.slots + 1
        ref = Engine(params, cfg, slots=2, max_len=64, buckets=(16,),
                     use_result_cache=False)
        assert outs == ref.generate([f"s{i}" for i in range(6)], max_new=2)

    def test_stream_throttle_skips_followers(self, tiny):
        """Regression: followers (deduped duplicates, no prompt/slot)
        must not stall admission of later distinct prompts — A and B
        decode concurrently even with duplicates of A in between."""
        cfg, params = tiny
        eng = Engine(params, cfg, slots=2, max_len=64, buckets=(16,))
        prompts = ["aaa", "aaa", "aaa", "aaa", "bbb"]
        outs = eng.generate_stream(iter(prompts), max_new=6, chunk=2)
        assert len(outs) == 5 and outs[0] == outs[1] == outs[2] == outs[3]
        # A and B were admitted into slots together: some decode steps
        # ran 2 busy slots (with the stall bug, A always decoded alone)
        assert eng.stats.busy_slot_steps > eng.stats.decode_steps
