"""Compression correctness: quantization, sparsification, pipeline e2e."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import registry
from repro.core import quantize as Q
from repro.core import sparsify as S
from repro.core.calibrate import calibrate
from repro.core.compressed import (QTensor, param_bytes, quantize_embed)
from repro.core.pipeline import InstanceOptimizer, Recipe
from repro.models import api

RNG = np.random.default_rng(0)


def _w(K=128, N=64):
    return RNG.normal(size=(K, N)).astype(np.float32)


def _H(K=128, rows=512):
    X = RNG.normal(size=(rows, K)).astype(np.float64)
    return X.T @ X


class TestQuantize:
    def test_absmax_error_bound(self):
        w = _w()
        qt = Q.absmax_quantize(w, bits=8, group=32)
        wd = np.asarray(qt.dequantize(), np.float32)
        # max error per element <= scale/2 (+ bf16 rounding slack)
        smax = np.asarray(qt.scale).repeat(32, 0)
        assert np.all(np.abs(w - wd) <= smax * 0.5 + 0.02 * np.abs(w) + 1e-3)

    def test_gptq_beats_absmax_in_hessian_norm(self):
        w, H = _w(), _H()
        g = Q.gptq_quantize(w, H, bits=4, group=32)
        a = Q.absmax_quantize(w, bits=4, group=32)
        eg = Q.quant_error(w, g, H)
        ea = Q.quant_error(w, a, H)
        assert eg < ea, (eg, ea)

    def test_int4_pack_roundtrip(self):
        codes = RNG.integers(-8, 8, size=(64, 32)).astype(np.int8)
        qt = QTensor(Q.pack_int4(jnp.asarray(codes)),
                     jnp.ones((2, 32), jnp.float32), 4, 32, (64, 32))
        got = np.asarray(qt.unpack())
        np.testing.assert_array_equal(got, codes)

    def test_smoothquant_flattens_activation_outliers(self):
        w = _w()
        amax = np.ones(128, np.float32)
        amax[7] = 100.0                      # an outlier channel
        s = Q.smooth_scales(amax, w, alpha=0.5)
        assert s[7] > np.median(s) * 3       # outlier migrated into weight
        qt = Q.absmax_quantize(w, bits=8, group=128, amax_x=amax,
                               smooth_alpha=0.5)
        # dequantize folds in_scale back: reconstruction still close to w
        wd = np.asarray(qt.dequantize(), np.float32)
        assert np.abs(w - wd).mean() < 0.02

    def test_qembed_roundtrip_and_logits(self):
        table = RNG.normal(size=(50, 16)).astype(np.float32)
        qe = quantize_embed(jnp.asarray(table))
        got = np.asarray(qe.lookup(jnp.arange(50)), np.float32)
        np.testing.assert_allclose(got, table, atol=2e-2, rtol=2e-2)
        x = RNG.normal(size=(3, 16)).astype(np.float32)
        lg = np.asarray(qe.logits(jnp.asarray(x, jnp.float32).astype(jnp.bfloat16)))
        np.testing.assert_allclose(lg, x @ table.T, atol=0.5, rtol=0.1)


class TestSparsify:
    def test_wanda_nm_structure(self):
        w = _w()
        mask = S.wanda_mask(w, np.ones(128, np.float32), n=2, m=4)
        g = mask.reshape(32, 4, 64).sum(1)
        assert (g == 2).all()

    def test_wanda_unstructured_sparsity(self):
        w = _w()
        mask = S.wanda_mask(w, np.ones(128, np.float32), sparsity=0.5)
        assert abs(mask.mean() - 0.5) < 0.02

    def test_sparsegpt_lower_error_than_wanda(self):
        """Error propagation must beat naive masking in ||E^T H E||."""
        w, H = _w(), _H()
        act = np.sqrt(np.diag(H)).astype(np.float32)
        wsg, msg = S.sparsegpt_prune(w, H, sparsity=0.5)
        mwd = S.wanda_mask(w, act, sparsity=0.5)
        wwd = np.where(mwd, w, 0.0)
        err = lambda wp: np.sqrt(np.einsum("io,ij,jo->", w - wp, H, w - wp))
        assert err(wsg) < err(wwd), (err(wsg), err(wwd))

    def test_block_mask_uniform_columns(self):
        w = _w(128, 128)
        m = S.block_sparse_mask(w, bs=32, density=0.5)
        assert (m.sum(0) == 2).all()
        bst = S.apply_block_mask(w, m, 32)
        assert bst.idx.shape == (4, 2)
        assert 0.49 < bst.density() < 0.51


class TestPipeline:
    @pytest.mark.parametrize("arch,recipe", [
        ("mistral-nemo-12b", Recipe(name="w8", wbits=8)),
        ("qwen2-moe-a2.7b", Recipe(name="m", wbits=8, experts_keep=4)),
        ("rwkv6-3b", Recipe(name="r", wbits=8, ffn_keep_frac=0.75)),
        ("zamba2-7b", Recipe(name="z", wbits=8, kv_keep_frac=0.5)),
        ("whisper-base", Recipe(name="w", wbits=8, drop_units=1)),
        ("gemma3-1b", Recipe(name="g", wbits=4, group=32,
                             quant_embed=True)),
    ])
    def test_e2e_compression(self, arch, recipe, reduced_models):
        cfg, params = reduced_models[arch]
        batch = make_batch(cfg)
        opt = InstanceOptimizer(params, cfg)
        opt.run_calibration(batch)
        p2, c2, rep = opt.apply(recipe)
        assert rep.bytes_after < rep.bytes_before
        logits, _ = api.forward(p2, c2, batch)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
        # decode path too
        cache = api.init_cache(c2, 2, 64)
        lg, _ = api.decode_step(p2, c2, cache, batch["tokens"][:, :1],
                                jnp.zeros((2,), jnp.int32), max_len=64)
        assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32))))

    def test_w8_preserves_argmax(self, reduced_models):
        cfg, params = reduced_models["mistral-nemo-12b"]
        batch = make_batch(cfg, B=4)
        opt = InstanceOptimizer(params, cfg)
        opt.run_calibration(batch)
        p2, c2, _ = opt.apply(Recipe(name="w8", wbits=8))
        l1, _ = api.forward(params, cfg, batch)
        l2, _ = api.forward(p2, c2, batch)
        agree = float(jnp.mean(jnp.argmax(l1[:, -1], -1)
                               == jnp.argmax(l2[:, -1], -1)))
        assert agree == 1.0

    def test_compression_ratio_reported(self, reduced_models):
        cfg, params = reduced_models["granite-20b"]
        opt = InstanceOptimizer(params, cfg)
        opt.run_calibration(make_batch(cfg))
        _, _, rep = opt.apply(Recipe(name="w4", wbits=4, group=32,
                                     quant_method="absmax"))
        assert rep.compression > 2.0   # int4 + f32 scales vs bf16

    def test_calibration_hessian_is_gram_matrix(self, reduced_models):
        cfg, params = reduced_models["mistral-nemo-12b"]
        batch = make_batch(cfg)
        stats = calibrate(params, cfg, batch, hessian=True)
        key = sorted(k for k in stats.weights if k.endswith("attn.wq"))[0]
        st = stats.weights[key]
        assert st.H is not None and st.H.shape[0] == st.H.shape[1]
        evs = np.linalg.eigvalsh(st.H)
        assert evs.min() > -1e-5          # PSD
        assert st.count > 0 and st.sqnorm.min() >= 0
