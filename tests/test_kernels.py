"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as Q
from repro.core import sparsify as S
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _rel(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-9)


class TestQuantMatmul:
    @pytest.mark.parametrize("M,K,N,g", [
        (8, 128, 128, 128), (64, 256, 128, 64), (1, 512, 256, 128),
        (130, 256, 384, 32), (16, 1024, 128, 128),
    ])
    def test_shapes(self, M, K, N, g):
        w = RNG.normal(size=(K, N)).astype(np.float32)
        qt = Q.absmax_quantize(w, bits=8, group=g)
        x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32).astype(
            jnp.bfloat16)
        got = ops.quant_matmul(x, qt.q, qt.scale, group=qt.group,
                               interpret=True)
        want = ref.quant_matmul(x, qt.q, qt.scale, group=qt.group)
        assert _rel(got, want) < 2e-2

    @pytest.mark.parametrize("xdtype", [jnp.bfloat16, jnp.float32])
    def test_dtypes(self, xdtype):
        w = RNG.normal(size=(256, 128)).astype(np.float32)
        qt = Q.absmax_quantize(w, bits=8, group=128)
        x = jnp.asarray(RNG.normal(size=(32, 256))).astype(xdtype)
        got = ops.quant_matmul(x, qt.q, qt.scale, group=qt.group,
                               interpret=True)
        want = ref.quant_matmul(x, qt.q, qt.scale, group=qt.group)
        assert _rel(got, want) < 2e-2
        assert got.dtype == xdtype

    def test_batched_input_reshape(self):
        w = RNG.normal(size=(128, 64)).astype(np.float32)
        qt = Q.absmax_quantize(w, bits=8, group=64)
        x = jnp.asarray(RNG.normal(size=(2, 5, 128)), jnp.bfloat16)
        got = ops.quant_matmul(x, qt.q, qt.scale, group=qt.group,
                               interpret=True)
        assert got.shape == (2, 5, 64)

    def test_in_scale_smoothquant(self):
        w = RNG.normal(size=(256, 128)).astype(np.float32)
        amax = np.abs(RNG.normal(size=256)).astype(np.float32) + 0.5
        qt = Q.absmax_quantize(w, bits=8, group=128, amax_x=amax,
                               smooth_alpha=0.5)
        assert qt.in_scale is not None
        x = jnp.asarray(RNG.normal(size=(16, 256)), jnp.bfloat16)
        got = ops.quant_matmul(x, qt.q, qt.scale, group=qt.group,
                               in_scale=qt.in_scale, interpret=True)
        want = ref.quant_matmul(x, qt.q, qt.scale, group=qt.group,
                                in_scale=qt.in_scale)
        assert _rel(got, want) < 2e-2


class TestBlockSparse:
    @pytest.mark.parametrize("K,N,bs,dens", [
        (256, 256, 64, 0.5), (512, 128, 128, 0.75), (128, 256, 32, 0.25),
    ])
    def test_skips_match_oracle(self, K, N, bs, dens):
        w = RNG.normal(size=(K, N)).astype(np.float32)
        m = S.block_sparse_mask(w, bs=bs, density=dens)
        bst = S.apply_block_mask(w, m, bs)
        x = jnp.asarray(RNG.normal(size=(16, K)), jnp.bfloat16)
        got = ops.block_sparse_matmul(x, bst.w, bst.idx, bs=bs,
                                      interpret=True)
        want = ref.block_sparse_matmul(x, bst.w, bst.mask, bs=bs)
        assert _rel(got, want) < 2e-2


class TestFlashAttention:
    @pytest.mark.parametrize("B,S_,T,H,Kh,D,win,cap", [
        (2, 64, 64, 4, 2, 64, 0, 0.0),      # GQA causal
        (1, 128, 128, 8, 1, 32, 32, 0.0),   # MQA sliding window
        (2, 64, 64, 4, 4, 64, 0, 30.0),     # MHA with softcap (gemma2)
        (1, 64, 192, 2, 2, 32, 0, 0.0),     # cross len (q_offset decode)
    ])
    def test_variants(self, B, S_, T, H, Kh, D, win, cap):
        q = jnp.asarray(RNG.normal(size=(B, S_, H, D)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(B, T, Kh, D)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(B, T, Kh, D)), jnp.bfloat16)
        off = T - S_
        got = ops.flash_attention(q, k, v, causal=True, window=win,
                                  softcap=cap, q_offset=off, interpret=True)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S_, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, T, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, T, D)
        want = ref.attention(qf, kf, vf, causal=True, window=win,
                             softcap=cap, q_offset=off)
        want = want.reshape(B, H, S_, D).transpose(0, 2, 1, 3)
        assert _rel(got, want) < 2e-2

    def test_matches_model_attention(self):
        """Kernel agrees with the model's own full_attention path."""
        from repro.models import layers as L
        B, S_, H, Kh, D = 2, 64, 4, 2, 32
        q = jnp.asarray(RNG.normal(size=(B, S_, H, D)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, S_, Kh, D)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, S_, Kh, D)), jnp.float32)
        got = ops.flash_attention(q.astype(jnp.bfloat16),
                                  k.astype(jnp.bfloat16),
                                  v.astype(jnp.bfloat16), causal=True,
                                  interpret=True)
        want = L.full_attention(q, k, v, causal=True)
        assert _rel(got, want) < 3e-2


class TestPagedAttention:
    @pytest.mark.parametrize("win,cap", [
        (0, 0.0), (24, 0.0), (0, 30.0), (24, 30.0),
    ])
    def test_matches_masked_decode(self, win, cap):
        """The paged kernel, gathering K/V through a scrambled block
        table, matches the model's contiguous decode attention."""
        from repro.models.transformer import _masked_decode
        S, T, H, Kh, D, bs = 3, 64, 4, 2, 32, 16
        nblk = T // bs
        q = jnp.asarray(RNG.normal(size=(S, 1, H, D)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(S, T, Kh, D)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(S, T, Kh, D)), jnp.float32)
        lengths = np.array([17, 40, 64], np.int32)
        # scatter each row's KV into a scrambled global pool (+1 spare
        # block that no table references)
        tables = np.asarray(RNG.permutation(S * nblk), np.int32) \
            .reshape(S, nblk)
        kp = np.zeros((S * nblk + 1, bs, Kh, D), np.float32)
        vp = np.zeros_like(kp)
        for s in range(S):
            for j in range(nblk):
                kp[tables[s, j]] = np.asarray(k[s, j * bs:(j + 1) * bs])
                vp[tables[s, j]] = np.asarray(v[s, j * bs:(j + 1) * bs])
        got = ops.paged_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                                  tables, lengths, softcap=cap,
                                  window=win, interpret=True)
        kpos = np.arange(T)
        valid = kpos[None, :] < lengths[:, None]
        if win:
            valid &= kpos[None, :] >= (lengths[:, None] - win)
        want = _masked_decode(q, k, v, jnp.asarray(valid), cap)
        assert _rel(got, want) < 1e-5


class TestKernelDispatch:
    def test_pallas_backend_routes_qtensor(self, monkeypatch):
        from repro.core import compressed as C
        w = RNG.normal(size=(128, 64)).astype(np.float32)
        qt = Q.absmax_quantize(w, bits=8, group=64)
        x = jnp.asarray(RNG.normal(size=(4, 128)), jnp.bfloat16)
        base = C.matmul(x, qt)          # default backend: reference on CPU
        calls = {}
        import repro.kernels.ops as kops
        orig = kops.quant_matmul
        def spy(*a, **k):
            calls["hit"] = True
            return orig(*a, **k)
        monkeypatch.setattr(kops, "quant_matmul", spy)
        with C.kernel_backend("pallas"):
            out = C.matmul(x, qt)
        assert calls.get("hit")
        # the off-TPU fallback computes the reference formula verbatim:
        # dispatch through the pallas backend is BYTE-identical on CPU
        assert np.array_equal(np.asarray(out), np.asarray(base))

    def test_backend_scoping_restores_default(self):
        from repro.core import compressed as C
        from repro.kernels.backend import resolve_backend
        assert resolve_backend("auto") == "reference"   # CPU test platform
        with C.kernel_backend("pallas"):
            assert C.current_backend() == "pallas"
            with C.kernel_backend("reference"):
                assert C.current_backend() == "reference"
            assert C.current_backend() == "pallas"
        assert C.current_backend() == "reference"

    def test_backend_validation(self):
        from repro.kernels.backend import normalize_backend
        with pytest.raises(ValueError):
            normalize_backend("cuda")
        assert normalize_backend(None) == "auto"
        assert normalize_backend("PALLAS") == "pallas"

    def test_use_kernels_shim_warns_and_maps(self):
        from repro.core import compressed as C
        with pytest.warns(DeprecationWarning):
            C.use_kernels(True)
        try:
            with pytest.warns(DeprecationWarning):
                assert C.kernels_enabled()
        finally:
            with pytest.warns(DeprecationWarning):
                C.use_kernels(False)
        with pytest.warns(DeprecationWarning):
            assert not C.kernels_enabled()
