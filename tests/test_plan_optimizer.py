"""Logical plan IR + rule-based optimizer + physical planner.

Everything here runs against deterministic fake engines — the plan
pipeline (plan -> optimize -> lower -> execute) is model-agnostic, and
the byte-identity guarantees it must uphold are exactly checkable with
a fake whose outputs are a pure function of the prompt.
"""
import dataclasses

import pytest

from repro.olap import operators as OPS
from repro.olap import optimizer as OPT
from repro.olap import physical as PHYS
from repro.olap import plan as P
from repro.olap.query import Query
from repro.olap.table import Table


class FakeEngine:
    """Output is a pure function of the prompt (like greedy decode)."""

    def __init__(self, fn=None):
        self.fn = fn or (lambda p: "out(" + p + ")")
        self.calls = []

    def generate(self, prompts, max_new=8):
        prompts = list(prompts)
        self.calls.extend(prompts)
        return [self.fn(p) for p in prompts]


class FakeSession:
    calib_rows = 4
    eval_rows = 2
    pool = None

    def __init__(self, fn=None):
        self.log = []
        self.eng = FakeEngine(fn)
        self.probes = []

    def base_engine(self):
        return self.eng

    def optimized_engine(self, qsig, probe):
        self.probes.append((qsig, list(probe)))
        return self.eng


def table():
    return Table({"category": ["a", "b", "a", "a", "c", "b", "a", "c"],
                  "status": ["ok", "bad", "ok", "bad", "ok", "ok",
                             "bad", "ok"]})


class TestPlanIR:
    def test_builder_appends_immutable_nodes(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="label") \
            .filter(lambda r: True, columns=["status"])
        nodes = P.chain(q.logical_plan())
        assert [n.kind for n in nodes] == ["filter", "map", "scan"]
        with pytest.raises(dataclasses.FrozenInstanceError):
            nodes[1].col = "other"

    def test_with_child_is_copy_not_mutation(self):
        scan = P.Scan(table())
        m = P.LLMMap(input=scan, col="category", prompt="p: ",
                     out_col="o", max_new=4)
        f = P.Filter(input=m, pred=lambda r: True)
        swapped = P.with_child(m, P.with_child(f, scan))
        assert f.child is m                     # original untouched
        assert swapped.child.kind == "filter"

    def test_schema_tracking(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="label") \
            .llm_correct("status")
        assert P.schema_at(q.logical_plan()) == {
            "category", "status", "label", "status_fixed"}

    def test_validate_rejects_missing_column(self):
        q = Query(table(), FakeSession()).llm_map("nope", prompt="p: ")
        with pytest.raises(ValueError, match="missing column"):
            P.validate(q.logical_plan())

    def test_qsig_stable_across_fusion(self):
        scan = P.Scan(table())
        m = P.LLMMap(input=scan, col="category", prompt="p: ",
                     out_col="o", max_new=4)
        fused = P.LLMFused(input=scan, col="category", prompt="p: ",
                           outs=("o", "o2"), max_new=4, src_kind="map")
        assert P.qsig(m) == P.qsig(fused)
        # same for corrects: the fused node keeps its constituents'
        # signature so fusion never forks the model cache
        c = P.LLMCorrect(input=scan, col="category", prompt="p: ",
                         out_col="o", max_new=4)
        fc = P.LLMFused(input=scan, col="category", prompt="p: ",
                        outs=("o", "o2"), max_new=4, src_kind="correct")
        assert P.qsig(c) == P.qsig(fc)
        assert P.qsig(c) != P.qsig(fused)


class TestRules:
    def _plan(self, q):
        opt, firings = OPT.optimize(q.logical_plan())
        return opt, [f.rule for f in firings]

    def test_pushdown_declared_filter_below_map(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="label") \
            .filter(lambda r: r["status"] == "ok", columns=["status"])
        opt, rules = self._plan(q)
        assert "pushdown" in rules
        kinds = [n.kind for n in P.chain(opt)]
        assert kinds.index("filter") > kinds.index("map")  # filter deeper

    def test_pushdown_blocked_without_declared_columns(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="label") \
            .filter(lambda r: r["status"] == "ok")       # opaque pred
        _, rules = self._plan(q)
        assert "pushdown" not in rules

    def test_pushdown_blocked_when_pred_reads_llm_output(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="label") \
            .filter(lambda r: r["label"] == "x", columns=["label"])
        _, rules = self._plan(q)
        assert "pushdown" not in rules

    def test_opaque_filter_still_crosses_llm_filter(self):
        # two filters commute regardless of read sets
        q = Query(table(), FakeSession()) \
            .llm_filter("category", prompt="keep? ") \
            .filter(lambda r: r["status"] == "ok")
        opt, rules = self._plan(q)
        assert "pushdown" in rules
        kinds = [n.kind for n in P.chain(opt)]
        assert kinds.index("filter") > kinds.index("llm_filter")

    def test_pushdown_never_crosses_join(self):
        right = Table({"name": ["a", "b"]})
        q = Query(Table({"name": ["a", "c"], "s": ["x", "y"]}),
                  FakeSession()) \
            .llm_join(right, ("name", "name")) \
            .filter(lambda r: True, columns=["l_s"])
        _, rules = self._plan(q)
        assert "pushdown" not in rules

    def test_dedup_fires_on_duplicate_scan_column(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="label")
        opt, rules = self._plan(q)
        assert rules == ["dedup"]
        assert P.chain(opt)[0].dedup

    def test_dedup_skips_all_unique_and_derived_columns(self):
        t = Table({"v": ["a", "b", "c"]})
        q1 = Query(t, FakeSession()).llm_map("v", prompt="p: ",
                                             out_col="o")
        _, rules = self._plan(q1)
        assert "dedup" not in rules              # no duplicates
        q2 = Query(table(), FakeSession()) \
            .llm_correct("category") \
            .llm_map("category_fixed", prompt="p: ", out_col="o")
        opt, _ = self._plan(q2)
        # the derived-column map has unknown uniqueness: never annotated
        derived = [n for n in P.chain(opt) if n.kind == "map"]
        assert derived and not derived[0].dedup

    def test_dedup_skips_shadowed_scan_column(self):
        # an op below REWRITES 'category' in place: the Scan stats no
        # longer describe the values the map will read, even though
        # the name still resolves in the stats table
        q = Query(table(), FakeSession()) \
            .llm_correct("category", prompt="fix: ",
                         out_col="category") \
            .llm_map("category", prompt="p: ", out_col="o")
        opt, _ = self._plan(q)
        maps = [n for n in P.chain(opt) if n.kind == "map"]
        assert maps and not maps[0].dedup
        # the correct itself still reads the pristine Scan column
        corrects = [n for n in P.chain(opt) if n.kind == "correct"]
        assert corrects and corrects[0].dedup

    def test_fusion_requires_identical_template(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="o1") \
            .llm_map("category", prompt="p: ", out_col="o2")
        opt, rules = self._plan(q)
        assert "fusion" in rules
        fused = P.chain(opt)[0]
        assert fused.kind == "fused" and fused.outs == ("o1", "o2")
        # different templates never fuse
        q2 = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="o1") \
            .llm_map("category", prompt="q: ", out_col="o2")
        _, rules2 = self._plan(q2)
        assert "fusion" not in rules2

    def test_fusion_blocked_when_second_reads_first_output(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="x") \
            .llm_map("x", prompt="p: ", out_col="y")
        _, rules = self._plan(q)
        assert "fusion" not in rules

    def test_fusion_blocked_across_kinds(self):
        # a fused map+correct would have to pick one kind's qsig and
        # fork the other's model cache: like-kinded fusion only
        q = Query(table(), FakeSession()) \
            .llm_correct("category", prompt="p: ", out_col="x",
                         max_new=4) \
            .llm_map("category", prompt="p: ", out_col="y", max_new=4)
        _, rules = self._plan(q)
        assert "fusion" not in rules

    def test_correct_correct_fusion_keeps_model_cache_key(self):
        q = Query(table(), FakeSession()) \
            .llm_correct("category", prompt="p: ", out_col="x",
                         max_new=4) \
            .llm_correct("category", prompt="p: ", out_col="y",
                         max_new=4)
        opt, rules = self._plan(q)
        assert "fusion" in rules
        fused = P.chain(opt)[0]
        solo = P.LLMCorrect(input=P.Scan(table()), col="category",
                            prompt="p: ", out_col="x", max_new=4)
        assert P.qsig(fused) == P.qsig(solo)

    def test_every_firing_strictly_reduces_cost(self):
        q = Query(table(), FakeSession()) \
            .llm_map("category", prompt="p: ", out_col="o1") \
            .llm_map("category", prompt="p: ", out_col="o2") \
            .filter(lambda r: r["status"] == "ok", columns=["status"])
        _, firings = OPT.optimize(q.logical_plan())
        assert len(firings) >= 3                # pushdown+fusion+dedup
        for f in firings:
            assert f.cost_after < f.cost_before

    def test_optimizer_is_deterministic(self):
        def build():
            return (Query(table(), FakeSession())
                    .llm_map("category", prompt="p: ", out_col="o1")
                    .llm_map("category", prompt="p: ", out_col="o2")
                    .filter(lambda r: r["status"] == "ok",
                            columns=["status"]))
        a, fa = OPT.optimize(build().logical_plan())
        b, fb = OPT.optimize(build().logical_plan())
        assert [(f.rule, f.desc, f.cost_before, f.cost_after)
                for f in fa] == \
               [(f.rule, f.desc, f.cost_before, f.cost_after)
                for f in fb]
        assert P.render(a) == P.render(b)


def run_pair(build):
    """Run the same query with the optimizer on and off; return
    (table_on, table_off, calls_on, calls_off)."""
    s_on, s_off = FakeSession(), FakeSession()
    r_on = build(s_on, optimize_plan=True).run()
    r_off = build(s_off, optimize_plan=False).run()
    return r_on, r_off, s_on.eng.calls, s_off.eng.calls


class TestByteIdentity:
    """Optimizer on vs off: byte-identical outputs on plans where
    every rule (pushdown, dedup, fusion) fires."""

    def test_all_rules_fire_and_outputs_identical(self):
        def build(sess, **kw):
            return (Query(table(), sess, optimize=False, **kw)
                    .llm_map("category", prompt="p: ", out_col="o1",
                             max_new=4)
                    .llm_map("category", prompt="p: ", out_col="o2",
                             max_new=4)
                    .filter(lambda r: r["status"] == "ok",
                            columns=["status"]))
        # precondition: all three rules fire on this plan
        _, firings = OPT.optimize(build(FakeSession()).logical_plan())
        assert {f.rule for f in firings} == {"pushdown", "dedup", "fusion"}
        r_on, r_off, calls_on, calls_off = run_pair(build)
        assert r_on.columns == r_off.columns          # byte-identical
        assert len(calls_on) < len(calls_off) / 2     # >2x fewer calls

    def test_llm_filter_pipeline_identical(self):
        def build(sess, **kw):
            return (Query(table(), sess, optimize=False, **kw)
                    .llm_filter("category",
                                prompt="keep? ",
                                keep=lambda o: "(keep? a)" in o)
                    .filter(lambda r: r["status"] != "bad",
                            columns=["status"]))
        r_on, r_off, calls_on, calls_off = run_pair(build)
        assert r_on.columns == r_off.columns
        assert len(calls_on) < len(calls_off)

    def test_optimized_models_path_identical(self):
        def build(sess, **kw):
            return (Query(table(), sess, optimize=True, **kw)
                    .llm_map("category", prompt="p: ", out_col="o",
                             max_new=4)
                    .filter(lambda r: r["status"] == "ok",
                            columns=["status"]))
        r_on, r_off, _, _ = run_pair(build)
        assert r_on.columns == r_off.columns

    def test_join_survives_the_pipeline(self):
        right = Table({"name": ["alpha", "beta", "Alpha"]})

        def build(sess, **kw):
            return (Query(Table({"name": ["alpha", "gamma"]}), sess,
                          optimize=False, **kw)
                    .llm_join(right, ("name", "name")))
        s = FakeSession(lambda p: "same"
                        if len(set(x.strip().lower() for x in
                                   p.split(":", 1)[1].split("|"))) == 1
                        else "different")
        s2 = FakeSession(s.eng.fn)
        r_on = build(s, optimize_plan=True).run()
        r_off = build(s2, optimize_plan=False).run()
        assert r_on.columns == r_off.columns
        assert len(r_on) == 2


class TestPhysicalPlan:
    def test_annotations(self):
        sess = FakeSession()
        q = Query(table(), sess, optimize=True) \
            .llm_map("category", prompt="p: ", out_col="o") \
            .filter(lambda r: r["status"] == "ok", columns=["status"])
        pp = q.physical_plan()
        [op] = pp.llm_ops
        assert op.engine == "optimized" and op.placement == "private"
        assert op.prefix == "p: " and op.dedup
        assert op.qsig == P.qsig(op.node)
        # base-engine query flips the annotation
        q2 = Query(table(), sess, optimize=False).llm_map("category")
        assert q2.physical_plan().llm_ops[0].engine == "base"

    def test_executor_protocol_counts_and_order(self):
        sess = FakeSession()
        q = Query(table(), sess, optimize=False) \
            .llm_map("category", prompt="p: ", out_col="o", max_new=4) \
            .filter(lambda r: r["status"] == "ok", columns=["status"])
        gen = q._ops()
        op = gen.send(None)
        prompts = list(op.spec.prompts)
        # dedup + pushdown applied: unique categories of ok-rows
        assert prompts == ["p: a", "p: c", "p: b"]
        with pytest.raises(StopIteration) as stop:
            gen.send([f"<{p}>" for p in prompts])
        out = stop.value.value
        assert out["o"] == ["<p: a>", "<p: a>", "<p: c>", "<p: b>",
                            "<p: c>"]

    def test_run_stats_report_invocations(self):
        sess = FakeSession()
        q = Query(table(), sess, optimize=False) \
            .llm_map("category", prompt="p: ", out_col="o", max_new=4)
        q.run()
        [st] = q.last_run_stats
        assert st.kind == "map" and st.invocations == 3   # unique values

    def test_select_lowered_inline(self):
        sess = FakeSession()
        out = Query(table(), sess, optimize=False) \
            .llm_map("category", prompt="p: ", out_col="o", max_new=4) \
            .select(["o"]).run()
        assert list(out.columns) == ["o"] and len(out) == 8

    def test_join_probe_honors_n_probe(self):
        """build_probe used to hardcode [:32] x [:2] for LLMJoin,
        silently ignoring the caller's bound — the cascade threshold is
        fit on this probe, so the requested sample size must be real."""
        left = Table({"k": [f"l{i}" for i in range(40)]})
        right = Table({"k": [f"r{i}" for i in range(8)]})
        node = P.LLMJoin(input=P.Scan(left), right=right, on=("k", "k"),
                         prompt="match: ", max_new=4)
        probe = PHYS.build_probe(node, left, 4)
        assert len(probe) == 4              # ceil(4/2)=2 left x 2 right
        assert probe == ["match: l0 | r0", "match: l0 | r1",
                         "match: l1 | r0", "match: l1 | r1"]
        # a tiny bound still yields a non-empty sample
        assert len(PHYS.build_probe(node, left, 1)) == 1
        # the default bound reproduces the historical 32 x 2 sample
        assert len(PHYS.build_probe(node, left, 64)) == 64


EXPECTED_EXPLAIN = """\
EXPLAIN (models: base, placement: private, plan optimizer: on, cost unit: rows x prompt_tokens)

logical plan:
  Filter[reads=(status)]
    LLMMap[category -> label, prompt='label: ']
      Scan[scan, rows=8, cols=(category, status)]

optimized plan:
  LLMMap[category -> label, prompt='label: ', dedup]  (rows 4 -> 4, 2 calls x 8 tok = cost 16)
    Filter[reads=(status)]  (rows 8 -> 4)
      Scan[scan, rows=8, cols=(category, status)]  (rows 8 -> 8)

rules fired:
  1. dedup: unique inputs only for LLMMap[category -> label, prompt='label: '] (cost 64 -> 24 rows x prompt_tokens) [verified]
  2. pushdown: Filter[reads=(status)] below LLMMap[category -> label, prompt='label: ', dedup] (cost 24 -> 16 rows x prompt_tokens) [verified]

physical plan:
  1. table filter
  2. llm map qsig=31aef8a83219 engine=base backend=reference placement=private dedup=on est_calls=2 prefix='label: '

estimated LLM cost: 64 -> 16 prompt-tokens (4.0x)"""


class TestExplain:
    def test_explain_snapshot(self):
        q = Query(table(), FakeSession(), optimize=False) \
            .llm_map("category", prompt="label: ", out_col="label",
                     max_new=4) \
            .filter(lambda r: r["status"] == "ok", columns=["status"])
        assert q.explain() == EXPECTED_EXPLAIN

    def test_explain_header_names_the_cost_unit(self):
        # the unit label is load-bearing: raw ints in EXPLAIN were
        # mistaken for row counts before it existed.  Assert the header
        # verbatim so the format cannot silently drift.
        q = Query(table(), FakeSession(), optimize=False) \
            .llm_map("category", prompt="label: ", out_col="label")
        header = q.explain().splitlines()[0]
        assert header == ("EXPLAIN (models: base, placement: private, "
                          "plan optimizer: on, "
                          "cost unit: rows x prompt_tokens)")

    def test_explain_marks_verified_rules(self):
        q = Query(table(), FakeSession(), optimize=False) \
            .llm_map("category", prompt="p: ", out_col="o", max_new=4)
        text = q.explain()
        assert "dedup" in text and "[verified]" in text

    def test_explain_optimizer_off_shows_no_rules(self):
        q = Query(table(), FakeSession(), optimize_plan=False) \
            .llm_map("category", prompt="p: ", out_col="o")
        text = q.explain()
        assert "plan optimizer: off" in text
        assert "(none)" in text

    def test_explain_does_not_execute(self):
        sess = FakeSession()
        Query(table(), sess).llm_map("category").explain()
        assert sess.eng.calls == []


class TestDedupSpec:
    def test_dedup_scatter_preserves_row_order(self):
        t = Table({"v": ["x", "y", "x", "z", "y"]})
        spec = OPS.map_spec(t, "v", prompt="p: ", out_col="o",
                            dedup=True)
        prompts = list(spec.prompts)
        assert prompts == ["p: x", "p: y", "p: z"]
        out = spec.finish(["X", "Y", "Z"])
        assert out["o"] == ["X", "Y", "X", "Z", "Y"]

    def test_dedup_stringifies_consistently(self):
        t = Table({"v": [1, "1", 1]})
        spec = OPS.correct_spec(t, "v", prompt="p: ", dedup=True)
        assert list(spec.prompts) == ["p: 1"]
        assert spec.finish(["one"])["v_fixed"] == ["one"] * 3
